"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic packed-documents pipeline, with async checkpointing, resume,
and straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 20   # quick look
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.data import SyntheticLM, make_batch
from repro.ft import StragglerMonitor
from repro.models import init_params
from repro.models.common import ArchConfig
from repro.train import cosine_lr, init_train_state, make_train_step

# ~100M params: 50k x 640 embed (32M, tied) + 10 layers x (attn 1.6M + mlp 4.9M)
CFG_100M = ArchConfig(
    name="repro-100m", family="dense", n_layers=10, d_model=640, n_heads=10,
    n_kv_heads=10, head_dim=64, d_ff=2560, vocab_size=50_304,
    tie_embeddings=True, dtype=jnp.float32,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = CFG_100M
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"== training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    opt = init_train_state(params)
    start = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir)
    if latest_step(args.ckpt_dir) is not None:
        state, start = restore(args.ckpt_dir, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"   resumed from checkpoint at step {start}")

    def step_with_lr(params, opt, batch):
        lr = cosine_lr(opt["step"], peak=args.lr, warmup=20, total=args.steps)
        return make_train_step(cfg, lr=args.lr)(params, opt, batch)

    step_fn = jax.jit(step_with_lr)
    stream = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    mon = StragglerMonitor()

    for s in range(start, args.steps):
        mon.start()
        params, opt, m = step_fn(params, opt, make_batch(stream, s))
        jax.block_until_ready(m["loss"])
        dur, slow = mon.stop()
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(m['loss']):.4f} "
                  f"({args.batch*args.seq/max(dur,1e-9):,.0f} tok/s"
                  f"{', STRAGGLER' if slow else ''})")
        if (s + 1) % 50 == 0:
            ckpt.save({"params": params, "opt": opt}, s + 1)
    ckpt.save({"params": params, "opt": opt}, args.steps)
    ckpt.wait()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
