"""Quickstart: autotune the syr2k schedule on this machine in ~a minute.

This is the paper's Sec. 4.1 case study end to end: define the pragma-shaped
parameter space (tiles x interchange x packing with the pack-B-requires-
pack-A condition), wall-clock candidate schedules through the plopper, and
let Bayesian optimization (Random Forest surrogate, LCB acquisition) find
the best configuration. Compare against the space's default.

    PYTHONPATH=src python examples/quickstart.py [--evals 30] [--learner RF]
"""

import argparse

from repro.core import TimingEvaluator, autotune
from repro.core.findmin import importance_report
from repro.kernels import ref as R
from repro.kernels import variants as V
from repro.kernels.spaces import kernel_space


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=30)
    ap.add_argument("--learner", default="RF", choices=["RF", "ET", "GBRT", "GP"])
    ap.add_argument("--n", type=int, default=240)
    ap.add_argument("--m", type=int, default=200)
    args = ap.parse_args()

    print(f"== syr2k autotuning: N={args.n} M={args.m}, "
          f"{args.evals} evaluations, learner={args.learner}")
    problem = R.init_syr2k(args.n, args.m)
    factory = V.syr2k_host(problem)
    evaluator = TimingEvaluator(factory, repeats=2, warmup=1)
    space = kernel_space("syr2k", target="host")
    print(f"   search space: {int(space.cardinality()):,} configurations "
          f"(paper: 10,648)")

    default = space.default_configuration()
    t_default = evaluator(default).objective
    print(f"   default config {default}: {t_default*1e3:.2f} ms")

    res = autotune(space, evaluator, max_evals=args.evals,
                   learner=args.learner, seed=1234)
    b = res.best
    print(f"   best config    {b.config}")
    print(f"   best time      {b.objective*1e3:.2f} ms "
          f"(found at evaluation {b.index}; "
          f"{t_default/b.objective:.2f}x vs default)")
    print("   parameter importance (step 9 of the paper's framework):")
    for name, spread in importance_report(res.db):
        print(f"     {name:12s} spread={spread*1e3:.2f} ms")


if __name__ == "__main__":
    main()
