"""Guard smoke: an injected latency regression heals itself end to end.

The chaos scenario the resilience layer exists for, on a real kernel
through the real serving stack:

1. autotune syr2k and publish the winner into a TuningStore (the
   baseline the drift watcher will compare live traffic against);
2. serve it through DispatchService with a GuardAgent attached — an
   epsilon of shadow evaluations re-times the served executable and
   tells live measurements back into the store;
3. inject ``dispatch.latency`` (the "driver update regressed this
   config" fault) and run the watcher: sustained p50 drift past the
   hysteresis threshold auto-quarantines the record with a machine-
   readable ``drift:<ratio>x`` reason and requests a re-campaign;
4. the next dispatch degrades to the default config (serving never
    stalls), and the drained re-campaign — its evaluator hardened with
   a deadline — publishes a replacement config, skipping the banned one.

    PYTHONPATH=src python examples/guard_smoke.py [--evals 6] [--root DIR]
"""

import argparse
import json
import os
import tempfile

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=6)
    ap.add_argument("--root", default=None,
                    help="working dir (default: a fresh tempdir)")
    ap.add_argument("--delay", type=float, default=0.2,
                    help="injected per-dispatch latency inflation (sec)")
    args = ap.parse_args()
    root = args.root or tempfile.mkdtemp(prefix="repro-guard-")
    store_dir = os.path.join(root, "store")

    from repro.dispatch import BackgroundTuner, DispatchService, TuningStore
    from repro.guard import (GuardAgent, HardenPolicy, ShadowPolicy,
                             WatchPolicy, inject)
    from repro.kernels import ref as R
    from repro.launch.autotune import main as autotune_main

    print(f"== tune syr2k ({args.evals} evals) into {store_dir}")
    autotune_main(["--kernel", "syr2k", "--max-evals", str(args.evals),
                   "--db", os.path.join(root, "campaign"),
                   "--store", store_dir])

    store = TuningStore(store_dir)
    sig = R.problem_signature("syr2k", 240, 200)
    banned = store.get("syr2k", sig, "host")
    assert banned is not None, "autotune must publish a baseline record"
    print(f"== baseline: {banned.config} @ {banned.objective:.2e}s")

    tuner = BackgroundTuner(store, max_evals=args.evals,
                            harden=HardenPolicy(deadline_sec=30.0))
    svc = DispatchService(store, tuner=tuner)
    guard = GuardAgent(
        svc,
        watch=WatchPolicy(drift_factor=3.0, hysteresis=2, cooldown_sec=0.0,
                          min_samples=4),
        shadow=ShadowPolicy(epsilon=1.0, challenger_fraction=0.0))
    svc.attach_guard(guard)

    C, A, B = R.init_syr2k(240, 200)
    fn = svc.dispatch("syr2k", C, A, B)
    assert svc.stats["store_exact"] == 1, svc.stats

    print("== serve healthy traffic (shadow evaluation armed)")
    for _ in range(6):
        fn(C, A, B)
    assert guard.check_once() == []          # window base
    for _ in range(6):
        fn(C, A, B)
    assert guard.check_once() == []          # healthy window: no breach
    shadow = guard.shadow.snapshot_stats()
    assert shadow["shadow_evals"] > 0
    print(f"   shadow: {shadow['shadow_evals']} evals, "
          f"{shadow['shadow_tells']} store tells")

    print(f"== inject dispatch.latency (+{args.delay}s on syr2k)")
    with inject("dispatch.latency", delay_sec=args.delay,
                where={"kernel": "syr2k"}):
        for _ in range(5):
            fn(C, A, B)
        assert guard.check_once() == []      # breach 1 of 2: hysteresis holds
        for _ in range(5):
            fn(C, A, B)
        decisions = guard.check_once()       # breach 2: sustained drift

    assert len(decisions) == 1, decisions
    d = decisions[0]
    assert d["action"] == "quarantine" and d["reason"].startswith("drift:")
    assert d["retune_requested"] is True
    quars = store.quarantines("syr2k")
    assert len(quars) == 1 and quars[0]["reason"].startswith("drift:")
    print(f"   watcher: quarantined {d['config']} ({d['reason']}), "
          f"re-campaign requested")

    print("== degraded serving: next dispatch falls back to the default")
    fn2 = svc.dispatch("syr2k", C, A, B)
    assert fn2 is not fn
    assert svc.stats["store_default"] >= 1, svc.stats
    out = np.asarray(fn2(C, A, B))
    np.testing.assert_allclose(
        out, np.asarray(R.syr2k_ref(C, A, B)), rtol=1e-4, atol=1e-4)

    print("== drain the hardened re-campaign")
    tuner.drain(timeout=600)
    tuner.shutdown()
    assert not tuner.errors, tuner.errors
    assert tuner.stats["campaigns"] >= 1
    replacement = store.get("syr2k", sig, "host")
    assert replacement is not None, "recovery must publish a replacement"
    assert replacement.config != d["config"], \
        "the drift-banned config must not be re-published"

    summary = svc.telemetry()["guard"]
    print(json.dumps({
        "banned": d["config"],
        "reason": d["reason"],
        "replacement": replacement.config,
        "replacement_source": replacement.source,
        "guard_stats": {k: summary[k] for k in
                        ("checks", "quarantines", "fallbacks", "retunes")},
        "shadow": summary["shadow"],
    }, indent=2))
    print("guard smoke OK: drift detected, quarantined, degraded, re-tuned")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
