"""Observability smoke: one traced tune → serve → scrape pipeline.

A syr2k campaign runs with tracing enabled (campaign ask/evaluate/tell and
database checkpoint spans land in one Chrome-trace JSONL), the tuned store
then serves dispatches whose execute latencies fill the per-signature
histogram, and the pipeline is asserted end to end: ``telemetry()`` reports
p50/p99 for the tuned signature, an :class:`ObsServer` scrape exposes the
same histogram as Prometheus text, the trace validates with every expected
span present, and the Perfetto export is loadable JSON.

    PYTHONPATH=src python examples/obs_smoke.py [--evals 8] [--root DIR]
"""

import argparse
import json
import os
import tempfile
import urllib.request

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=8)
    ap.add_argument("--root", default=None,
                    help="working dir (default: a fresh tempdir)")
    args = ap.parse_args()
    root = args.root or tempfile.mkdtemp(prefix="repro-obs-")
    store_path = os.path.join(root, "store")
    trace_path = os.path.join(root, "trace.jsonl")
    metrics_path = os.path.join(root, "metrics.jsonl")
    perfetto_path = os.path.join(root, "trace.perfetto.json")

    from repro.dispatch import DispatchService, TuningStore
    from repro.kernels import ref as R
    from repro.launch.autotune import main as autotune_main
    from repro.obs.export import ObsServer, write_snapshot
    from repro.obs.metrics import get_registry, summarize_histograms
    from repro.obs.trace import configure_tracer, export_chrome_trace, validate_trace

    configure_tracer(trace_path, process_name="obs-smoke")

    print(f"== traced syr2k campaign ({args.evals} evals) into {store_path}")
    autotune_main(["--kernel", "syr2k", "--max-evals", str(args.evals),
                   "--db", os.path.join(root, "campaign"),
                   "--store", store_path])

    print("== serving the tuned store; execute latencies -> histogram")
    svc = DispatchService(TuningStore(store_path))
    C, A, B = R.init_syr2k(240, 200)
    fn = svc.dispatch("syr2k", C, A, B)
    for _ in range(5):
        fn(C, A, B)
    tel = svc.telemetry()
    assert svc.stats["store_exact"] == 1, svc.stats
    rows = [r for r in tel["execute_latency"] if r["kernel"] == "syr2k"]
    assert len(rows) == 1, tel["execute_latency"]
    row = rows[0]
    assert row["count"] == 5, row
    assert 0 < row["p50_sec"] <= row["p99_sec"], row

    print("== /metrics scrape must expose the same histogram")
    server = ObsServer(registry=svc.metrics).start()
    try:
        with urllib.request.urlopen(server.url + "/metrics") as r:
            text = r.read().decode()
    finally:
        server.stop()
    assert "repro_dispatch_execute_seconds_count" in text, text[:2000]
    assert 'kernel="syr2k"' in text
    assert f'signature="{row["signature"]}"' in text

    write_snapshot(metrics_path, registry=get_registry(), source="obs-smoke")
    configure_tracer(None)

    print("== trace must validate with the full span set")
    report = validate_trace(trace_path)
    assert report["ok"], report
    required = {"campaign.ask", "campaign.evaluate", "campaign.tell",
                "db.checkpoint", "dispatch.lookup"}
    missing = required - set(report["names"])
    assert not missing, f"missing spans: {sorted(missing)}"

    n_events = export_chrome_trace(trace_path, perfetto_path)
    loaded = json.load(open(perfetto_path))
    assert len(loaded["traceEvents"]) == n_events > 0

    print(json.dumps({
        "trace_events": report["events"],
        "span_names": report["names"],
        "execute_latency": row,
        "campaign_overhead": summarize_histograms(
            get_registry().snapshot(), prefix="campaign_"),
        "artifacts": {"trace": trace_path, "metrics": metrics_path,
                      "perfetto": perfetto_path},
    }, indent=2, default=str))
    print("obs smoke OK: traced campaign, per-signature p50/p99, "
          "Prometheus scrape, Perfetto export")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
