"""Beyond-paper: autotune the *distributed configuration* of a training step
(grad-accumulation, remat policy, attention chunking, precision) against the
compiled-artifact roofline model — the paper's BO engine one level up.

Runs on 8 simulated host devices so it completes in a couple of minutes:

    PYTHONPATH=src:. python examples/autotune_mesh.py [--evals 8]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402

import jax  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=8)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    from benchmarks.hillclimb import knob_space, make_cell_evaluator
    from repro.configs import get_config
    from repro.core import autotune

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config(args.arch)
    log = []
    ev = make_cell_evaluator(args.arch, "train_4k", mesh, log)
    space = knob_space("train", is_moe=cfg.n_experts > 0)

    base = ev(space.default_configuration())
    print(f"baseline ({space.default_configuration()}):")
    print(f"  modeled step bound = {base.objective:.4f}s  "
          f"dominant={base.info.get('dominant')}")

    res = autotune(space, ev, max_evals=args.evals, learner="RF", seed=1234,
                   n_initial=4)
    b = res.best
    print(f"best after {args.evals} lower+compile evaluations:")
    print(f"  config = {b.config}")
    print(f"  modeled step bound = {b.objective:.4f}s "
          f"({base.objective/b.objective:.2f}x better), "
          f"dominant={b.info.get('dominant')}")


if __name__ == "__main__":
    main()
