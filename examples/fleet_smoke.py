"""Fleet smoke: two hosts share one tuning campaign through repro.fleet.

Host A autotunes syr2k (the paper's BO loop, host-timed) and publishes the
winner into its TuningStore; a `repro-fleet sync` through a shared-directory
transport replicates it; host B's DispatchService then resolves the tuned
config for the exact runtime signature with **zero local evaluations** —
the cross-host warm-start story of the ROADMAP's top open item, end to end
through the real CLIs.

    PYTHONPATH=src python examples/fleet_smoke.py [--evals 8] [--root DIR]
"""

import argparse
import json
import os
import tempfile

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=8)
    ap.add_argument("--root", default=None,
                    help="working dir (default: a fresh tempdir)")
    args = ap.parse_args()
    root = args.root or tempfile.mkdtemp(prefix="repro-fleet-")
    store_a = os.path.join(root, "hostA", "store")
    store_b = os.path.join(root, "hostB", "store")
    shared = "file:" + os.path.join(root, "shared")

    from repro.dispatch import DispatchService, TuningStore
    from repro.kernels import ref as R
    from repro.launch.autotune import main as autotune_main
    from repro.launch.fleet import main as fleet_main

    print(f"== host A: tuning syr2k ({args.evals} evals) into {store_a}")
    autotune_main(["--kernel", "syr2k", "--max-evals", str(args.evals),
                   "--db", os.path.join(root, "hostA", "campaign"),
                   "--store", store_a])

    print("== host A: repro-fleet sync (push the tuned config)")
    assert fleet_main(["sync", "--store", store_a, "--transport", shared]) == 0
    print("== host B: repro-fleet sync (pull it)")
    assert fleet_main(["sync", "--store", store_b, "--transport", shared]) == 0

    print("== host B: dispatch() must resolve A's config with zero evals")
    svc = DispatchService(TuningStore(store_b))     # no tuner: nothing to eval
    C, A, B = R.init_syr2k(240, 200)
    out = np.asarray(svc.dispatch("syr2k", C, A, B)(C, A, B))
    assert svc.stats["store_exact"] == 1, svc.stats
    assert svc.stats["bg_enqueued"] == 0
    rec = TuningStore(store_b).get("syr2k", R.problem_signature("syr2k", 240, 200),
                                   "host")
    assert rec is not None and rec.source.startswith("cli:"), rec
    np.testing.assert_allclose(
        out, np.asarray(R.syr2k_ref(C, A, B)), rtol=1e-4, atol=1e-4)
    print(json.dumps({"host_b_resolved": rec.config,
                      "objective_sec": rec.objective,
                      "stats": svc.stats}, indent=2))
    print("fleet smoke OK: host B serves host A's tuned config, zero evals")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
