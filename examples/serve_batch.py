"""Batched serving example: prefill a batch of prompts, decode greedily with
the per-family cache (GQA ring cache for windowed archs, MLA latents for
DeepSeek, SSM state for Mamba2).

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-780m

--store STORE_DIR attaches a repro.dispatch service: prefill attention and
the decode matmuls resolve tuned block shapes from the TuningStore by shape
signature (write-time bucketed, so jittery batch sizes share records), and
the dispatch stats line shows where each resolution came from.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_reduced
from repro.models import init_params
from repro.serve import cache_bytes_per_token, greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--store", default=None, metavar="STORE_DIR",
                    help="TuningStore dir: serve through repro.dispatch")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_reduced(args.arch), dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"== serving {cfg.name} (reduced): batch={args.batch}, "
          f"cache/token={cache_bytes_per_token(cfg)} bytes")

    svc = None
    if args.store:
        from repro.dispatch import DispatchService, TuningStore
        svc = DispatchService(TuningStore(args.store, bucket=True))

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "audio":
        kw["enc_embed"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_len, cfg.d_model))
    t0 = time.time()
    out = greedy_decode(params, cfg, prompt, steps=args.gen,
                        max_len=args.prompt_len + args.gen, service=svc, **kw)
    jax.block_until_ready(out)
    print(f"   generated {args.batch}x{args.gen} ids in {time.time()-t0:.1f}s")
    if svc is not None:
        print(f"   dispatch stats: {svc.stats}")
    for b in range(min(2, args.batch)):
        print(f"   request {b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
