"""Optimizer-overhead benchmark: how much wall-clock the BO loop itself costs.

CATBench (Tørring et al. 2024) makes optimizer overhead a first-class metric
for compiler-autotuning loops: at the paper's scale (200 evaluations over
spaces of up to 170k configurations) the surrogate fit + acquisition scan can
dominate the tuning loop once the evaluations themselves are cheap (cost
backend) or run concurrently (``--parallel N``). This benchmark times the
``ask`` / ``tell`` hot path of :class:`repro.core.search.BayesianSearch` at
n ∈ {50, 100, 200} observations for all four learners and writes
``BENCH_tuner_overhead.json`` (stamped with host/git-sha/timestamp via
``benchmarks.common.bench_meta``) plus ``BENCH_tuner_overhead.obs.jsonl``, an
``repro.obs`` metrics snapshot with ``bench_{ask,tell,ask_batch}_seconds``
histograms labeled per learner — so the speedup from vectorizing the
surrogate stack is a tracked number rather than a claim. A tiny synthetic
cascade rides along so the snapshot also carries the repro.fidelity
screen/promote counters and the feasibility-pruning count (``n_pruned``).

Usage::

    PYTHONPATH=src python benchmarks/tuner_overhead.py            # full matrix
    PYTHONPATH=src python benchmarks/tuner_overhead.py --quick    # CI smoke
    PYTHONPATH=src python benchmarks/tuner_overhead.py --quick \
        --assert-ask-budget 5.0       # fail loudly on surrogate perf regression

The ``--assert-ask-budget`` flag exits non-zero when the median ``ask()`` at
the largest measured n exceeds the budget (seconds) for any learner — the CI
regression tripwire.
"""

from __future__ import annotations

import argparse
import os
import statistics
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import bench_meta, write_bench_json  # noqa: E402
from repro.core.plopper import EvalResult  # noqa: E402
from repro.core.search import BayesianSearch  # noqa: E402
from repro.core.space import Categorical, ConfigurationSpace, Ordinal  # noqa: E402
from repro.obs.export import write_snapshot  # noqa: E402
from repro.obs.metrics import MetricsRegistry, summarize_histograms  # noqa: E402

TILES = (4, 8, 16, 20, 32, 64, 96, 100, 128, 256, 2048)  # the paper's 11-entry list


def make_space(seed: int = 1234) -> ConfigurationSpace:
    """A syr2k-shaped space scaled toward the paper's largest (170,368-config
    mvt space): pragma on/off categoricals plus 11-entry tile-size ordinals."""
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("p_interchange", (True, False), default=False),
        Categorical("p_pack_a", (True, False), default=False),
        Categorical("p_pack_b", (True, False), default=False),
        Categorical("p_vectorize", (True, False), default=False),
        Ordinal("t_l1", TILES, default=96),
        Ordinal("t_l2", TILES, default=96),
        Ordinal("t_l3", TILES, default=96),
        Ordinal("u_factor", TILES, default=4),
    ])
    return cs


def objective(cfg) -> float:
    t = 1.0
    t -= 0.25 * bool(cfg["p_pack_a"]) + 0.15 * bool(cfg["p_pack_b"])
    t -= 0.1 * bool(cfg["p_interchange"]) + 0.05 * bool(cfg["p_vectorize"])
    for k, opt in (("t_l1", 64), ("t_l2", 32), ("t_l3", 96), ("u_factor", 8)):
        t += 2e-4 * abs(int(cfg[k]) - opt)
    return t


def seeded_search(learner: str, n_obs: int, seed: int = 1234) -> BayesianSearch:
    """A search whose DB already holds ``n_obs`` told observations — the
    steady state whose per-iteration ask/tell cost we measure."""
    search = BayesianSearch(make_space(seed), learner=learner, seed=seed,
                            n_initial=min(10, n_obs))
    rng = np.random.default_rng(seed + 1)
    for cfg in search.space.sample_configurations(n_obs, rng):
        search.tell(cfg, EvalResult(objective(cfg), True, {}))
    return search


def time_learner(learner: str, n_obs: int, repeats: int, batch: int,
                 seed: int = 1234, registry: MetricsRegistry | None = None) -> dict:
    search = seeded_search(learner, n_obs, seed)
    registry = registry if registry is not None else MetricsRegistry()
    labels = {"learner": learner, "n_obs": n_obs}

    # the real loop shape: every ask is followed by a tell, so each fit sees
    # freshly-grown training data (no artificial repeat-ask memoization)
    ask_times, tell_times = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        cfg = search.ask()
        ask_times.append(time.perf_counter() - t0)
        registry.observe("bench_ask_seconds", ask_times[-1], **labels)
        t0 = time.perf_counter()
        search.tell(cfg, EvalResult(objective(cfg), True, {}))
        tell_times.append(time.perf_counter() - t0)
        registry.observe("bench_tell_seconds", tell_times[-1], **labels)

    # batched ask: n proposals through one pooled candidate set + liar refits
    batch_times = []
    for _ in range(max(1, repeats // 2)):
        t0 = time.perf_counter()
        cfgs = search.ask(batch)
        batch_times.append(time.perf_counter() - t0)
        registry.observe("bench_ask_batch_seconds", batch_times[-1],
                         batch=batch, **labels)
        for cfg in cfgs:
            search.tell(cfg, EvalResult(objective(cfg), True, {}))

    return {
        "ask_sec": statistics.median(ask_times),
        "ask_mean_sec": statistics.fmean(ask_times),
        f"ask_batch{batch}_sec": statistics.median(batch_times),
        "tell_sec": statistics.median(tell_times),
        "repeats": repeats,
    }


def time_cascade(registry: MetricsRegistry, seed: int = 1234) -> dict:
    """One tiny synthetic cascade so the overhead snapshot also carries the
    repro.fidelity counters (``fidelity_screened_total`` /
    ``fidelity_promoted_total``), the per-rung campaign latency histograms,
    and a non-zero feasibility-pruning count (``n_pruned``) — the tuner's
    full telemetry surface in one artifact."""
    from repro.core.plopper import EvalResult
    from repro.fidelity import CascadeCampaign, FidelityLadder, Rung
    from repro.obs.metrics import get_registry, set_registry

    space = make_space(seed)
    ladder = FidelityLadder([
        Rung(0, "cost", lambda c: EvalResult(1e-3 * objective(c), True, {}),
             budget=24, promote=4),
        Rung(1, "hw", lambda c: EvalResult(objective(c), True, {}), budget=8),
    ])
    prev = get_registry()
    set_registry(registry)  # campaigns bind the process registry at build
    try:
        res = CascadeCampaign(
            space, ladder, seed=seed, n_initial=6, kernel="synthetic",
            feasibility=lambda c: int(c["t_l1"]) <= 1024).run()
    finally:
        set_registry(prev)
    return {
        "screened": res.stats["screened"],
        "promoted": res.stats["promoted"],
        "hw_evals": res.hw_evals,
        "n_pruned": sum(r.timings.get("n_pruned", 0) for r in res.rungs),
        "ask_sec": res.timings["ask_sec"],
        "tell_sec": res.timings["tell_sec"],
    }


def run(learners, sizes, repeats, batch, out, seed=1234):
    # every ask/tell lands in one registry as bench_{ask,tell,ask_batch}_seconds
    # histograms labeled (learner, n_obs) — the same snapshot format the rest
    # of the obs stack speaks, so a dashboard ingesting dispatch snapshots
    # can ingest benchmark runs unchanged
    registry = MetricsRegistry()
    results: dict = {
        "space_cardinality": make_space().cardinality(),
        "sizes": list(sizes),
        "learners": {},
    }
    for learner in learners:
        per_n = {}
        for n_obs in sizes:
            per_n[str(n_obs)] = time_learner(learner, n_obs, repeats, batch,
                                             seed, registry=registry)
            print(f"[{learner}] n={n_obs}: ask={per_n[str(n_obs)]['ask_sec'] * 1e3:.2f}ms "
                  f"ask(batch{batch})={per_n[str(n_obs)][f'ask_batch{batch}_sec'] * 1e3:.2f}ms "
                  f"tell={per_n[str(n_obs)]['tell_sec'] * 1e6:.1f}us", flush=True)
        results["learners"][learner] = per_n
    results["cascade"] = time_cascade(registry, seed)
    print(f"[cascade] screened={results['cascade']['screened']} "
          f"promoted={results['cascade']['promoted']} "
          f"hw_evals={results['cascade']['hw_evals']} "
          f"n_pruned={results['cascade']['n_pruned']}", flush=True)
    snapshot = registry.snapshot()
    results["obs"] = summarize_histograms(snapshot)
    write_bench_json(out, results)
    obs_out = os.path.splitext(out)[0] + ".obs.jsonl"
    write_snapshot(obs_out, registry=registry, bench="tuner_overhead")
    print(f"wrote {out} and {obs_out}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--learners", nargs="*", default=["RF", "ET", "GBRT", "GP"])
    ap.add_argument("--sizes", nargs="*", type=int, default=[50, 100, 200])
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: RF+GP only, n in {50, 200}, 3 repeats")
    ap.add_argument("--out", default="BENCH_tuner_overhead.json")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--assert-ask-budget", type=float, default=None, metavar="SEC",
                    help="exit non-zero if median ask() at the largest n exceeds "
                         "this many seconds for any learner")
    args = ap.parse_args(argv)
    if args.quick:
        args.learners = ["RF", "GP"]
        args.sizes = [50, 200]
        args.repeats = 3
    results = run(args.learners, args.sizes, args.repeats, args.batch,
                  args.out, args.seed)
    if args.assert_ask_budget is not None:
        top = str(max(args.sizes))
        over = {lr: per_n[top]["ask_sec"]
                for lr, per_n in results["learners"].items()
                if per_n[top]["ask_sec"] > args.assert_ask_budget}
        if over:
            print(f"FAIL: ask() at n={top} over budget "
                  f"({args.assert_ask_budget}s): {over}", file=sys.stderr)
            return 1
        print(f"ask() budget OK: all learners under {args.assert_ask_budget}s at n={top}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
