"""Shared benchmark machinery: the per-table comparison runner.

Each PolyBench table compares five compilation strategies, mirroring the
paper's rows (mapping documented in DESIGN.md §2):

  row 1  naive          untransformed loop nest       ("gcc -O3")
  row 2  xla_default    one library call, stock XLA   ("clang -O3")
  row 3  blocked_heur   blocked variant, compiler-default heuristic tiles
                        (128^3 MXU-ish)               ("clang -O3 + polly")
  row 4  blocked_paper  blocked variant, the paper's default tiles
                        (96, 2048, 256)               ("polly + pragmas, default tiles")
  row 5  autotuned      blocked variant, best config from a BO campaign
                        over the paper-shaped space   ("polly + pragmas + ytopt")

All rows are wall-clocked on this host via TimingEvaluator (the role the
paper's Core-i7 plays). Dataset sizes are scaled so campaigns finish on CPU;
set REPRO_BENCH_SCALE=large for closer-to-paper sizes and REPRO_BENCH_EVALS
to change the campaign length (default 30; paper used 200).
"""

from __future__ import annotations

import datetime
import json
import os
import platform
import socket
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TimingEvaluator, autotune
from repro.core.space import ConfigurationSpace

EVALS = int(os.environ.get("REPRO_BENCH_EVALS", "30"))
SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")
LEARNER = os.environ.get("REPRO_BENCH_LEARNER", "RF")


def bench_meta() -> dict:
    """Provenance stamp shared by every ``BENCH_*.json`` artifact: which
    host/commit produced the numbers and when — so two artifacts are
    comparable (or visibly not) without archaeology."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:  # noqa: BLE001 — no git is fine (tarball checkout)
        sha = None
    return {
        "host": socket.gethostname(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "git_sha": sha,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def write_bench_json(path: str, payload: dict) -> dict:
    """Stamp ``payload`` with :func:`bench_meta` and write it as JSON;
    returns the stamped dict."""
    out = {"meta": bench_meta(), **payload}
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    return out


def time_callable(fn, args, repeats: int = 3, warmup: int = 1) -> float:
    run = jax.jit(fn)
    out = None
    for _ in range(warmup):
        out = run(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = run(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return min(times)


def run_table(
    name: str,
    naive_fn,
    xla_fn,
    args,
    variant_factory,
    space: ConfigurationSpace,
    heur_config: dict,
    paper_config: dict,
    max_evals: int = EVALS,
    learner: str = LEARNER,
    check_against=None,
) -> list[tuple[str, float, str]]:
    """Returns CSV rows (name, us_per_call, derived)."""
    rows = []

    t = time_callable(naive_fn, args)
    rows.append((f"{name}/naive", t * 1e6, "gcc-O3-role"))

    t = time_callable(xla_fn, args)
    rows.append((f"{name}/xla_default", t * 1e6, "clang-O3-role"))

    for label, cfg in (("blocked_heur", heur_config), ("blocked_paper", paper_config)):
        fn, fargs = variant_factory(cfg)
        t = time_callable(fn, fargs)
        rows.append((f"{name}/{label}", t * 1e6, f"config={cfg}"))

    ev = TimingEvaluator(variant_factory, repeats=2, warmup=1)
    res = autotune(space, ev, max_evals=max_evals, learner=learner, seed=1234)
    best = res.best
    rows.append((
        f"{name}/autotuned_{learner}",
        best.objective * 1e6,
        f"at_eval={best.index}/{max_evals};config={best.config}",
    ))

    if check_against is not None:
        fn, fargs = variant_factory(best.config)
        got = jax.jit(fn)(*fargs)
        ok = bool(jnp.allclose(got, check_against, atol=2e-2, rtol=2e-2))
        rows.append((f"{name}/autotuned_correct", float(ok), "allclose-vs-ref"))
    return rows


def emit(rows) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
