"""Cascade-vs-flat benchmark: same answer, half the hardware bill.

The multi-fidelity claim (repro.fidelity) is quantitative: a cascade that
screens on the analytic cost model and promotes only the top-k should reach
an objective within a few percent of a flat single-fidelity BO campaign
while spending at most half the hardware-rung evaluations. This benchmark
measures exactly that, per kernel:

  * **flat** — one ``Campaign`` wall-clocking every proposal at bench dims
    with budget E (the paper's loop);
  * **cascade** — a ``CascadeCampaign`` over the default ladder whose
    hardware rung gets at most E/2.

Both run the same learner/seed; both winners are then re-timed back-to-back
(min of 5 repeats) so the quality comparison is one fair measurement rather
than two campaigns' internal numbers. Results land in ``BENCH_fidelity.json``
(stamped via ``benchmarks.common.bench_meta``) plus an ``repro.obs``
snapshot with the ``fidelity_screened_total`` / ``fidelity_promoted_total``
counters and per-rung campaign latency histograms.

Usage::

    PYTHONPATH=src python benchmarks/fidelity_bench.py            # full
    PYTHONPATH=src python benchmarks/fidelity_bench.py --quick    # CI smoke

Exit is non-zero when any kernel misses the gate (hardware evals over the
--hw-frac budget, or the cascade winner slower than --tol over the flat
winner); --no-check reports without gating.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import write_bench_json  # noqa: E402
from repro.core.plopper import TimingEvaluator  # noqa: E402
from repro.engine import Campaign  # noqa: E402
from repro.fidelity import CascadeCampaign, default_ladder  # noqa: E402
from repro.kernels.problems import bench_problem  # noqa: E402
from repro.kernels.spaces import kernel_space  # noqa: E402
from repro.obs.export import write_snapshot  # noqa: E402
from repro.obs.metrics import (  # noqa: E402
    MetricsRegistry,
    set_registry,
    summarize_histograms,
)


def retime(kernel: str, config: dict, repeats: int = 5) -> float:
    """One fair measurement for a winner config (min of ``repeats``)."""
    timer = TimingEvaluator(bench_problem(kernel), repeats=repeats, warmup=2)
    res = timer(config)
    return float(res.objective) if res.ok else float("inf")


def bench_kernel(kernel: str, flat_evals: int, budgets: tuple,
                 seed: int, learner: str) -> dict:
    space = kernel_space(kernel, target="host", seed=seed)

    flat = Campaign(
        space, TimingEvaluator(bench_problem(kernel), repeats=2, warmup=1),
        max_evals=flat_evals, learner=learner, seed=seed).run()

    ladder = default_ladder(kernel, budgets=budgets)
    cascade = CascadeCampaign(
        kernel_space(kernel, target="host", seed=seed), ladder,
        learner=learner, seed=seed, kernel=kernel).run()

    # back-to-back re-time of both winners: the quality verdict comes from
    # one measurement context, not from each campaign's own noisy numbers
    t_flat = retime(kernel, dict(flat.best.config))
    t_cascade = retime(kernel, dict(cascade.best.config))
    return {
        "kernel": kernel,
        "learner": learner,
        "seed": seed,
        "flat": {
            "budget": flat_evals,
            "hw_evals": flat.n_evaluated + flat.n_failed,
            "best_config": dict(flat.best.config),
            "best_sec": float(flat.best.objective),
            "retimed_sec": t_flat,
        },
        "cascade": {
            "ladder": ladder.describe(),
            "hw_evals": cascade.hw_evals,
            "screened": cascade.stats["screened"],
            "promoted": cascade.stats["promoted"],
            "calibration": cascade.stats["calibration"],
            "best_config": dict(cascade.best.config),
            "best_sec": float(cascade.best.objective),
            "retimed_sec": t_cascade,
        },
        "hw_eval_ratio": round(cascade.hw_evals / max(1, flat.n_evaluated
                                                      + flat.n_failed), 4),
        "quality_ratio": round(t_cascade / t_flat, 4) if t_flat > 0
        else float("inf"),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", nargs="*", default=["matmul", "mm3"],
                    help="kernels to compare (default: the two whose "
                         "cost-model rank correlation is strongest)")
    ap.add_argument("--flat-evals", type=int, default=30,
                    help="flat campaign budget E (cascade hardware rung "
                         "gets at most E/2)")
    ap.add_argument("--budgets", default=None, metavar="B0,B1[,B2]",
                    help="cascade rung budgets (default: 4E cost screens, "
                         "E/2 proxy, E/2 - 3 hardware)")
    ap.add_argument("--learner", default="RF")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--tol", type=float, default=0.05,
                    help="allowed cascade slowdown over flat (0.05 = 5%%)")
    ap.add_argument("--hw-frac", type=float, default=0.5,
                    help="max cascade hardware evals as a fraction of flat's")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: flat budget 16, cost->hw ladder (96, 8)")
    ap.add_argument("--no-check", action="store_true",
                    help="report without gating the exit code")
    ap.add_argument("--out", default="BENCH_fidelity.json")
    args = ap.parse_args(argv)

    if args.quick:
        args.flat_evals = 16
        budgets = (96, 8)
    elif args.budgets:
        budgets = tuple(int(x) for x in args.budgets.split(","))
    else:
        e = args.flat_evals
        budgets = (4 * e, max(4, e // 2), max(3, e // 2 - 3))
    if args.budgets and args.quick:
        budgets = tuple(int(x) for x in args.budgets.split(","))

    registry = MetricsRegistry()
    prev = set_registry(registry)  # capture the fidelity counters per run
    try:
        rows = [bench_kernel(k, args.flat_evals, budgets, args.seed,
                             args.learner) for k in args.kernels]
    finally:
        set_registry(prev)

    failures = []
    for r in rows:
        hw_ok = r["hw_eval_ratio"] <= args.hw_frac + 1e-9
        q_ok = r["quality_ratio"] <= 1.0 + args.tol
        r["gate"] = {"hw_ok": hw_ok, "quality_ok": q_ok,
                     "pass": hw_ok and q_ok}
        if not r["gate"]["pass"]:
            failures.append(r["kernel"])
        print(f"[{r['kernel']}] flat {r['flat']['retimed_sec'] * 1e6:.1f}us "
              f"({r['flat']['hw_evals']} hw evals) vs cascade "
              f"{r['cascade']['retimed_sec'] * 1e6:.1f}us "
              f"({r['cascade']['hw_evals']} hw evals, "
              f"{r['cascade']['screened']} screened) "
              f"quality x{r['quality_ratio']:.3f} "
              f"hw x{r['hw_eval_ratio']:.2f} "
              f"{'PASS' if r['gate']['pass'] else 'FAIL'}", flush=True)

    payload = {
        "flat_evals": args.flat_evals,
        "budgets": list(budgets),
        "tol": args.tol,
        "hw_frac": args.hw_frac,
        "kernels": rows,
        "gate_pass": not failures,
        "obs": summarize_histograms(registry.snapshot()),
    }
    write_bench_json(args.out, payload)
    obs_out = os.path.splitext(args.out)[0] + ".obs.jsonl"
    write_snapshot(obs_out, registry=registry, bench="fidelity")
    print(f"wrote {args.out} and {obs_out}")

    if failures and not args.no_check:
        print(f"FAIL: gate missed for {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
