"""Continuous-batching serve benchmark — the ground truth for every
"serving got faster" claim.

Replays a mixed prompt/output-length workload with Poisson arrivals through
the real serving stack (``serve.prefill`` + ``decode_step`` on a
:class:`repro.serve.PagedKVCache`), reshaping the decode batch as requests
join and leave, and reports p50/p99 inter-token latency, TTFT, and token
throughput per serving mode:

  * ``einsum``  — the pre-paging reference: one dense max-batch/max-len
    cache, every step attends over the full allocation (the stub-grade
    cache this PR replaces);
  * ``default`` — paged cache + dispatch-service *default* decode config
    (empty tuning store);
  * ``tuned``   — paged cache + a store seeded by a short timing campaign
    over the decode space at the serving signature: the kernel's
    ``impl``/``bk``/``hg`` axes and the cache's ``page`` layout axis are
    tuned together (page decides the seq-bucket ladder every view is cut
    on, the compute-vs-retrace trade).

Writes ``BENCH_serve.json`` via ``benchmarks.common.write_bench_json`` and
``BENCH_serve.obs.jsonl`` — an ``repro.obs`` metrics snapshot from the tuned
run's service registry, with ``dispatch_execute_seconds`` histograms for
both the prefill (flash_attention) and decode (decode_attention) kernels,
so ``repro-obs summarize --metrics`` shows the two hot paths side by side.

The run fails (exit 1) when any mode's p99 token latency is missing,
non-finite, or degenerate — the CI serve-smoke tripwire.

Usage::

    PYTHONPATH=src python benchmarks/serve_bench.py            # full run
    PYTHONPATH=src python benchmarks/serve_bench.py --quick    # CI smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.common import time_callable, write_bench_json  # noqa: E402
from repro.analyze.feasibility import check_config  # noqa: E402
from repro.configs import get_reduced  # noqa: E402
from repro.dispatch import DispatchService, TuningRecord, TuningStore  # noqa: E402
from repro.kernels.model_kernels import (  # noqa: E402
    decode_attention_builder,
    decode_attention_signature,
    init_decode_attention,
    init_flash_attention,
)
from repro.kernels.spaces import kernel_space  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.obs.export import write_snapshot  # noqa: E402
from repro.obs.metrics import MetricsRegistry  # noqa: E402
from repro.serve import PagedKVCache, make_serve_step, prefill  # noqa: E402


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------


def make_workload(n_requests: int, rate: float, prompt_lens, out_mean: int,
                  out_cap: int, seed: int):
    """Deterministic request list: Poisson arrivals (exponential gaps at
    ``rate`` req/s), prompt lengths cycled from a fixed set, output lengths
    4 + geometric(mean ``out_mean``) capped at ``out_cap``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n_requests):
        out = 4 + int(rng.geometric(1.0 / max(out_mean - 4, 1)))
        reqs.append({
            "id": i,
            "arrival": float(arrivals[i]),
            "prompt_len": int(prompt_lens[i % len(prompt_lens)]),
            "out_len": int(min(out, out_cap)),
        })
    return reqs


def _pad_batch(active, free, max_batch):
    """Round the batch up the {1,2,4,8,...} ladder with free slots so the
    serve step sees a bounded set of batch shapes (padding rows decode
    garbage at position 0 that admission later overwrites)."""
    b = 1
    while b < len(active):
        b *= 2
    b = min(b, max_batch)
    pad = [s for s in free if s not in active][: b - len(active)]
    return active + pad


# ---------------------------------------------------------------------------
# one serving run
# ---------------------------------------------------------------------------


def run_mode(mode: str, cfg, params, workload, *, max_batch: int, max_len: int,
             page_size: int, service, round_cap: int = 8) -> dict:
    """Serve ``workload`` to completion; returns latency/throughput metrics.

    ``einsum`` mode decodes the full dense allocation every step (no views);
    paged modes cut bucketed views per round and write back on membership or
    bucket changes."""
    paged = mode != "einsum"
    pc = PagedKVCache(cfg, max_batch, max_len,
                      page_size=page_size if paged else max_len)
    serve = make_serve_step(cfg, service=service) if service is not None \
        else jax.jit(make_serve_step(cfg))
    pending = sorted(workload, key=lambda r: r["arrival"])
    pending = list(pending)
    state: dict[int, dict] = {}   # slot -> {req, tok, done}
    token_lat: list[float] = []
    ttft: list[float] = []
    tokens_out = 0
    peak = pc.stats()   # paged accounting at peak residency, not at drain

    t0 = time.perf_counter()
    skipped = 0.0   # idle fast-forward: virtual seconds skipped while empty

    def clock():
        return time.perf_counter() - t0 + skipped

    while pending or state:
        # admissions: arrivals due now, while slots are free
        free = pc.free_slots()
        while pending and free and pending[0]["arrival"] <= clock():
            req = pending.pop(0)
            slot = free.pop(0)
            prompt = jax.random.randint(
                jax.random.PRNGKey(1000 + req["id"]),
                (1, req["prompt_len"]), 0, cfg.vocab_size)
            logits, cache = prefill(params, {"tokens": prompt}, cfg,
                                    max_len=pc.alloc, service=service)
            pc.admit(slot, cache, req["prompt_len"])
            first = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
            jax.block_until_ready(first)
            state[slot] = {"req": req, "tok": int(first[0]), "made": 1}
            tokens_out += 1
            ttft.append(clock() - req["arrival"])
            if state[slot]["made"] >= req["out_len"]:
                pc.release(slot)
                del state[slot]
        if not state:
            if pending:   # idle: fast-forward to the next arrival
                skipped += max(0.0, pending[0]["arrival"] - clock()) + 1e-9
            continue

        # one decode round: fixed membership, fixed bucket
        active = sorted(state)
        cur_stats = pc.stats()
        if cur_stats["tokens_resident"] > peak["tokens_resident"]:
            peak = cur_stats
        if paged:
            slots = _pad_batch(active, pc.free_slots(), max_batch)
            steps = min(round_cap,
                        min(state[s]["req"]["out_len"] - state[s]["made"]
                            for s in active))
            bucket = pc.seq_bucket(slots, extra=steps)
            view = pc.view(slots, bucket)
        else:
            slots = list(range(max_batch))
            steps = min(round_cap,
                        min(state[s]["req"]["out_len"] - state[s]["made"]
                            for s in active))
            bucket = pc.alloc
            view = pc.buf
        for _ in range(steps):
            cur = jnp.asarray([[state[s]["tok"] if s in state else 0]
                               for s in slots], jnp.int32)
            pos = jnp.asarray([int(pc.pos[s]) + 1 if s in state else 0
                               for s in slots], jnp.int32)
            ts = time.perf_counter()
            nxt, _, view = serve(params, view, cur, pos)
            jax.block_until_ready(nxt)
            dt = time.perf_counter() - ts
            pc.advance(active)
            tokens_out += len(active)
            token_lat.extend([dt] * len(active))
            for i, s in enumerate(slots):
                if s in state:
                    state[s]["tok"] = int(nxt[i, 0])
                    state[s]["made"] += 1
        if paged:
            pc.writeback(slots, bucket, view)
        else:
            pc.buf = view
        for s in list(active):
            if state[s]["made"] >= state[s]["req"]["out_len"]:
                pc.release(s)
                del state[s]

    wall = time.perf_counter() - t0
    lat = np.asarray(token_lat)
    out = {
        "mode": mode,
        "page_size": page_size if paged else None,
        "requests": len(workload),
        "tokens": tokens_out,
        "wall_sec": wall,
        "throughput_tok_s": tokens_out / wall if wall > 0 else None,
        "token_lat_p50_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else None,
        "token_lat_p99_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else None,
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3) if ttft else None,
    }
    out["kv_cache"] = peak
    if service is not None:
        service.attach_kv_cache(pc)
        tel = service.telemetry()
        out["dispatch"] = {k: tel[k] for k in
                           ("store_exact", "store_near", "store_default",
                            "exec_hit", "exec_miss", "build_failed",
                            "infeasible")}
    return out


# ---------------------------------------------------------------------------
# the inline decode-space campaign (mode "tuned")
# ---------------------------------------------------------------------------


def tune_decode(cfg, *, max_batch: int, resident: int, n_candidates: int,
                seed: int) -> tuple[dict, list]:
    """Short timing campaign over the decode space at the serving signature.
    Each candidate is wall-clocked at *its own* seq bucket —
    ``ceil(resident/page)*page`` — so the ``page`` layout axis's padded
    attention work is part of the measured objective, exactly the
    layout-belongs-in-the-space point the bench exists to demonstrate."""
    K, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    BH = max_batch * K
    cs = kernel_space("decode_attention", target="host", seed=seed)
    cands = [dict(cs.default_configuration())]
    while len(cands) < n_candidates:
        c = dict(cs.sample_configuration())
        if c not in cands:
            cands.append(c)
    trace, best, best_t = [], None, float("inf")
    for c in cands:
        page = int(c["page"])
        s_eff = -(-resident // page) * page   # the bucket this page serves
        if not check_config("decode_attention", c,
                            dims=(BH, G, s_eff, hd), target="host").ok:
            continue
        args = init_decode_attention(BH, G, s_eff, hd)
        t = time_callable(decode_attention_builder(c), args,
                          repeats=3, warmup=1)
        trace.append({"config": c, "seconds": t})
        if t < best_t:
            best, best_t = c, t
    return best, trace


def seed_store(store, cfg, best: dict, *, max_batch: int, max_resident: int,
               alloc: int) -> int:
    """Publish the tuned config for every signature the serving loop will
    derive: batch ladder x page-aligned seq buckets (plus the prefill
    replay's full-allocation bucket)."""
    K, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    page = int(best["page"])
    buckets = set(range(page, -(-max_resident // page) * page + 1, page))
    buckets.add(-(-alloc // page) * page)
    batches = {1}
    b = 1
    while b < max_batch:
        b = min(b * 2, max_batch)
        batches.add(b)
    n = 0
    for bsz in sorted(batches):
        for s in sorted(buckets):
            sig = decode_attention_signature(bsz * K, G, s, hd)
            if store.put(TuningRecord("decode_attention", sig, "host",
                                      dict(best), 1.0)):
                n += 1
    return n


# ---------------------------------------------------------------------------
# obs probe: real execute-latency samples for prefill + decode kernels
# ---------------------------------------------------------------------------


def probe_kernels(service, cfg, *, max_batch: int, bucket: int,
                  prompt_len: int, reps: int = 20) -> None:
    """Eager dispatch calls at the serving shapes so the obs snapshot's
    ``dispatch_execute_seconds`` histograms carry real per-call samples for
    both hot paths (in-model dispatches record at trace time only)."""
    K, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    BH = max_batch * K
    args = init_decode_attention(BH, G, bucket, hd)
    fn = service.dispatch("decode_attention", *args, ring=False, window=0)
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    fargs = init_flash_attention(BH, prompt_len, prompt_len, hd)
    fn = service.dispatch("flash_attention", *fargs, causal=True)
    for _ in range(reps):
        jax.block_until_ready(fn(*fargs))


# ---------------------------------------------------------------------------
# guard phase (--inject-drift): shadow overhead + the drift-heal loop
# ---------------------------------------------------------------------------


def guard_drift_phase(cfg, best: dict, *, store_root: str, max_batch: int,
                      bucket: int, quick: bool) -> dict:
    """Measure the guard's serving cost and prove the drift loop on the
    decode hot path.

    Shadow overhead is measured where it is actually paid: eager dispatch
    calls at the serving shape (in-model dispatches are jitted, so shadow
    sampling — like all per-call instrumentation — only sees the eager
    path). With ``epsilon=0.1`` nine of ten calls pay one counter check,
    so the *median* call is a non-shadow call and must stay within 2% of
    an unguarded service — the shadow cost lands in the tail by design.
    Guarded and unguarded calls are interleaved and the overhead gate uses
    min-of-N: on a shared box, scheduler noise dwarfs a ~1us deterministic
    cost at the median, and the minimum isolates exactly the per-call cost
    the 2% claim is about (p50s of both are still reported). Then
    ``dispatch.latency`` is injected and the watcher must quarantine the
    served record and degrade to the default config within two windows."""
    from repro.guard import (GuardAgent, ShadowPolicy, WatchPolicy,
                             guard_counters, inject)

    K, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    BH = max_batch * K
    args = init_decode_attention(BH, G, bucket, hd)
    sig = decode_attention_signature(BH, G, bucket, hd)
    reps = 100 if quick else 400
    epsilon = 0.1

    def serve(svc):
        fn = svc.dispatch("decode_attention", *args, ring=False, window=0)
        jax.block_until_ready(fn(*args))    # compile outside the timing
        return fn

    # -- unguarded reference ------------------------------------------------
    store_p = TuningStore(os.path.join(store_root, "guard_plain"))
    store_p.put(TuningRecord("decode_attention", sig, "host", dict(best), 1.0))
    fn_plain = serve(DispatchService(store_p, metrics=MetricsRegistry()))

    # -- guarded service: shadow epsilon + drift watch ----------------------
    store_g = TuningStore(os.path.join(store_root, "guard"))
    store_g.put(TuningRecord("decode_attention", sig, "host", dict(best), 1.0))
    svc = DispatchService(store_g, metrics=MetricsRegistry())
    guard = GuardAgent(
        svc,
        watch=WatchPolicy(drift_factor=3.0, hysteresis=2, cooldown_sec=0.0,
                          min_samples=8),
        shadow=ShadowPolicy(epsilon=epsilon, challenger_fraction=0.0))
    svc.attach_guard(guard)
    fn = serve(svc)

    t_plain, t_shadow = [], []
    for _ in range(reps):           # interleaved: box noise hits both alike
        t0 = time.perf_counter()
        jax.block_until_ready(fn_plain(*args))
        t_plain.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))    # shadow tells sharpen the seed
        t_shadow.append(time.perf_counter() - t0)
    p50_plain = float(np.percentile(t_plain, 50))
    p50_shadow = float(np.percentile(t_shadow, 50))
    overhead = min(t_shadow) / min(t_plain) - 1.0

    # -- injected latency regression: the watcher must heal it --------------
    guard.check_once()                       # window base
    delay = max(0.05, 10.0 * p50_plain)      # unambiguous drift
    with inject("dispatch.latency", delay_sec=delay,
                where={"kernel": "decode_attention"}):
        for _ in range(12):
            fn(*args)
        first = guard.check_once()           # breach 1 of 2: hysteresis
        for _ in range(12):
            fn(*args)
        decisions = guard.check_once()       # breach 2: quarantine
    drift_ok = (first == [] and len(decisions) == 1
                and decisions[0]["reason"].startswith("drift:"))
    # degraded serving: the quarantined record must not resolve again
    before = svc.stats["store_default"]
    serve(svc)
    fallback_ok = svc.stats["store_default"] == before + 1

    return {
        "epsilon": epsilon,
        "p50_plain_ms": p50_plain * 1e3,
        "p50_shadow_ms": p50_shadow * 1e3,
        "shadow_overhead_frac": overhead,
        "drift_ok": drift_ok,
        "fallback_ok": fallback_ok,
        "decisions": decisions,
        "shadow": guard.shadow.snapshot_stats(),
        "quarantines": guard.stats["quarantines"],
        "fallbacks": guard.stats["fallbacks"],
        "counters": guard_counters(svc.metrics.snapshot()),
    }


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--inject-drift", action="store_true",
                    help="also run the guard phase: shadow-eval overhead "
                         "and an injected-latency drift-heal scenario")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--candidates", type=int, default=None,
                    help="decode-space candidates for the tuned mode")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--store", default="results/serve_bench_store")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--obs-out", default="BENCH_serve.obs.jsonl")
    args = ap.parse_args(argv)

    quick = args.quick
    n_req = args.requests or (8 if quick else 24)
    max_len = args.max_len or (256 if quick else 1024)
    rate = args.rate or (50.0 if quick else 12.0)
    n_cand = args.candidates or (6 if quick else 12)
    prompt_lens = (8, 16) if quick else (16, 32, 48)
    out_mean, out_cap = (8, 12) if quick else (24, 48)

    cfg = dataclasses.replace(get_reduced("qwen2-0.5b"), dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    workload = make_workload(n_req, rate, prompt_lens, out_mean, out_cap,
                             args.seed)
    max_resident = max(r["prompt_len"] + r["out_len"] for r in workload)
    resident_typ = int(np.median(
        [r["prompt_len"] + r["out_len"] // 2 for r in workload]))

    print(f"# serve_bench: {n_req} requests, max_batch={args.max_batch}, "
          f"max_len={max_len}, rate={rate}/s, max_resident={max_resident}")

    results: dict[str, dict] = {}

    # -- einsum reference: dense full-allocation cache, no dispatch ----------
    results["einsum"] = run_mode(
        "einsum", cfg, params, workload, max_batch=args.max_batch,
        max_len=max_len, page_size=max_len, service=None)
    print(f"einsum : p50={results['einsum']['token_lat_p50_ms']:.3f}ms "
          f"p99={results['einsum']['token_lat_p99_ms']:.3f}ms "
          f"tput={results['einsum']['throughput_tok_s']:.1f} tok/s")

    # -- default: paged cache + empty store (space-default decode config) ----
    default_page = int(kernel_space("decode_attention",
                                    target="host").default_configuration()["page"])
    svc = DispatchService(TuningStore(os.path.join(args.store, "default")),
                          metrics=MetricsRegistry())
    results["default"] = run_mode(
        "default", cfg, params, workload, max_batch=args.max_batch,
        max_len=max_len, page_size=default_page, service=svc)
    print(f"default: p50={results['default']['token_lat_p50_ms']:.3f}ms "
          f"p99={results['default']['token_lat_p99_ms']:.3f}ms "
          f"tput={results['default']['throughput_tok_s']:.1f} tok/s "
          f"(page={default_page})")

    # -- tuned: inline campaign over impl/bk/hg/page, store-seeded -----------
    best, trace = tune_decode(cfg, max_batch=args.max_batch,
                              resident=resident_typ, n_candidates=n_cand,
                              seed=args.seed)
    store = TuningStore(os.path.join(args.store, "tuned"))
    n_rec = seed_store(store, cfg, best, max_batch=args.max_batch,
                       max_resident=max_resident, alloc=max_len)
    print(f"tuned config {best} ({n_rec} store records)")
    svc_t = DispatchService(store, metrics=MetricsRegistry())
    results["tuned"] = run_mode(
        "tuned", cfg, params, workload, max_batch=args.max_batch,
        max_len=max_len, page_size=int(best["page"]), service=svc_t)
    results["tuned"]["decode_config"] = best
    results["tuned"]["campaign"] = trace
    print(f"tuned  : p50={results['tuned']['token_lat_p50_ms']:.3f}ms "
          f"p99={results['tuned']['token_lat_p99_ms']:.3f}ms "
          f"tput={results['tuned']['throughput_tok_s']:.1f} tok/s "
          f"(page={best['page']})")

    # resolved-vs-default sanity: the tuned run must actually have served
    # store-resolved configs, not degraded to defaults
    disp = results["tuned"]["dispatch"]
    assert disp["store_exact"] >= 1, "tuned store records did not resolve"
    assert disp["build_failed"] == 0, "tuned config failed to build"

    # obs snapshot with real per-call samples for both hot-path kernels
    probe_kernels(svc_t, cfg, max_batch=args.max_batch,
                  bucket=min(-(-resident_typ // int(best["page"]))
                             * int(best["page"]), max_len),
                  prompt_len=max(prompt_lens))
    write_snapshot(args.obs_out, registry=svc_t.metrics, bench="serve",
                   mode="tuned")

    guard_payload = None
    if args.inject_drift:
        bucket = min(-(-resident_typ // int(best["page"])) * int(best["page"]),
                     max_len)
        print("# guard phase: shadow overhead + injected-drift heal loop")
        guard_payload = guard_drift_phase(
            cfg, best, store_root=args.store, max_batch=args.max_batch,
            bucket=bucket, quick=quick)
        print(f"guard  : shadow p50 {guard_payload['p50_shadow_ms']:.3f}ms vs "
              f"plain {guard_payload['p50_plain_ms']:.3f}ms "
              f"({guard_payload['shadow_overhead_frac']:+.2%}), "
              f"{guard_payload['shadow']['shadow_evals']} shadow evals, "
              f"{guard_payload['quarantines']} quarantine(s)")

    payload = {
        "workload": {
            "requests": n_req, "rate_req_s": rate,
            "prompt_lens": list(prompt_lens), "out_mean": out_mean,
            "out_cap": out_cap, "max_batch": args.max_batch,
            "max_len": max_len, "seed": args.seed,
            "arch": cfg.name, "reduced": True,
        },
        "modes": results,
        "speedup_p50_tuned_vs_einsum":
            results["einsum"]["token_lat_p50_ms"]
            / results["tuned"]["token_lat_p50_ms"],
        "speedup_p50_tuned_vs_default":
            results["default"]["token_lat_p50_ms"]
            / results["tuned"]["token_lat_p50_ms"],
    }
    if guard_payload is not None:
        payload["guard"] = guard_payload
    write_bench_json(args.out, payload)
    print(f"wrote {args.out} and {args.obs_out}")
    print(f"speedup p50 tuned vs einsum : "
          f"{payload['speedup_p50_tuned_vs_einsum']:.2f}x")
    print(f"speedup p50 tuned vs default: "
          f"{payload['speedup_p50_tuned_vs_default']:.2f}x")

    # guard tripwires: shadow epsilon must be ~free at the median, and the
    # injected regression must have been quarantined with fallback
    if guard_payload is not None:
        limit = 0.25 if quick else 0.02   # quick runs are too short to bound
        if guard_payload["shadow_overhead_frac"] > limit:
            print(f"FAIL: shadow epsilon costs "
                  f"{guard_payload['shadow_overhead_frac']:.1%} p50 "
                  f"(limit {limit:.0%})")
            return 1
        if not (guard_payload["drift_ok"] and guard_payload["fallback_ok"]):
            print(f"FAIL: drift-heal loop incomplete: {guard_payload}")
            return 1

    # tripwire: p99 must exist, be finite, and be non-degenerate
    for mode, r in results.items():
        p99 = r["token_lat_p99_ms"]
        if p99 is None or not np.isfinite(p99) or p99 <= 0.0:
            print(f"FAIL: degenerate p99 for mode {mode}: {p99}")
            return 1
        if r["token_lat_p50_ms"] > p99:
            print(f"FAIL: p50 > p99 for mode {mode}")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
