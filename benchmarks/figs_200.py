"""The paper's Figs 3-6 at full budget: 200-evaluation campaigns on syr2k
under each of the four learners, with best-so-far trajectories (the red line
in the paper's figures) exported to results/fig_syr2k_<learner>.csv.

This is where the GP duplicate-skip phenomenon shows at the paper's own
scale: GP consumes budget on repeat proposals and completes fewer real
evaluations than RF/ET/GBRT (the paper saw 66/200).

    PYTHONPATH=src:. python -m benchmarks.figs_200 [--evals 200]
"""

from __future__ import annotations

import argparse
import csv
import json
import os

from repro.core import TimingEvaluator, compare_learners
from repro.kernels import ref as R
from repro.kernels import variants as V
from repro.kernels.spaces import kernel_space


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evals", type=int, default=200)
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--m", type=int, default=160)
    ap.add_argument("--outdir", default="results")
    args = ap.parse_args()

    problem = R.init_syr2k(args.n, args.m)
    factory = V.syr2k_host(problem)
    ev = TimingEvaluator(factory, repeats=2, warmup=1)
    results = compare_learners(
        kernel_space("syr2k", target="host"), ev, max_evals=args.evals,
        seed=1234)

    os.makedirs(args.outdir, exist_ok=True)
    summary = {}
    for learner, res in results.items():
        traj = res.db.best_trajectory()
        path = os.path.join(args.outdir, f"fig_syr2k_{learner}.csv")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["evaluation", "objective_sec", "best_so_far_sec",
                        "status"])
            for rec, best in zip(res.db.records, traj):
                w.writerow([rec.index, rec.objective, best, rec.status])
        b = res.best
        summary[learner] = {
            "best_sec": b.objective, "found_at_eval": b.index,
            "real_evaluations": res.n_evaluated,
            "skipped_duplicates": res.n_skipped,
            "budget": args.evals, "config": b.config,
        }
        print(f"[{learner:4s}] best={b.objective*1e6:9.1f}us @eval {b.index:3d}  "
              f"real_evals={res.n_evaluated:3d}/{args.evals}  "
              f"skipped_dups={res.n_skipped}")
    with open(os.path.join(args.outdir, "fig_syr2k_summary.json"), "w") as f:
        json.dump(summary, f, indent=1)


if __name__ == "__main__":
    main()
