"""Beyond-paper §Perf move: replace the XLA chunked-attention path with the
Pallas flash kernel (kernels/flash_attention.py) and recompute the cell's
roofline memory term.

Method (no TPU, so structural):
  1. lower + walk the *standalone* attention forward and forward+backward at
     the cell's per-device/per-microbatch geometry -> measured HBM bytes of
     the materializing path, per layer per microbatch (A_fwd, A_fwdbwd);
  2. flash traffic for the same geometry is analytic (q/k/v/o streams; the
     backward re-streams k/v and writes dq/dk/dv: ~4x the forward traffic,
     still O(S));
  3. adjusted memory term = baseline - L * accum * (A_xla - A_flash) / HBM_bw.

The flash kernel itself is validated against the oracle in
tests/test_kernels.py; this file only does the accounting.

    PYTHONPATH=src:. python -m benchmarks.flash_adjust --arch qwen2-vl-7b
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels.flash_attention import flash_hbm_bytes
from repro.models.attention import gqa_attention
from repro.perf.hlo_cost import module_cost
from repro.perf.roofline import HW


def attention_traffic(B, S, H, K, hd, chunk=512):
    """Walker-measured HBM bytes of the XLA chunked attention, fwd and
    fwd+bwd, at the given per-device geometry."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.ShapeDtypeStruct((B, S, H, hd), jnp.bfloat16)
    k = jax.ShapeDtypeStruct((B, S, K, hd), jnp.bfloat16)
    v = jax.ShapeDtypeStruct((B, S, K, hd), jnp.bfloat16)

    def fwd(q, k, v):
        return gqa_attention(q, k, v, causal=True, chunk=chunk).sum()

    c_fwd = jax.jit(fwd).lower(q, k, v).compile()
    a_fwd = module_cost(c_fwd.as_text()).bytes

    grad = jax.grad(fwd, argnums=(0, 1, 2))
    c_bwd = jax.jit(grad).lower(q, k, v).compile()
    a_fwdbwd = module_cost(c_bwd.as_text()).bytes
    return a_fwd, a_fwdbwd


def adjust(arch: str, baseline_mem_sec: float, baseline_compute_sec: float,
           baseline_coll_sec: float, accum: int, mesh_model: int = 16,
           mesh_data: int = 16, global_batch: int = 256, S: int = 4096):
    cfg = get_config(arch)
    # per-device, per-microbatch geometry (heads over model, batch over data)
    B_micro = max(global_batch // mesh_data // accum, 1)
    H_loc = max(cfg.n_heads // mesh_model, 1)
    K_loc = max(cfg.n_kv_heads // mesh_model, 1)
    hd = cfg.hd

    a_fwd, a_fwdbwd = attention_traffic(B_micro, S, H_loc, K_loc, hd)
    # remat=full replays the forward once during the backward pass
    a_xla_layer = a_fwdbwd + a_fwd

    f_fwd = flash_hbm_bytes(B_micro, H_loc, K_loc, S, S, hd, dtype_bytes=2)
    f_layer = 4.0 * f_fwd  # fwd + bwd(re-stream k/v, write dq/dk/dv)

    L = cfg.n_layers
    saved = L * accum * (a_xla_layer - f_layer)
    adj_mem = baseline_mem_sec - saved / HW.hbm_bw
    before_bound = max(baseline_mem_sec, baseline_compute_sec, baseline_coll_sec)
    after_bound = max(adj_mem, baseline_compute_sec, baseline_coll_sec)
    return {
        "arch": arch,
        "attention_xla_bytes_per_layer_micro": a_xla_layer,
        "attention_flash_bytes_per_layer_micro": f_layer,
        "traffic_ratio": a_xla_layer / max(f_layer, 1),
        "memory_sec_before": baseline_mem_sec,
        "memory_sec_after": adj_mem,
        "bound_before": before_bound,
        "bound_after": after_bound,
        "speedup": before_bound / max(after_bound, 1e-12),
        "roofline_fraction_after": baseline_compute_sec / max(after_bound, 1e-12),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-vl-7b")
    ap.add_argument("--hillclimb-json", default=None)
    args = ap.parse_args()

    hc = args.hillclimb_json or f"results/hillclimb_{args.arch}_train_4k.json"
    with open(hc) as f:
        d = json.load(f)
    base = d["baseline"]
    accum = int(base["config"].get("accum", 8))
    out = adjust(args.arch, base["memory_sec"], base["compute_sec"],
                 base["collective_sec"], accum)
    print(json.dumps(out, indent=2))
    with open(f"results/flash_adjust_{args.arch}.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
