"""TPU-target Pallas schedule tuning (backend B2): autotune each kernel's
BlockSpec geometry against the analytic v5e cost model, at the paper's
LARGE dataset sizes. The chosen config is then validated for correctness in
interpret mode at reduced size — schedule legality is by construction, so
the reduced-size check is a full proxy.

Rows report modeled microseconds on TPU v5e for (default MXU tiles) vs
(autotuned), plus the modeled roofline utilization of the tuned schedule.

Campaign results route through ``repro.dispatch``: pass a
:class:`~repro.dispatch.TuningStore` (or a path) to :func:`tune_all` and each
kernel's campaign (a) warm-starts from the store's nearest tuned record and
(b) publishes its winner back, so successive benchmark runs converge in a
fraction of the evaluation budget and serving picks the configs up for free.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import EVALS
from repro.core import EvalResult, autotune
from repro.dispatch import TuningRecord, TuningStore, resolve
from repro.kernels.cost import kernel_cost
from repro.kernels.spaces import kernel_space
from repro.perf.roofline import HW

# the paper's LARGE dataset sizes per kernel; the model kernels (serving hot
# path) use a 16-head 4k-context serving shape as their "LARGE" analog
LARGE_SHAPES = {
    "syr2k": (1200, 1000),
    "mm3": (800, 900, 1000, 1100, 1200),
    "lu": (2000,),
    "heat3d": (120, 500),
    "covariance": (1400, 1200),
    "floyd_warshall": (2800,),
    "flash_attention": (16, 4096, 4096, 128),
    "matmul": (2000, 2300, 2600),
}

DEFAULTS_TPU = {
    "syr2k": dict(bi=128, bj=128, bk=128),
    "mm3": dict(bm=128, bn=128, bk=128),
    "lu": dict(bs=32, bm=128, bn=128),
    "heat3d": dict(bi=8, fuse_t=1),
    "covariance": dict(bi=128, bj=128, bk=256),
    "floyd_warshall": dict(bs=64, bi=128, bj=128, unroll=1),
    "flash_attention": dict(impl="pallas", bq=128, bk=128),
    "matmul": dict(bm=128, bn=128, bk=128, pack=True),
}


def make_evaluator(name: str):
    shape = LARGE_SHAPES[name]

    def ev(cfg) -> EvalResult:
        t, info = kernel_cost(name, cfg, *shape)
        if not np.isfinite(t):
            return EvalResult(1e9, False, info)
        return EvalResult(t, True, info)

    return ev


def _signature(name: str):
    # per-argument scheme shared with repro.dispatch (see kernels.ref)
    from repro.kernels.ref import problem_signature
    return problem_signature(name, *LARGE_SHAPES[name])


def tune_all(max_evals: int | None = None, store: TuningStore | str | None = None):
    if isinstance(store, str):
        store = TuningStore(store)
    rows = []
    for name in LARGE_SHAPES:
        ev = make_evaluator(name)
        base_t, base_info = kernel_cost(name, DEFAULTS_TPU[name], *LARGE_SHAPES[name])
        warm_cfgs, warm_recs = None, None
        if store is not None:
            r = resolve(store, name, _signature(name), backend="cost")
            if r is not None:
                warm_cfgs = [dict(r.config)]
                warm_recs = [(dict(r.config), r.record.objective)]
        res = autotune(kernel_space(name, target="tpu"), ev,
                       max_evals=max_evals or max(EVALS, 40), learner="RF",
                       seed=1234, warm_start=warm_cfgs,
                       warm_start_records=warm_recs)
        b = res.best
        if store is not None and b is not None:
            store.put(TuningRecord(
                kernel=name, signature=_signature(name), backend="cost",
                config=dict(b.config), objective=float(b.objective),
                n_evals=len(res.db), source="benchmark:pallas_tuning"))
        flops = b.info.get("flops", 0.0)
        util = flops / (b.objective * HW.peak_flops) if b.objective > 0 else 0.0
        rows.append((f"pallas_tpu/{name}/default", base_t * 1e6,
                     f"config={DEFAULTS_TPU[name]}"))
        rows.append((f"pallas_tpu/{name}/autotuned", b.objective * 1e6,
                     f"at_eval={b.index};mxu_util={util:.2f};config={b.config}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(tune_all())
