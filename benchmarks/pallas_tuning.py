"""TPU-target Pallas schedule tuning (backend B2): autotune each kernel's
BlockSpec geometry against the analytic v5e cost model, at the paper's
LARGE dataset sizes. The chosen config is then validated for correctness in
interpret mode at reduced size — schedule legality is by construction, so
the reduced-size check is a full proxy.

Rows report modeled microseconds on TPU v5e for (default MXU tiles) vs
(autotuned), plus the modeled roofline utilization of the tuned schedule.

Shape tables live in :mod:`repro.kernels.problems` (shared with the autotune
CLI and the cost-backend background tuner). Campaign results route through
``repro.dispatch``: pass a :class:`~repro.dispatch.TuningStore` (or a path)
to :func:`tune_all` and each kernel's campaign (a) warm-starts from the
store's nearest tuned records and (b) publishes its winner back, so
successive benchmark runs converge in a fraction of the evaluation budget
and serving picks the configs up for free.
"""

from __future__ import annotations

from benchmarks.common import EVALS
from repro.core import autotune
from repro.dispatch import TuningRecord, TuningStore
from repro.dispatch.lookup import warm_start_material
from repro.kernels.cost import kernel_cost
from repro.kernels.problems import (
    DEFAULTS_TPU,
    LARGE_SHAPES,
    make_cost_evaluator,
    problem_signature_for,
)
from repro.kernels.spaces import kernel_space
from repro.perf.roofline import HW

# back-compat alias: this module's historical evaluator-factory name
make_evaluator = make_cost_evaluator


def _signature(name: str):
    return problem_signature_for(name, backend="cost")


def tune_all(max_evals: int | None = None, store: TuningStore | str | None = None,
             parallel: int = 1):
    if isinstance(store, str):
        store = TuningStore(store)
    rows = []
    for name in LARGE_SHAPES:
        ev = make_cost_evaluator(name)
        base_t, base_info = kernel_cost(name, DEFAULTS_TPU[name], *LARGE_SHAPES[name])
        warm_cfgs, warm_recs = None, None
        if store is not None:
            warm_cfgs, warm_recs = warm_start_material(
                store, name, _signature(name), backend="cost")
        res = autotune(kernel_space(name, target="tpu"), ev,
                       max_evals=max_evals or max(EVALS, 40), learner="RF",
                       seed=1234, parallel=parallel, warm_start=warm_cfgs,
                       warm_start_records=warm_recs)
        b = res.best
        if store is not None and b is not None:
            store.put(TuningRecord(
                kernel=name, signature=_signature(name), backend="cost",
                config=dict(b.config), objective=float(b.objective),
                n_evals=len(res.db), source="benchmark:pallas_tuning"))
        flops = b.info.get("flops", 0.0)
        util = flops / (b.objective * HW.peak_flops) if b.objective > 0 else 0.0
        rows.append((f"pallas_tpu/{name}/default", base_t * 1e6,
                     f"config={DEFAULTS_TPU[name]}"))
        rows.append((f"pallas_tpu/{name}/autotuned", b.objective * 1e6,
                     f"at_eval={b.index};mxu_util={util:.2f};config={b.config}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(tune_all())
