"""Gradient-compression roofline measurement: lower a shard_map data-parallel
training step with f32 / bf16 / int8 gradient all-reduce payloads and walk
the compiled HLO — the wire-format bytes must shrink 1x / 2x / 4x, which is
the cross-pod collective-term lever the §Perf narrative banks for
collective-bound cells.

Error-feedback correctness of the compressed path is covered by
tests/test_ckpt_ft.py; this file quantifies the traffic.

    PYTHONPATH=src:. python -m benchmarks.compression_bench
"""

from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402
import functools    # noqa: E402
import json         # noqa: E402

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402


def build_step(cfg, mode: str, mesh):
    """Pure-DP step via shard_map: replicated params, sharded batch, explicit
    gradient all-reduce whose payload dtype is the knob."""
    from jax import shard_map

    from repro.ft.compression import compressed_psum
    from repro.models.model import loss_fn

    def per_shard(params, batch):
        (total, _), grads = jax.value_and_grad(
            functools.partial(loss_fn, cfg=cfg), has_aux=True)(params, batch)

        def reduce_leaf(g):
            g32 = g.astype(jnp.float32)
            if mode == "f32":
                return jax.lax.psum(g32, "data")
            if mode == "bf16":
                return jax.lax.psum(g32.astype(jnp.bfloat16), "data").astype(jnp.float32)
            return compressed_psum(g32, "data")  # int8 + max-scale combine

        grads = jax.tree_util.tree_map(reduce_leaf, grads)
        return jax.lax.pmean(total, "data"), grads

    return shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(), {"tokens": P("data", None), "labels": P("data", None)}),
        out_specs=(P(), P()),
    )


def main():
    from repro.configs import get_reduced
    from repro.models.model import init_params
    from repro.perf.hlo_cost import module_cost

    mesh = jax.make_mesh((8,), ("data",))
    cfg = dataclasses.replace(get_reduced("qwen1.5-0.5b"), dtype=jnp.float32)
    params = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    batch = {
        "tokens": jax.ShapeDtypeStruct((16, 64), jnp.int32),
        "labels": jax.ShapeDtypeStruct((16, 64), jnp.int32),
    }
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))

    rows = {}
    for mode in ("f32", "bf16", "int8"):
        step = build_step(cfg, mode, mesh)
        with mesh:
            compiled = jax.jit(step).lower(params, batch).compile()
        cost = module_cost(compiled.as_text())
        ar = cost.coll_by_kind.get("all-reduce", 0.0)
        rows[mode] = {"all_reduce_bytes": ar,
                      "bytes_per_param": ar / n_params,
                      "total_collective_bytes": cost.collective_bytes}
        print(f"{mode:5s} all-reduce payload: {ar/1e6:8.2f} MB "
              f"({ar/n_params:5.2f} B/param)")

    r = rows
    print(f"bf16 saves {1 - r['bf16']['all_reduce_bytes']/r['f32']['all_reduce_bytes']:.0%}, "
          f"int8 saves {1 - r['int8']['all_reduce_bytes']/r['f32']['all_reduce_bytes']:.0%} "
          f"of gradient all-reduce traffic")
    os.makedirs("results", exist_ok=True)
    with open("results/compression_bench.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
