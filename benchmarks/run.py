"""Benchmark harness entry point — one function per paper table.

Prints ``name,us_per_call,derived`` CSV. Knobs (env):
  REPRO_BENCH_EVALS    autotuning campaign length (default 30; paper: 200)
  REPRO_BENCH_SCALE    small | large dataset sizes
  REPRO_BENCH_LEARNER  surrogate for the per-table campaigns (default RF)
  REPRO_BENCH_ONLY     comma-separated table substring filter
"""

from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    from benchmarks.common import emit
    from benchmarks.learners import learner_comparison
    from benchmarks.roofline_table import csv_rows
    from benchmarks.tables import ALL_TABLES

    only = [s for s in os.environ.get("REPRO_BENCH_ONLY", "").split(",") if s]

    def wanted(name: str) -> bool:
        return not only or any(o in name for o in only)

    t_start = time.time()
    for table_fn in ALL_TABLES:
        if not wanted(table_fn.__name__):
            continue
        t0 = time.time()
        try:
            rows = table_fn()
            emit(rows)
            print(f"# {table_fn.__name__} took {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001 — one broken table must not kill the run
            print(f"{table_fn.__name__}/ERROR,0,{traceback.format_exc(limit=2)!r}")

    if wanted("pallas"):
        try:
            from benchmarks.pallas_tuning import tune_all
            emit(tune_all())
        except Exception:  # noqa: BLE001
            print(f"pallas_tuning/ERROR,0,{traceback.format_exc(limit=2)!r}")

    if wanted("learners"):
        try:
            emit(learner_comparison())
        except Exception:  # noqa: BLE001
            print(f"learners/ERROR,0,{traceback.format_exc(limit=2)!r}")

    if wanted("roofline"):
        emit(csv_rows())

    print(f"# total {time.time()-t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
