"""Roofline table generator: results/dryrun.json -> CSV rows and the
EXPERIMENTS.md §Roofline markdown table."""

from __future__ import annotations

import json
import os

DRYRUN_JSON = os.environ.get("REPRO_DRYRUN_JSON", "results/dryrun.json")


def load(path: str = DRYRUN_JSON):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def csv_rows(path: str = DRYRUN_JSON, mesh: str = "16x16"):
    rows = []
    for r in load(path):
        if r.get("mesh") != mesh:
            continue
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            rows.append((name, 0.0, r.get("reason", r.get("error", r["status"]))))
            continue
        rf = r["roofline"]
        rows.append((
            name,
            rf["bound_sec"] * 1e6,
            f"dominant={rf['dominant']};frac={rf['roofline_fraction']:.3f};"
            f"useful={rf['useful_flops_ratio'] and round(rf['useful_flops_ratio'], 3)}",
        ))
    return rows


def markdown(path: str = DRYRUN_JSON, mesh: str = "16x16") -> str:
    out = [
        f"| arch | shape | compute s | memory s | collective s | dominant | "
        f"roofline frac | 6ND/HLO | bytes/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(path):
        if r.get("mesh") != mesh:
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"{r.get('reason', r.get('error', r['status']))[:60]} |")
            continue
        rf = r["roofline"]
        u = rf["useful_flops_ratio"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_sec']:.4g} | "
            f"{rf['memory_sec']:.4g} | {rf['collective_sec']:.4g} | "
            f"{rf['dominant']} | {rf['roofline_fraction']:.3f} | "
            f"{u and round(u, 3)} | {rf['bytes_per_device']:.3g} | |")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(markdown(mesh=mesh))
