"""Per-paper-table benchmarks (Tables 1-7): one entry per PolyBench kernel.

Sizes are host-scaled (the paper's i7 measured seconds; this container
measures milliseconds at reduced N — the *orderings* are the claims under
test; see EXPERIMENTS.md)."""

from __future__ import annotations

import functools

import jax.numpy as jnp

from benchmarks.common import SCALE, run_table
from repro.kernels import ref as R
from repro.kernels import variants as V
from repro.kernels.spaces import kernel_space


def _sizes(small, large):
    return large if SCALE == "large" else small


# paper row-4 defaults: tiling (96, 2048, 256) + interchange + packing
_PAPER = dict(bi=96, bk=2048, bj=256, bm=96, bn=256, interchange=True)
# row-3 "compiler heuristic" defaults: 128-cubed
_HEUR = dict(bi=128, bk=128, bj=128, bm=128, bn=128, interchange=False)


def table1_syr2k():
    N, M = _sizes((240, 200), (600, 500))
    C, A, B = R.init_syr2k(N, M)
    naive = V.naive_fns()["syr2k"]
    factory = V.syr2k_host((C, A, B))
    want = R.syr2k_ref(C, A, B)
    return run_table(
        "table1_syr2k",
        naive, R.syr2k_ref, (C, A, B),
        factory, kernel_space("syr2k", target="host"),
        heur_config=dict(_HEUR, pack_a=False, pack_b=False),
        paper_config=dict(_PAPER, pack_a=True, pack_b=True),
        check_against=want,
    )


def table2_mm3():
    P, Q, Rr, S, T = _sizes((200, 180, 160, 150, 170), (480, 420, 400, 380, 440))
    A, B, C, D = R.init_mm3(P, Q, Rr, S, T)
    naive = V.naive_fns()["mm3"]
    factory = V.mm3_host((A, B, C, D))
    want = R.mm3_ref(A, B, C, D)
    return run_table(
        "table2_3mm",
        naive, R.mm3_ref, (A, B, C, D),
        factory, kernel_space("mm3", target="host"),
        heur_config=dict(bm=128, bn=128, bk=128),
        paper_config=dict(bm=96, bn=256, bk=2048, pack1=True, pack2=True, pack3=True),
        check_against=want,
    )


def table3_lu():
    (N,) = _sizes((256,), (512,))
    (A,) = R.init_lu(N)
    factory = V.lu_host((A,))
    want = R.lu_ref(A)
    return run_table(
        "table3_lu",
        R.lu_ref, R.lu_ref, (A,),
        factory, kernel_space("lu", target="host"),
        heur_config=dict(bs=32),
        paper_config=dict(bs=64, bm=96, bn=256),
        check_against=want,
    )


def table4_heat3d():
    N, T = _sizes((40, 8), (80, 20))
    (A,) = R.init_heat3d(N)
    factory = V.heat3d_host((A,), tsteps=T)
    ref_fn = functools.partial(R.heat3d_ref, tsteps=T)
    want = R.heat3d_ref(A, T)
    return run_table(
        "table4_heat3d",
        ref_fn, ref_fn, (A,),
        factory, kernel_space("heat3d", target="host"),
        heur_config=dict(bi=8, fuse_t=1),
        paper_config=dict(bi=16, fuse_t=1),
        check_against=want,
    )


def table5_covariance():
    N, M = _sizes((300, 240), (700, 600))
    (data,) = R.init_covariance(N, M)
    naive = V.naive_fns()["covariance"]
    factory = V.covariance_host((data,))
    want = R.covariance_ref(data)
    return run_table(
        "table5_covariance",
        naive, R.covariance_ref, (data,),
        factory, kernel_space("covariance", target="host"),
        heur_config=dict(bi=128, bj=128, bk=128),
        paper_config=dict(bi=96, bj=256, bk=2048, interchange=True),
        check_against=want,
    )


def table67_floyd_warshall():
    """Tables 6+7: the heuristic-regression case. Row 'blocked_heur' with
    deliberately tiny tiles is the Polly-regression analog (slower than the
    naive k-loop); the autotuner recovers (Table 7's story)."""
    (N,) = _sizes((240,), (500,))
    (W,) = R.init_floyd_warshall(N)
    factory = V.floyd_warshall_host((W,))
    want = R.floyd_warshall_ref(W)
    return run_table(
        "table67_floyd_warshall",
        R.floyd_warshall_ref, R.floyd_warshall_ref, (W,),
        factory, kernel_space("floyd_warshall", target="host"),
        heur_config=dict(bs=4, bi=8, bj=8, unroll=1),   # regression analog
        paper_config=dict(bs=100, bi=16, bj=8, unroll=1),  # paper best (100,16,8)
        check_against=want,
    )


ALL_TABLES = [
    table1_syr2k, table2_mm3, table3_lu, table4_heat3d, table5_covariance,
    table67_floyd_warshall,
]
