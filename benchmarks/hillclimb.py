"""§Perf hillclimb: drive the dominant roofline term down on chosen cells by
autotuning the distributed-config knob space with the paper's BO engine
(backend B2 objective = compiled-artifact roofline bound, with an HBM-
feasibility penalty).

This is the paper's method applied one level up — the "application/system
parameters" extension its Sec. 5 proposes as future work. Each evaluation is
a full .lower().compile() of the cell on the production mesh + the HLO-walker
roofline; the performance database is the iteration log EXPERIMENTS.md §Perf
reports.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
  PYTHONPATH=src:. python -m benchmarks.hillclimb --arch qwen2-vl-7b \
      --shape train_4k --evals 12
"""

from __future__ import annotations

import os

# must precede any jax import (jax locks device count at first init)
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json      # noqa: E402

HBM_BYTES = 16e9  # v5e per-chip HBM


def knob_space(kind: str, is_moe: bool, seed: int = 1234):
    from repro.core.space import Categorical, ConfigurationSpace, Ordinal

    cs = ConfigurationSpace(seed=seed)
    if kind == "train":
        cs.add_hyperparameters([
            Ordinal("accum", (1, 2, 4, 8, 16), default=8),
            Categorical("remat", ("none", "dots", "full"), default="full"),
            Ordinal("attn_chunk", (256, 512, 1024, 2048), default=512),
            Categorical("attn_f32", (True, False), default=True),
            Categorical("moment_dtype", ("float32", "bfloat16"),
                        default="float32"),
            Categorical("seq_parallel", (False, True), default=False),
        ])
    else:
        cs.add_hyperparameters([
            Ordinal("attn_chunk", (256, 512, 1024, 2048), default=512),
            Categorical("attn_f32", (True, False), default=True),
            Categorical("mla_absorb", (True, False), default=True),
        ])
    if is_moe:
        cs.add_hyperparameters([
            Ordinal("moe_group", (512, 1024, 2048, 4096, 8192), default=2048),
            Ordinal("capacity_factor", (1.0, 1.25, 1.5, 2.0), default=1.25),
        ])
    return cs


def config_to_knobs(config: dict) -> dict:
    knobs: dict = {}
    overrides: dict = {}
    for k, v in config.items():
        if k in ("attn_f32", "moe_group", "capacity_factor"):
            overrides[k] = v
        elif k == "accum":
            knobs["accum"] = int(v)
        elif k == "attn_chunk":
            knobs["attn_chunk"] = int(v)
        else:
            knobs[k] = v
    if overrides:
        knobs["cfg_overrides"] = overrides
    return knobs


def make_cell_evaluator(arch: str, shape: str, mesh, log: list):
    import jax
    from repro.core.plopper import EvalResult
    from repro.launch.cells import lower_cell, plan_cell
    from repro.perf.roofline import analyze_compiled

    def evaluate(config) -> EvalResult:
        try:
            knobs = config_to_knobs(dict(config))
            plan = plan_cell(arch, shape, mesh, knobs)
            lowered, aux = lower_cell(plan, mesh)
            compiled = lowered.compile()
            rep = analyze_compiled(compiled, chips=plan.chips,
                                   model_flops=aux["model_flops"])
            mem = compiled.memory_analysis()
            dev_bytes = (getattr(mem, "temp_size_in_bytes", 0)
                         + getattr(mem, "argument_size_in_bytes", 0)
                         - getattr(mem, "alias_size_in_bytes", 0))
            obj = rep.bound_sec
            feasible = dev_bytes <= HBM_BYTES
            if not feasible:  # quadratic pressure penalty: OOM-compile analog
                obj = obj * (dev_bytes / HBM_BYTES) ** 2
            row = dict(config=dict(config), objective=obj, feasible=feasible,
                       device_bytes=dev_bytes, **rep.row())
            log.append(row)
            return EvalResult(obj, True, row)
        except Exception as e:  # noqa: BLE001
            log.append(dict(config=dict(config), error=str(e)))
            return EvalResult(1e9, False, {"error": str(e)})

    return evaluate


PROBES = [
    # hypothesis ladder: each row is one lower+compile (see EXPERIMENTS §Perf)
    ("baseline", {}),
    ("seq-parallel residual (activation mem & traffic / model-axis)",
     {"seq_parallel": True}),
    ("seq-parallel + bf16 moments (halve optimizer HBM)",
     {"seq_parallel": True, "moment_dtype": "bfloat16"}),
    ("seq-parallel + bf16 moments + accum 4 (fewer grad passes)",
     {"seq_parallel": True, "moment_dtype": "bfloat16", "accum": 4}),
    ("+ bf16 attention scores",
     {"seq_parallel": True, "moment_dtype": "bfloat16", "attn_f32": False}),
    ("+ tight MoE dispatch (group 1024, cf 1.0)",
     {"seq_parallel": True, "moment_dtype": "bfloat16",
      "moe_group": 1024, "capacity_factor": 1.0}),
]


def run_probe(arch: str, shape: str, out: str, multi_pod: bool = False):
    """Hypothesis -> change -> re-lower -> record, one compile per row."""
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    log: list = []
    ev = make_cell_evaluator(arch, shape, mesh, log)
    space = knob_space("train", is_moe=cfg.n_experts > 0)
    default = space.default_configuration()

    rows = []
    for label, delta in PROBES:
        if ("moe_group" in delta or "capacity_factor" in delta) and not cfg.n_experts:
            continue
        config = dict(default)
        config.update({k: v for k, v in delta.items() if k in default})
        res = ev(config)
        row = dict(log[-1])
        row["hypothesis"] = label
        rows.append(row)
        r = row if "error" not in row else {}
        print(f"  [{label[:52]:52s}] obj={row.get('objective', float('nan')):9.3f}"
              f" mem={r.get('memory_sec', 0):8.3f} coll={r.get('collective_sec', 0):7.3f}"
              f" bytes={r.get('device_bytes', 0)/1e9:6.1f}GB feas={r.get('feasible')}",
              flush=True)

    ok = [r for r in rows if "error" not in r]
    best = min(ok, key=lambda r: r["objective"])
    payload = {"arch": arch, "shape": shape, "mode": "probe",
               "mesh": "x".join(map(str, mesh.devices.shape)),
               "baseline": rows[0], "best": best,
               "improvement": (rows[0]["objective"] - best["objective"])
               / max(rows[0]["objective"], 1e-12),
               "log": rows}
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[probe] {arch} x {shape}: baseline {rows[0]['objective']:.3f}s -> "
          f"best {best['objective']:.3f}s ({payload['improvement']*100:.1f}%) "
          f"[{best['hypothesis']}]")
    return payload


def run(arch: str, shape: str, evals: int, out: str, multi_pod: bool = False,
        learner: str = "RF", parallel: int = 1, db_path: str | None = None):
    """Thin adapter over :class:`repro.engine.Campaign`: the campaign owns
    warm-start, budget, and (with ``db_path``) crash-safe resume; this
    driver only builds the evaluator and reports the payload. ``parallel``
    keeps that many lower+compile evaluations in flight (each evaluation
    holds the GIL only between XLA calls, so compiles overlap well)."""
    import jax
    from repro.configs import SHAPES, get_config
    from repro.engine import Campaign
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    kind = SHAPES[shape].kind
    log: list = []
    ev = make_cell_evaluator(arch, shape, mesh, log)
    space = knob_space(kind, is_moe=cfg.n_experts > 0)

    # paper-faithful baseline first: the space's defaults, warm-starting the
    # search so 'best' can never regress below the known default schedule
    baseline_cfg = space.default_configuration()
    base = ev(baseline_cfg)
    baseline = dict(log[-1])

    res = Campaign(space, ev, max_evals=evals, learner=learner, seed=1234,
                   n_initial=max(4, evals // 3), parallel=parallel,
                   db_path=db_path, warm_start=[baseline_cfg]).run()
    best = res.best
    payload = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "baseline": baseline,
        "best": {"config": best.config, "objective": best.objective,
                 "info": best.info},
        "improvement": (baseline["objective"] - best.objective)
        / max(baseline["objective"], 1e-12),
        "log": log,
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[hillclimb] {arch} x {shape}: baseline {baseline['objective']:.4f}s"
          f" -> best {best.objective:.4f}s "
          f"({payload['improvement']*100:.1f}% better) config={best.config}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--evals", type=int, default=12)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--learner", default="RF")
    ap.add_argument("--parallel", type=int, default=1,
                    help="lower+compile evaluations in flight (1 = serial)")
    ap.add_argument("--db", default=None,
                    help="campaign checkpoint dir (resume a killed hillclimb)")
    ap.add_argument("--probe", action="store_true",
                    help="hypothesis-ladder mode: one compile per probe")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out = args.out or f"results/hillclimb_{args.arch}_{args.shape}.json"
    if args.probe:
        run_probe(args.arch, args.shape, out, args.multi_pod)
    else:
        run(args.arch, args.shape, args.evals, out, args.multi_pod,
            args.learner, parallel=args.parallel, db_path=args.db)


if __name__ == "__main__":
    main()
