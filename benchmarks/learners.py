"""Four-learner comparison (the paper's Figs 3-6 / Sec 4 methodology):
run the same syr2k campaign under RF / ET / GBRT / GP and report best
objective, the evaluation it was found at, and how many evaluations were
skipped (GP's duplicate-proposal early-finish behavior, Sec 2.2)."""

from __future__ import annotations

from benchmarks.common import EVALS
from repro.core import TimingEvaluator, compare_learners
from repro.kernels import ref as R
from repro.kernels import variants as V
from repro.kernels.spaces import kernel_space


def learner_comparison(max_evals: int | None = None):
    N, M = 192, 160
    C, A, B = R.init_syr2k(N, M)
    factory = V.syr2k_host((C, A, B))
    ev = TimingEvaluator(factory, repeats=2, warmup=1)
    results = compare_learners(
        kernel_space("syr2k", target="host"), ev,
        max_evals=max_evals or EVALS, seed=1234,
    )
    rows = []
    for learner, res in results.items():
        b = res.best
        rows.append((
            f"learners_syr2k/{learner}",
            b.objective * 1e6,
            f"at_eval={b.index};evaluated={res.n_evaluated};"
            f"skipped_dups={res.n_skipped};config={b.config}",
        ))
    return rows
