"""repro.fidelity — multi-fidelity cascade campaigns with top-k promotion.

A declarative :class:`FidelityLadder` (analytic cost model → reduced-shape
proxy timing → full hardware timing) plus a :class:`CascadeCampaign` that
screens a wide configuration pool on the cheap rungs and promotes only the
top-k to the next — successive-halving budgets — while every rung's
observations feed the surrogate as calibrated priors. See
``repro-fidelity audit`` for the rank-correlation contract that decides
which kernels may screen analytically.
"""

from repro.fidelity.calibrate import RungCalibration, pairs_from_records
from repro.fidelity.cascade import CascadeCampaign, CascadeResult
from repro.fidelity.ladder import FidelityLadder, Rung, default_ladder

__all__ = [
    "CascadeCampaign",
    "CascadeResult",
    "FidelityLadder",
    "Rung",
    "RungCalibration",
    "default_ladder",
    "pairs_from_records",
]
