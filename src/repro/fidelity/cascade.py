"""CascadeCampaign: successive-halving multi-fidelity search.

The cascade runs one :class:`~repro.engine.Campaign` per rung, bottom-up::

    rung 0 (cost):   screen wide  — budget 64, promote top 8
    rung 1 (proxy):  re-measure   — budget 16, promote top 4
    rung 2 (hw):     ground truth — budget 8  → the answer

Each rung's campaign is seeded two ways from the rungs below it:

  * **promotions** — the lower rung's top-k configurations are evaluated
    *first* at the new fidelity (the engine's ``warm_start`` path), so the
    expensive rung spends its budget on the cheap rung's shortlist before
    exploring on its own;
  * **priors** — every lower-rung observation enters the surrogate as a
    virtual observation (the ``warm_start_records`` machinery), calibrated
    onto the target rung's scale by the online per-rung bias/scale model
    (:class:`~repro.fidelity.calibrate.RungCalibration`, learned from the
    paired measurements the promotions themselves produce). Records are
    passed in ascending fidelity order; the search dedupes by canonical
    config key keeping the highest-fidelity row, so a config observed at
    three rungs trains the surrogate exactly once.

Every rung checkpoints through its own ``PerformanceDatabase`` JSONL under
``<db_root>/rung<level>/``. A killed cascade resumes with exactly the
remaining per-rung budgets: completed rungs replay as no-ops (their budget
is already recorded), the interrupted rung continues from its checkpoint,
and — because promotions, calibration pairs, and priors are all derived
from the rung databases — a fixed-seed resumed run is replay-identical to
an uninterrupted one.

Each rung is split into two campaign phases over the *same* database:
phase A evaluates the promotions (no priors, no proposals — it consumes no
RNG), then calibration is refreshed so the fresh (low, high) pairs inform
it, then phase B spends the rest of the rung budget on calibrated-prior BO.
Without the split, the first hardware rung would receive priors on the raw
cost-model scale — orders of magnitude off — because no paired measurement
exists yet.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Mapping

import numpy as np

from repro.core.database import OK, PerformanceDatabase, Record
from repro.core.search import SearchResult
from repro.core.space import ConfigurationSpace, config_key
from repro.engine import Campaign
from repro.fidelity.calibrate import RungCalibration, pairs_from_records
from repro.fidelity.ladder import FidelityLadder
from repro.obs.metrics import get_registry
from repro.obs.trace import span as obs_span

__all__ = ["CascadeCampaign", "CascadeResult"]

_SEED_STRIDE = 7919  # prime stride: distinct, deterministic per-rung streams


@dataclasses.dataclass
class CascadeResult:
    """Per-rung results plus the cascade's own accounting."""

    ladder: FidelityLadder
    rungs: list[SearchResult]
    best: Record | None            # the top (ground-truth) rung's best
    stats: dict                    # screened/promoted per rung + aggregates
    timings: dict                  # ask/tell/wait summed over every rung

    @property
    def hw_evals(self) -> int:
        """Records spent at the top rung — the hardware bill the cascade
        exists to shrink."""
        return self.stats["rungs"][-1]["evaluated"] + \
            self.stats["rungs"][-1]["failed"]

    def summary(self) -> str:
        parts = []
        for rung, res, st in zip(self.ladder, self.rungs, self.stats["rungs"]):
            parts.append(f"{rung.name}[{st['evaluated']}ev"
                         f"/{st['promoted']}up]" if rung.promote else
                         f"{rung.name}[{st['evaluated']}ev]")
        head = f"cascade {' -> '.join(parts)}"
        if self.best is None:
            return head + " best=<none>"
        return head + f" best={self.best.objective:.6g} config={self.best.config}"


class CascadeCampaign:
    """Screen on cheap rungs, promote the top-k, measure only the shortlist.

    ``db_root`` is a directory; each rung checkpoints under
    ``<db_root>/rung<level>/`` (``None`` = in-memory, no resume).
    ``kernel`` only labels the obs counters. Everything else matches
    :class:`~repro.engine.Campaign`'s knobs and is applied per rung.
    """

    def __init__(
        self,
        space: ConfigurationSpace,
        ladder: FidelityLadder,
        *,
        db_root: str | None = None,
        learner: str = "RF",
        seed: int = 1234,
        n_initial: int = 10,
        init_method: str = "lhs",
        kappa: float = 1.96,
        acq: str = "LCB",
        parallel: int = 1,
        warm_start: list | None = None,
        warm_start_records: list[tuple[Mapping[str, Any], float]] | None = None,
        feasibility: Callable[[Mapping[str, Any]], bool] | None = None,
        callback: Callable[[Record], None] | None = None,
        kernel: str | None = None,
        min_calibration_pairs: int = 3,
    ):
        self.space = space
        self.ladder = ladder
        self.db_root = db_root
        self.learner = learner
        self.seed = seed
        self.n_initial = n_initial
        self.init_method = init_method
        self.kappa = kappa
        self.acq = acq
        self.parallel = parallel
        self.warm_start = list(warm_start or [])
        # external priors at ground-truth fidelity (e.g. the background
        # tuner's nearest-store-neighbor records): they seed the *top* rung's
        # surrogate, appended after the calibrated lower-rung priors so the
        # dedup-keep-last contract lets a true measurement override a
        # calibrated estimate of the same config
        self.warm_start_records = list(warm_start_records or [])
        self.feasibility = feasibility
        self.callback = callback
        self.kernel = kernel
        self.min_calibration_pairs = min_calibration_pairs
        self._metrics = get_registry()
        self._dbs: dict[int, PerformanceDatabase] = {}

    # -- per-rung plumbing -------------------------------------------------------

    def _db(self, level: int) -> PerformanceDatabase:
        db = self._dbs.get(level)
        if db is None:
            path = None if self.db_root is None else \
                os.path.join(self.db_root, f"rung{level}")
            db = self._dbs[level] = PerformanceDatabase(
                path, param_names=self.space.param_names)
        return db

    def _labels(self, rung) -> dict:
        labels = {"rung": rung.level}
        if self.kernel is not None:
            labels["kernel"] = self.kernel
        return labels

    def _adjacent_calibrations(self, upto: int) -> list[RungCalibration]:
        """``calibs[i]`` maps rung ``i``'s scale onto rung ``i+1``'s, fit
        from configs both databases have measured (promotions create these
        pairs). Derived from the JSONLs alone, so resume re-learns the
        identical mapping."""
        calibs = []
        for i in range(upto):
            c = RungCalibration(min_pairs=self.min_calibration_pairs)
            lo = self._db(self.ladder[i].level).records
            hi = self._db(self.ladder[i + 1].level).records
            for low, high in pairs_from_records(lo, hi):
                c.update(low, high)
            calibs.append(c)
        return calibs

    def _priors_for(self, rung_idx: int) -> list[tuple[dict, float]] | None:
        """Every lower-rung observation, chained through the adjacent
        calibrations onto the target rung's scale, in ascending fidelity
        order (the dedup-keep-last contract of ``warm_start_records``)."""
        priors: list[tuple[dict, float]] = []
        if rung_idx > 0:
            calibs = self._adjacent_calibrations(rung_idx)
            for j in range(rung_idx):
                for rec in self._db(self.ladder[j].level).records:
                    if rec.status != OK or not np.isfinite(rec.objective):
                        continue
                    obj = float(rec.objective)
                    for c in calibs[j:rung_idx]:
                        obj = c.apply(obj)
                    priors.append((dict(rec.config), obj))
        if rung_idx == len(self.ladder) - 1:
            priors.extend((dict(c), float(o)) for c, o in self.warm_start_records)
        return priors or None

    def _promotions(self, rung_idx: int) -> list[dict]:
        """Top-k configs of rung ``rung_idx`` by objective (OK records only,
        deduped by canonical key) — the shortlist the next rung measures."""
        rung = self.ladder[rung_idx]
        ranked = sorted(self._db(rung.level).evaluated(),
                        key=lambda r: (r.objective, r.index))
        out, seen = [], set()
        for rec in ranked:
            key = config_key(rec.config)
            if key in seen or not np.isfinite(rec.objective):
                continue
            seen.add(key)
            out.append(dict(rec.config))
            if len(out) >= rung.promote:
                break
        return out

    def _campaign(self, rung, *, max_evals: int, warm_start: list,
                  priors, db: PerformanceDatabase) -> Campaign:
        executor = rung.executor
        return Campaign(
            self.space,
            None if executor is not None else rung.evaluator,
            executor=executor,
            max_evals=max_evals,
            learner=self.learner,
            seed=self.seed + _SEED_STRIDE * rung.level,
            db=db,
            n_initial=self.n_initial,
            init_method=self.init_method,
            kappa=self.kappa,
            acq=self.acq,
            parallel=self.parallel,
            warm_start=warm_start,
            warm_start_records=priors,
            callback=self.callback,
            feasibility=self.feasibility,
            rung=rung.level,
        )

    # -- the cascade -------------------------------------------------------------

    def run(self) -> CascadeResult:
        results: list[SearchResult] = []
        rung_stats: list[dict] = []
        timings = {"ask_sec": 0.0, "tell_sec": 0.0, "wait_sec": 0.0}
        promoted: list[dict] = []
        for i, rung in enumerate(self.ladder):
            db = self._db(rung.level)
            already = len(db)   # resumed records count against this budget
            with obs_span("fidelity.rung", rung_name=rung.name,
                          **self._labels(rung)):
                warm = promoted if i > 0 else list(self.warm_start)
                if warm:
                    # phase A: measure the shortlist (and any rung-0 seeds)
                    # first. Proposes nothing, so it consumes no RNG; on
                    # resume, already-recorded promotions are skipped and
                    # the budget cap keeps the phase a strict subset of the
                    # rung's own budget.
                    res = self._campaign(
                        rung, max_evals=min(len(warm), rung.budget),
                        warm_start=warm, priors=None, db=db).run()
                    self._merge_timings(timings, res.timings)
                # phase B: calibration now sees the pairs phase A produced
                res = self._campaign(
                    rung, max_evals=rung.budget, warm_start=[],
                    priors=self._priors_for(i), db=db).run()
            self._merge_timings(timings, res.timings)
            results.append(res)
            fresh = len(db) - already
            promoted = self._promotions(i) if rung.promote else []
            stat = {
                "rung": rung.level, "name": rung.name,
                "budget": rung.budget, "screened": fresh,
                "evaluated": res.n_evaluated, "failed": res.n_failed,
                "skipped": res.n_skipped, "promoted": len(promoted),
            }
            rung_stats.append(stat)
            labels = self._labels(rung)
            self._metrics.add("fidelity_screened_total", fresh, **labels)
            if promoted:
                self._metrics.add("fidelity_promoted_total", len(promoted),
                                  **labels)

        calibs = self._adjacent_calibrations(len(self.ladder) - 1)
        stats = {
            "rungs": rung_stats,
            "screened": sum(s["screened"] for s in rung_stats[:-1]),
            "promoted": sum(s["promoted"] for s in rung_stats),
            "calibration": [c.describe() for c in calibs],
        }
        return CascadeResult(
            ladder=self.ladder, rungs=results,
            best=self._db(self.ladder.top.level).best(),
            stats=stats, timings=timings)

    @staticmethod
    def _merge_timings(into: dict, timings: dict | None) -> None:
        if timings:
            for k in ("ask_sec", "tell_sec", "wait_sec"):
                into[k] += timings.get(k, 0.0)
