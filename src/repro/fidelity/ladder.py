"""The fidelity ladder: a declarative stack of evaluation fidelities.

A :class:`Rung` names one way of scoring a configuration — cheaper and less
faithful toward the bottom, expensive ground truth at the top:

  * **rung 0 — analytic** (``cost``): the roofline cost model
    (:func:`repro.kernels.cost.kernel_cost`). Zero hardware; thousands of
    configs per second; ordering-faithful where the model is good (see
    ``repro-fidelity audit``).
  * **rung 1 — proxy** (``proxy``): wall-clock timing at reduced problem
    dims (:data:`repro.kernels.problems.PROXY_DIMS`). Real compilation and
    execution, a fraction of the full cost.
  * **rung 2 — hardware** (``hw``): full-dims timing — the paper's
    evaluation, the budget that matters.

Each rung carries an evaluation ``budget`` (counted exactly like a
campaign's ``max_evals``: records, failures, and GP skips all consume it)
and a ``promote`` count — how many of its best configurations graduate to
the next rung (the successive-halving shape: wide and cheap below, narrow
and expensive above). :func:`default_ladder` builds the standard
cost → proxy → hardware stack for any benchmark kernel; ladders with
arbitrary evaluators (tests, third-party fidelities) construct
:class:`FidelityLadder` directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

from repro.core.plopper import EvalResult

__all__ = ["Rung", "FidelityLadder", "default_ladder"]


@dataclasses.dataclass(frozen=True)
class Rung:
    """One fidelity level.

    ``evaluator`` is the standard ``config -> EvalResult`` callable.
    ``executor``, when set, overrides it for this rung's campaign (e.g. a
    hardened or thread-pool executor for the hardware rung); the evaluator
    is then ignored by the campaign but still used for calibration-free
    re-scoring, so keep both coherent.
    """

    level: int
    name: str
    evaluator: Callable[[Mapping[str, Any]], EvalResult]
    budget: int
    promote: int = 0          # top-k graduating to the next rung (0 on top)
    executor: Any | None = None

    def __post_init__(self):
        if self.budget < 1:
            raise ValueError(f"rung {self.name!r}: budget must be >= 1, "
                             f"got {self.budget}")
        if self.promote < 0:
            raise ValueError(f"rung {self.name!r}: promote must be >= 0, "
                             f"got {self.promote}")


class FidelityLadder:
    """An ordered, validated sequence of rungs (ascending fidelity)."""

    def __init__(self, rungs: Sequence[Rung]):
        rungs = list(rungs)
        if not rungs:
            raise ValueError("a fidelity ladder needs at least one rung")
        levels = [r.level for r in rungs]
        if levels != sorted(set(levels)):
            raise ValueError(f"rung levels must be strictly ascending, got {levels}")
        names = [r.name for r in rungs]
        if len(set(names)) != len(names):
            raise ValueError(f"rung names must be unique, got {names}")
        for below, above in zip(rungs, rungs[1:]):
            if below.promote < 1:
                raise ValueError(
                    f"rung {below.name!r} promotes nothing to {above.name!r}; "
                    f"set promote >= 1 on every non-top rung")
            if below.promote > below.budget:
                raise ValueError(
                    f"rung {below.name!r} cannot promote {below.promote} from "
                    f"a budget of {below.budget}")
            if below.promote > above.budget:
                raise ValueError(
                    f"rung {below.name!r} promotes {below.promote} but "
                    f"{above.name!r} can only evaluate {above.budget}")
        self.rungs = rungs

    def __len__(self) -> int:
        return len(self.rungs)

    def __iter__(self):
        return iter(self.rungs)

    def __getitem__(self, i: int) -> Rung:
        return self.rungs[i]

    @property
    def top(self) -> Rung:
        """The ground-truth rung — its best record is the cascade's answer,
        and its budget is the hardware-evaluation bill."""
        return self.rungs[-1]

    def describe(self) -> list[dict]:
        return [{"level": r.level, "name": r.name, "budget": r.budget,
                 "promote": r.promote} for r in self.rungs]


def default_ladder(
    kernel: str,
    *,
    budgets: Sequence[int] = (64, 16, 8),
    promote: Sequence[int] | None = None,
    dims: tuple | None = None,
    proxy_dims: tuple | None = None,
    repeats: int = 2,
    warmup: int = 1,
    top_executor: Any | None = None,
) -> FidelityLadder:
    """The standard cost → proxy → hardware ladder for a benchmark kernel.

    ``budgets`` gives one entry per rung, bottom-up; a 2-entry budget list
    builds a cost → hardware ladder (no proxy rung) — the shape the
    background tuner uses. ``promote`` defaults to half the next rung's
    budget (at least 2). ``dims`` defaults to the kernel's
    :data:`~repro.kernels.problems.BENCH_DIMS`; ``proxy_dims`` to
    :data:`~repro.kernels.problems.PROXY_DIMS`. Raises ``KeyError`` for
    kernels without a cost-model entry (not ``fidelity_ready`` — see
    ``repro-analyze space``).
    """
    from repro.core.plopper import TimingEvaluator
    from repro.kernels.cost import KERNEL_COST_FNS
    from repro.kernels.problems import (
        BENCH_DIMS,
        PROXY_DIMS,
        bench_problem,
        make_cost_evaluator,
    )

    if kernel not in KERNEL_COST_FNS:
        raise KeyError(
            f"kernel {kernel!r} has no cost-model entry and cannot screen on "
            f"rung 0 (fidelity_ready == False); registered cost models: "
            f"{sorted(KERNEL_COST_FNS)}")
    if len(budgets) not in (2, 3):
        raise ValueError(f"budgets must have 2 or 3 entries, got {list(budgets)}")
    dims = tuple(dims) if dims is not None else BENCH_DIMS[kernel]
    if promote is None:
        promote = [max(2, b // 2) for b in budgets[1:]]
    if len(promote) != len(budgets) - 1:
        raise ValueError(
            f"promote needs {len(budgets) - 1} entries for {len(budgets)} "
            f"rungs, got {list(promote)}")

    rungs = [Rung(level=0, name="cost", budget=int(budgets[0]),
                  promote=int(promote[0]),
                  evaluator=make_cost_evaluator(kernel, dims))]
    if len(budgets) == 3:
        pdims = tuple(proxy_dims) if proxy_dims is not None \
            else PROXY_DIMS.get(kernel, dims)
        rungs.append(Rung(
            level=1, name="proxy", budget=int(budgets[1]),
            promote=int(promote[1]),
            evaluator=TimingEvaluator(bench_problem(kernel, pdims),
                                      repeats=repeats, warmup=warmup)))
    rungs.append(Rung(
        level=len(budgets) - 1, name="hw", budget=int(budgets[-1]),
        evaluator=TimingEvaluator(bench_problem(kernel, dims),
                                  repeats=repeats, warmup=warmup),
        executor=top_executor))
    return FidelityLadder(rungs)
