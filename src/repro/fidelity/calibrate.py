"""Per-rung bias/scale calibration between fidelities.

A cost-model score and a wall-clock measurement live on different scales
(modeled seconds for a hypothetical TPU vs real host seconds — often orders
of magnitude apart), and a proxy-shape timing is systematically faster than
the full shape. Feeding raw low-rung objectives into a higher rung's
surrogate as priors would teach it a wrong *level* even when the *ordering*
is right. :class:`RungCalibration` learns the mapping online from paired
observations — configurations measured at both rungs, which the cascade's
promotions produce for free — as a log-space affine model::

    log(high) ≈ a + b · log(low)

i.e. a multiplicative bias (``e^a``) and a power-law scale (``b``). With
fewer than ``min_pairs`` pairs the model degrades gracefully: a single pair
calibrates the median ratio (pure bias, ``b = 1``); no pairs at all is the
identity. ``b`` is clipped to a sane band so two noisy early pairs cannot
invert or explode the mapping.

Calibration state is *derived*, never persisted: the cascade rebuilds it
from the per-rung performance databases (joining records by canonical
config key), which is what makes a resumed cascade's calibration identical
to an uninterrupted run's.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["RungCalibration", "pairs_from_records"]

_B_MIN, _B_MAX = 0.25, 4.0  # power-law clip band
_EPS = 1e-12                # objectives at/below this are uncalibratable


class RungCalibration:
    """Online low-rung → high-rung objective mapping."""

    def __init__(self, min_pairs: int = 3):
        self.min_pairs = min_pairs
        self._low: list[float] = []
        self._high: list[float] = []
        self._coef: tuple[float, float] | None = None  # (a, b), lazily fit

    @property
    def n_pairs(self) -> int:
        return len(self._low)

    def update(self, low: float, high: float) -> bool:
        """Add one paired observation; non-finite or non-positive values
        (failure penalties, infeasible scores) are rejected — they would
        poison the fit and carry no scale information."""
        low, high = float(low), float(high)
        if not (math.isfinite(low) and math.isfinite(high)):
            return False
        if low <= _EPS or high <= _EPS:
            return False
        self._low.append(low)
        self._high.append(high)
        self._coef = None
        return True

    def _fit(self) -> tuple[float, float]:
        if self._coef is not None:
            return self._coef
        lx = np.log(np.asarray(self._low))
        ly = np.log(np.asarray(self._high))
        if len(lx) < self.min_pairs or float(np.ptp(lx)) < 1e-9:
            # bias-only: not enough pairs (or a degenerate vertical cloud)
            # to estimate a slope — match the median log-ratio
            a = float(np.median(ly - lx))
            self._coef = (a, 1.0)
            return self._coef
        b, a = np.polyfit(lx, ly, 1)
        b = float(min(_B_MAX, max(_B_MIN, b)))
        # re-center the intercept after clipping so the mapping still passes
        # through the cloud's median
        a = float(np.median(ly - b * lx))
        self._coef = (a, b)
        return self._coef

    def apply(self, low: float) -> float:
        """Map a low-rung objective onto the high rung's scale. Identity
        with no pairs; non-positive/non-finite inputs pass through untouched
        (penalty semantics are scale-free already)."""
        low = float(low)
        if not self._low or not math.isfinite(low) or low <= _EPS:
            return low
        a, b = self._fit()
        return math.exp(a + b * math.log(low))

    def describe(self) -> dict:
        if not self._low:
            return {"n_pairs": 0, "bias": 1.0, "scale": 1.0}
        a, b = self._fit()
        return {"n_pairs": self.n_pairs, "bias": math.exp(a), "scale": b}


def pairs_from_records(low_records, high_records) -> list[tuple[float, float]]:
    """Join two record lists by canonical config key, yielding
    (low_objective, high_objective) pairs for configs observed (status OK)
    at both rungs — the calibration's training set, re-derivable from the
    per-rung JSONLs on resume. When a config was evaluated more than once
    at a rung the first OK observation wins (record order is deterministic,
    so so is the join)."""
    from repro.core.database import OK
    from repro.core.space import config_key

    lows: dict[tuple, float] = {}
    for r in low_records:
        if r.status == OK:
            lows.setdefault(config_key(r.config), float(r.objective))
    pairs = []
    seen: set[tuple] = set()
    for r in high_records:
        key = config_key(r.config)
        if r.status == OK and key in lows and key not in seen:
            seen.add(key)
            pairs.append((lows[key], float(r.objective)))
    return pairs
