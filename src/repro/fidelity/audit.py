"""Rank-correlation audit: does the cost model order configs like hardware?

Rung 0 of the fidelity ladder screens with the analytic cost model, so the
cascade's whole premise is that the model's *ordering* (not its absolute
scale — calibration handles that) agrees with measured timing. This module
makes that a checkable contract: sample configurations, score each with the
cost model and with wall-clock timing at the same problem dims, and report
the Spearman rank correlation ρ. Kernels whose ρ clears the threshold are
safe to screen analytically (``screen_ok``); weak kernels are flagged so a
cascade over them leans on the proxy rung instead.

``repro-fidelity audit`` exposes this as a CLI; the pinned regression test
(`tests/test_fidelity.py`) holds the matmul-family kernels to a minimum ρ
so a cost-model regression that scrambles ordering fails CI rather than
silently degrading every cascade.
"""

from __future__ import annotations

from typing import Callable, Mapping, Sequence

import numpy as np

__all__ = ["spearman_rho", "audit_kernel", "audit_kernels", "DEFAULT_RHO_MIN"]

# below this the cost model is no better than a weak shuffle — don't screen
DEFAULT_RHO_MIN = 0.2


def spearman_rho(x: Sequence[float], y: Sequence[float]) -> float:
    """Spearman rank correlation without scipy: average-rank both vectors
    (ties share the mean of their rank block), then Pearson on the ranks.
    Returns NaN for fewer than 3 pairs or a degenerate (constant) vector."""
    x = np.asarray(x, dtype=float)
    y = np.asarray(y, dtype=float)
    if x.size < 3 or x.size != y.size:
        return float("nan")

    def ranks(v: np.ndarray) -> np.ndarray:
        order = np.argsort(v, kind="stable")
        r = np.empty(v.size, dtype=float)
        r[order] = np.arange(1, v.size + 1, dtype=float)
        # average ties so equal scores carry equal rank
        for val in np.unique(v):
            mask = v == val
            if mask.sum() > 1:
                r[mask] = r[mask].mean()
        return r

    rx, ry = ranks(x), ranks(y)
    sx, sy = rx.std(), ry.std()
    if sx < 1e-12 or sy < 1e-12:
        return float("nan")
    return float(np.mean((rx - rx.mean()) * (ry - ry.mean())) / (sx * sy))


def audit_kernel(
    kernel: str,
    *,
    n_samples: int = 10,
    seed: int = 7,
    dims: tuple | None = None,
    target: str = "host",
    repeats: int = 1,
    warmup: int = 1,
    rho_min: float = DEFAULT_RHO_MIN,
    measure: Callable[[Mapping], float] | None = None,
) -> dict:
    """Audit one kernel: cost-model score vs measured time over a fixed-seed
    sample of its configuration space, both at the same ``dims`` (default:
    the reduced proxy dims, so the audit is cheap enough for CI).

    ``measure`` injects the ground-truth scorer (``config -> seconds``) —
    tests use synthetic measurements; the default wall-clocks the host
    variant. Configs the cost model rejects (VMEM-infeasible) or whose
    measurement fails are dropped from the correlation and counted in
    ``n_dropped``.
    """
    from repro.core.plopper import TimingEvaluator
    from repro.kernels.problems import PROXY_DIMS, bench_problem, make_cost_evaluator
    from repro.kernels.spaces import kernel_space

    if dims is None:
        from repro.kernels.problems import BENCH_DIMS

        dims = PROXY_DIMS.get(kernel, BENCH_DIMS[kernel])
    dims = tuple(dims)
    cost = make_cost_evaluator(kernel, dims)
    if measure is None:
        timer = TimingEvaluator(bench_problem(kernel, dims),
                                repeats=repeats, warmup=warmup)

        def measure(cfg, _timer=timer):
            res = _timer(cfg)
            return float(res.objective) if res.ok else float("nan")

    space = kernel_space(kernel, target=target, seed=seed)
    rng = np.random.default_rng(seed)
    configs = space.sample_configurations(n_samples, rng)

    cost_scores, times, dropped = [], [], 0
    for cfg in configs:
        c = cost(cfg)
        if not c.ok or not np.isfinite(c.objective):
            dropped += 1
            continue
        t = float(measure(cfg))
        if not np.isfinite(t) or t <= 0:
            dropped += 1
            continue
        cost_scores.append(float(c.objective))
        times.append(t)

    rho = spearman_rho(cost_scores, times)
    return {
        "kernel": kernel,
        "dims": list(dims),
        "target": target,
        "n_sampled": len(configs),
        "n_paired": len(times),
        "n_dropped": dropped,
        "rho": None if np.isnan(rho) else round(rho, 4),
        "rho_min": rho_min,
        "screen_ok": bool(not np.isnan(rho) and rho >= rho_min),
    }


def audit_kernels(
    kernels: Sequence[str] | None = None,
    **kwargs,
) -> list[dict]:
    """Audit every ``fidelity_ready`` kernel (or an explicit subset), in
    sorted order so reports and tests are stable."""
    from repro.kernels.cost import KERNEL_COST_FNS

    if kernels is None:
        kernels = sorted(KERNEL_COST_FNS)
    return [audit_kernel(k, **kwargs) for k in kernels]
