"""Checkpointing: msgpack(+zstd when available) pytree snapshots with atomic
rename, async save, and step-addressed resume — the train-loop half of fault
tolerance (the autotuner's half is the performance database, which is its own
resume log).

``zstandard`` is optional: shards start with a one-byte format flag
(``\\x01`` = zstd-compressed, ``\\x00`` = raw msgpack), so hosts without the
compressor still checkpoint and restore. Legacy flagless shards (a bare zstd
frame, magic ``0x28``) remain readable when zstandard is installed."""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional: fall back to uncompressed shards
    zstandard = None

__all__ = ["save", "restore", "AsyncCheckpointer", "latest_step"]

_MAGIC = "repro-ckpt-v1"
_FLAG_RAW = b"\x00"
_FLAG_ZSTD = b"\x01"


def _pack_leaf(x):
    a = np.asarray(x)
    # msgpack can't carry bf16 natively; view as uint16 with a dtype tag
    if a.dtype == jnp.bfloat16:
        return {"d": "bfloat16", "s": a.shape, "b": a.view(np.uint16).tobytes()}
    return {"d": a.dtype.str, "s": a.shape, "b": a.tobytes()}


def _unpack_leaf(rec):
    if rec["d"] == "bfloat16":
        a = np.frombuffer(rec["b"], np.uint16).reshape(rec["s"])
        return jnp.asarray(a.view(jnp.bfloat16))
    return np.frombuffer(rec["b"], np.dtype(rec["d"])).reshape(rec["s"])


def save(path: str, tree, step: int, *, meta: dict | None = None,
         level: int = 3) -> str:
    """Write <path>/step_<n>/ with shard payload + metadata; atomic rename."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = msgpack.packb(
        {"magic": _MAGIC, "leaves": [_pack_leaf(x) for x in leaves]},
        use_bin_type=True)
    if zstandard is not None:
        payload = _FLAG_ZSTD + zstandard.ZstdCompressor(level=level).compress(payload)
    else:
        payload = _FLAG_RAW + payload

    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "shard_0.msgpack.zst"), "wb") as f:
        f.write(payload)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "meta": meta or {}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, tree_template, step: int | None = None):
    """Restore into the structure of ``tree_template`` (shapes validated)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "shard_0.msgpack.zst"), "rb") as f:
        data = f.read()
    flag, body = data[:1], data[1:]
    if flag == _FLAG_RAW:
        payload = body
    elif flag == _FLAG_ZSTD:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint shard is zstd-compressed but zstandard is not installed")
        payload = zstandard.ZstdDecompressor().decompress(body)
    else:  # legacy flagless shard: a bare zstd frame
        if zstandard is None:
            raise RuntimeError(
                "legacy zstd checkpoint shard but zstandard is not installed")
        payload = zstandard.ZstdDecompressor().decompress(data)
    obj = msgpack.unpackb(payload, raw=False)
    assert obj["magic"] == _MAGIC, "corrupt checkpoint"
    leaves, treedef = jax.tree_util.tree_flatten(tree_template)
    rec = obj["leaves"]
    if len(rec) != len(leaves):
        raise ValueError(f"leaf count mismatch: ckpt {len(rec)} vs template {len(leaves)}")
    out = []
    for r, tmpl in zip(rec, leaves):
        a = _unpack_leaf(r)
        if tuple(a.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"shape mismatch {a.shape} vs {np.shape(tmpl)}")
        out.append(a)
    return treedef.unflatten(out), step


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training (one in flight)."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None

    def save(self, tree, step: int, meta: dict | None = None):
        self.wait()
        # device->host copy happens on the caller thread (consistent snapshot)
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._pending = self._pool.submit(self._do_save, host_tree, step, meta)

    def _do_save(self, host_tree, step, meta):
        save(self.path, host_tree, step, meta=meta)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.path)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None
