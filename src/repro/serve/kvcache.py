"""KV-cache utilities on top of model.init_cache: cache-usage accounting
(bytes per token, per arch) — the MLA-vs-GQA comparison numbers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.model import init_cache

__all__ = ["init_cache", "cache_bytes_per_token", "cache_bytes"]


def cache_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> int:
    if cfg.family == "ssm":
        return 0  # state is O(1) in sequence length
    if cfg.attn_type == "mla":
        per = cfg.kv_lora_rank + cfg.qk_rope_dim
        n = cfg.n_layers
    elif cfg.family == "hybrid":
        import numpy as np
        sites = int(np.ceil(cfg.n_layers / cfg.attn_every)) if cfg.attn_every else 0
        per = 2 * cfg.n_kv_heads * cfg.hd
        n = sites
    else:
        per = 2 * cfg.n_kv_heads * cfg.hd
        n = cfg.n_layers
    return int(per * n * dtype_bytes)


def cache_bytes(cfg: ArchConfig, batch: int, seq: int, dtype_bytes: int = 2) -> int:
    return cache_bytes_per_token(cfg, dtype_bytes) * batch * seq
