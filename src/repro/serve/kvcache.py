"""KV-cache layer: byte accounting (the MLA-vs-GQA comparison numbers) and
the paged/blocked cache behind continuous-batching serving.

:class:`PagedKVCache` replaces the flat per-request dense cache for the
GQA families. The backing store is one dense buffer of ``max_batch`` slots,
but every *view* the model attends against is cut at **page granularity**:
``page_size`` — a layout axis of the tuned ``decode_attention`` space (see
``kernels.spaces``) — fixes the seq-bucket ladder, so a request that is
``pos`` tokens deep attends against ``ceil((pos+1)/page)*page`` keys, not
``max_len``. Small pages mean tight buckets (little padded attention work)
but many distinct buckets (one serve-step retrace + one dispatch signature
each); large pages the reverse — exactly the compute-vs-retrace trade the
tuner gets to own.

Requests occupy slots: :meth:`admit` copies a prefilled cache into a free
slot, decode rounds run on :meth:`view`/:meth:`writeback` batched views of
whichever slots are live (batch reshaping = picking a different slot set),
and :meth:`release` frees the slot. :meth:`stats` reports pages allocated
vs tokens resident — the paged-accounting numbers
``DispatchService.telemetry()`` surfaces under ``kv_cache``.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.model import init_cache

__all__ = [
    "init_cache", "cache_bytes_per_token", "cache_bytes", "PagedKVCache",
]


def cache_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> int:
    if cfg.family == "ssm":
        return 0  # state is O(1) in sequence length
    if cfg.attn_type == "mla":
        per = cfg.kv_lora_rank + cfg.qk_rope_dim
        n = cfg.n_layers
    elif cfg.family == "hybrid":
        sites = int(np.ceil(cfg.n_layers / cfg.attn_every)) if cfg.attn_every else 0
        per = 2 * cfg.n_kv_heads * cfg.hd
        n = sites
    else:
        per = 2 * cfg.n_kv_heads * cfg.hd
        n = cfg.n_layers
    return int(per * n * dtype_bytes)


def cache_bytes(cfg: ArchConfig, batch: int, seq: int, dtype_bytes: int = 2,
                page_size: int | None = None) -> int:
    """Cache footprint for ``batch`` sequences of ``seq`` tokens. With
    ``page_size`` the per-sequence length is rounded up to page granularity
    — the paged layout's allocation unit (pages are whole or nothing)."""
    if page_size:
        seq = -(-seq // page_size) * page_size
    return cache_bytes_per_token(cfg, dtype_bytes) * batch * seq


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PagedKVCache:
    """Slot-managed, page-bucketed KV cache for dense/GQA serving.

    Only the GQA attention families qualify: MLA keeps its latent cache,
    SSM state is O(1), and ring-buffer (sliding-window) caches already
    allocate O(window). The windowless restriction is the same static gate
    the decode dispatch route uses (``blocks.attn_layer_decode``)."""

    def __init__(self, cfg: ArchConfig, max_batch: int, max_len: int, *,
                 page_size: int = 128, dtype=None):
        if cfg.attn_type == "mla" or cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError(f"paged KV cache requires a GQA family, got "
                             f"{cfg.family}/{cfg.attn_type}")
        if cfg.sliding_window or cfg.local_global_ratio:
            raise ValueError("paged KV cache does not support windowed archs "
                             "(their ring cache is already O(window))")
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.page_size = int(page_size)
        self.alloc = _cdiv(max_len, page_size) * page_size
        self.dtype = dtype or cfg.dtype
        self.buf = init_cache(cfg, self.max_batch, self.alloc, self.dtype)
        # host-side slot table: last written position per slot, -1 = free
        self.pos = np.full(self.max_batch, -1, np.int64)

    # -- slot management ---------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i in range(self.max_batch) if self.pos[i] < 0]

    def active_slots(self) -> list[int]:
        return [i for i in range(self.max_batch) if self.pos[i] >= 0]

    def admit(self, slot: int, prefilled: dict, prompt_len: int) -> None:
        """Copy a prefilled single-request cache (``init_cache(cfg, 1, n)``
        pytree, ``n <= alloc``) into ``slot``. Stale data beyond the prompt
        is harmless: decode masks by position and overwrites slot-by-slot."""
        if self.pos[slot] >= 0:
            raise ValueError(f"slot {slot} is occupied")

        def insert(buf, new):
            idx = (0,) * (buf.ndim - 4) + (slot, 0, 0, 0)
            return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), idx)

        self.buf = jax.tree_util.tree_map(insert, self.buf, prefilled)
        self.pos[slot] = prompt_len - 1

    def release(self, slot: int) -> None:
        self.pos[slot] = -1

    # -- bucketed batch views ----------------------------------------------------

    def seq_bucket(self, slots, extra: int = 1) -> int:
        """The page-aligned view length covering every slot's position plus
        ``extra`` upcoming tokens — the S the dispatch signature sees."""
        if len(slots) == 0:
            return self.page_size
        need = int(max(self.pos[s] for s in slots)) + 1 + extra
        return min(_cdiv(need, self.page_size) * self.page_size, self.alloc)

    def view(self, slots, bucket: int) -> dict:
        """Batched cache view over ``slots``, cut at ``bucket`` pages — what
        a decode round's serve_step consumes. A distinct (len(slots),
        bucket) shape is a distinct jit trace + dispatch signature."""
        idx = np.asarray(slots, np.int32)
        # stacked per-layer leaves are (L, B, S, K, hd); un-stacked singleton
        # sites (e.g. a moe arch's leading dense layer) are (B, S, K, hd)
        return jax.tree_util.tree_map(
            lambda a: a[:, idx, :bucket] if a.ndim == 5 else a[idx, :bucket],
            self.buf)

    def writeback(self, slots, bucket: int, cache: dict) -> None:
        """Scatter a round's updated view back into the backing buffer."""
        idx = np.asarray(slots, np.int32)

        def put(buf, c):
            c = c.astype(buf.dtype)
            if buf.ndim == 5:
                return buf.at[:, idx, :bucket].set(c)
            return buf.at[idx, :bucket].set(c)

        self.buf = jax.tree_util.tree_map(put, self.buf, cache)

    def pos_vector(self, slots) -> jnp.ndarray:
        """(len(slots),) int32 per-sequence decode positions."""
        return jnp.asarray([int(self.pos[s]) for s in slots], jnp.int32)

    def advance(self, slots) -> None:
        """Record one decoded token per slot (host-side position bump)."""
        for s in slots:
            self.pos[s] += 1

    # -- accounting --------------------------------------------------------------

    def stats(self) -> dict:
        """Paged accounting: pages allocated vs tokens resident. Allocation
        is page-granular per active sequence (a page is whole or nothing);
        ``bytes_backing`` is the dense backing buffer's full footprint."""
        active = self.active_slots()
        tokens = int(sum(int(self.pos[s]) + 1 for s in active))
        pages = int(sum(_cdiv(int(self.pos[s]) + 1, self.page_size)
                        for s in active))
        per_tok = cache_bytes_per_token(
            self.cfg, jnp.dtype(self.dtype).itemsize)
        cap = pages * self.page_size
        return {
            "page_size": self.page_size,
            "slots_active": len(active),
            "slots_total": self.max_batch,
            "tokens_resident": tokens,
            "pages_allocated": pages,
            "bytes_resident": tokens * per_tok,
            "bytes_allocated": cap * per_tok,
            "bytes_backing": self.max_batch * self.alloc * per_tok,
            "page_occupancy": (tokens / cap) if cap else 0.0,
        }
