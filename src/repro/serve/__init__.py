"""repro.serve — KV cache (dense + paged) + prefill/decode serving steps."""

from repro.serve.kvcache import (
    PagedKVCache,
    cache_bytes,
    cache_bytes_per_token,
    init_cache,
)
from repro.serve.step import greedy_decode, make_serve_step, prefill

__all__ = ["PagedKVCache", "cache_bytes", "cache_bytes_per_token",
           "init_cache", "greedy_decode", "make_serve_step", "prefill"]
