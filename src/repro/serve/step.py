"""Serving steps: prefill (forward over the prompt) + batched greedy decode.

``decode_step`` (one token against a filled cache) lives in
repro.models.model; this module adds the request-batch driver used by the
serving example and benchmarks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.model import decode_step, forward, init_cache

__all__ = ["prefill", "greedy_decode", "make_serve_step"]


def prefill(params, batch, cfg: ArchConfig, max_len: int, service=None, **fw_kw):
    """Run the prompt through the model, then replay it through decode_step to
    fill the cache (simple, correct reference path; a fused prefill-with-cache
    is a §Perf optimization). ``service`` routes the prompt forward's
    attention (tuned flash ``bq``/``bk``) and matmul call sites through
    :mod:`repro.dispatch` — this is where serving traffic finally meets the
    tuning store."""
    logits, _ = forward(params, batch, cfg, service=service, **fw_kw)
    B, S = batch["tokens"].shape
    cache = init_cache(cfg, B, max_len)

    def body(cache, t):
        _, cache = decode_step(params, cache, jax.lax.dynamic_slice_in_dim(
            batch["tokens"], t, 1, axis=1), t, cfg, service=service)
        return cache, None

    cache, _ = jax.lax.scan(body, cache, jnp.arange(S))
    return logits, cache


def make_serve_step(cfg: ArchConfig, *, mla_absorb: bool = True, service=None):
    """serve_step(params, cache, token, pos) -> (next_token, logits, cache).

    With a :class:`repro.dispatch.DispatchService`, the step is routed
    through the service's compiled-executable cache — every caller asking for
    the same model config shares one jitted entry point — and the decode
    matmul call sites inside resolve tuned block shapes from the service's
    store, so its hit/miss counters cover serving traffic alongside kernel
    dispatches."""

    def serve_step(params, cache, token, pos):
        logits, cache = decode_step(params, cache, token, pos, cfg,
                                    mla_absorb=mla_absorb, service=service)
        nxt = jnp.argmax(logits, axis=-1).astype(token.dtype)[:, None]
        return nxt, logits, cache

    if service is not None:
        # key on the full dataclass repr: two configs sharing a name (e.g. a
        # full model and its reduced() variant) must not share a closure
        return service.jit_cached(
            f"serve_step/{cfg!r}/absorb={mla_absorb}", serve_step)
    return serve_step


def greedy_decode(params, cfg: ArchConfig, prompt: jnp.ndarray, steps: int,
                  max_len: int, service=None, **fw_kw):
    """prompt: (B, S). Returns (B, steps) generated ids. ``service`` routes
    prefill attention and the per-step matmuls through tuned dispatch
    variants and the decode step through the service's executable cache."""
    batch = {"tokens": prompt}
    if cfg.family == "audio":
        batch["enc_embed"] = fw_kw.pop("enc_embed")
    logits, cache = prefill(params, batch, cfg, max_len, service=service, **fw_kw)
    B, S = prompt.shape
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(prompt.dtype)[:, None]
    serve = make_serve_step(cfg, service=service)

    def body(carry, t):
        tok, cache = carry
        nxt, _, cache = serve(params, cache, tok, t)
        return (nxt, cache), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (tok, cache), S + jnp.arange(steps))
    return toks.T
