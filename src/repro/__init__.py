"""repro: Bayesian-optimization schedule autotuning for JAX/Pallas on TPU —
a reproduction and TPU-native extension of Wu et al., "Autotuning PolyBench
Benchmarks with LLVM Clang/Polly Loop Optimization Pragmas Using Bayesian
Optimization" (2020)."""

__version__ = "1.0.0"
