"""repro-obs — observability CLI: snapshots, traces, and a /metrics endpoint.

    # latest merged metrics view of a snapshot JSONL (or a live endpoint)
    python -m repro.launch.obs snapshot --file results/obs/metrics.jsonl
    python -m repro.launch.obs snapshot --url http://127.0.0.1:8710 --prom

    # human-readable tail of a trace file
    python -m repro.launch.obs tail --trace results/obs/trace.jsonl -n 20

    # validate a trace + per-span-name latency stats; optionally export a
    # Perfetto-loadable JSON and require specific spans (CI assertion)
    python -m repro.launch.obs summarize --trace results/obs/trace.jsonl \
        --perfetto results/obs/trace.perfetto.json \
        --require-spans campaign.ask,campaign.evaluate,campaign.tell

    # histogram summaries (count / p50 / p99) from a metrics snapshot file
    python -m repro.launch.obs summarize --metrics results/obs/metrics.jsonl

    # serve merged snapshot-file metrics as a Prometheus /metrics endpoint
    python -m repro.launch.obs serve --file results/obs/metrics.jsonl --port 8710

All commands print a JSON summary on stdout (except ``snapshot --prom``,
which prints Prometheus text). Non-zero exit on failed validation or a
missing required span, so CI can assert on the timeline's shape.
"""

from __future__ import annotations

import argparse
import json
import urllib.request

from repro.obs.export import ObsServer, prometheus_text, read_snapshot_file
from repro.obs.metrics import merge_snapshots, summarize_histograms
from repro.obs.trace import export_chrome_trace, iter_trace, validate_trace


def _scrape(url: str) -> dict:
    with urllib.request.urlopen(url.rstrip("/") + "/snapshot", timeout=10) as r:
        return json.loads(r.read())


def _load_snapshot(args) -> dict:
    snaps = []
    if args.file:
        snaps.append(read_snapshot_file(args.file))
    if args.url:
        snaps.append(_scrape(args.url))
    return merge_snapshots(*snaps)


def cmd_snapshot(args) -> int:
    snap = _load_snapshot(args)
    if args.prom:
        print(prometheus_text(snap), end="")
    else:
        print(json.dumps(snap, indent=2))
    return 0


def cmd_tail(args) -> int:
    events = [ev for ev in iter_trace(args.trace)]
    for ev in events[-args.n:]:
        dur = f"{ev.get('dur', 0) / 1e3:10.3f}ms" if ev.get("ph") == "X" else " " * 12
        attrs = json.dumps(ev.get("args", {})) if ev.get("args") else ""
        print(f"{ev.get('ts', 0):>16} {ev.get('ph', '?'):>2} {dur} "
              f"{ev.get('name', '?'):32s} {attrs}")
    return 0


def cmd_summarize(args) -> int:
    out: dict = {}
    ok = True
    if args.trace:
        report = validate_trace(args.trace)
        spans: dict[str, list[int]] = {}
        for ev in iter_trace(args.trace):
            if ev.get("ph") == "X" and "dur" in ev:
                spans.setdefault(str(ev["name"]), []).append(int(ev["dur"]))
        report["spans"] = {
            name: {
                "count": len(durs),
                "total_ms": round(sum(durs) / 1e3, 3),
                "max_ms": round(max(durs) / 1e3, 3),
            }
            for name, durs in sorted(spans.items())
        }
        if args.require_spans:
            missing = [s for s in args.require_spans.split(",")
                       if s and s not in spans]
            report["missing_spans"] = missing
            ok = ok and not missing
        if args.perfetto:
            report["perfetto"] = {
                "path": args.perfetto,
                "events": export_chrome_trace(args.trace, args.perfetto),
            }
        ok = ok and report["ok"]
        out["trace"] = report
    if args.metrics:
        snap = read_snapshot_file(args.metrics)
        out["metrics"] = {
            "counters": snap.get("counters", []),
            "histograms": summarize_histograms(snap),
        }
    if not out:
        print(json.dumps({"error": "nothing to summarize: pass --trace "
                                    "and/or --metrics"}))
        return 2
    print(json.dumps(out, indent=2))
    return 0 if ok else 1


def cmd_serve(args) -> int:
    if args.file:
        source = lambda: read_snapshot_file(args.file)  # noqa: E731 — re-read per scrape
    else:
        source = None  # live default registry (in-process embedding)
    server = ObsServer(source=source, host=args.host, port=args.port)
    print(json.dumps({"serving": server.url,
                      "endpoints": ["/metrics", "/snapshot"],
                      "file": args.file}))
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-obs", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("snapshot", help="print a merged metrics snapshot")
    p.add_argument("--file", default=None, help="metrics snapshot JSONL")
    p.add_argument("--url", default=None, help="live /snapshot endpoint to scrape")
    p.add_argument("--prom", action="store_true",
                   help="print Prometheus text instead of JSON")

    p = sub.add_parser("tail", help="print the last N trace events")
    p.add_argument("--trace", required=True)
    p.add_argument("-n", type=int, default=20)

    p = sub.add_parser("summarize",
                       help="validate a trace / summarize metrics histograms")
    p.add_argument("--trace", default=None)
    p.add_argument("--metrics", default=None, help="metrics snapshot JSONL")
    p.add_argument("--perfetto", default=None, metavar="OUT",
                   help="also export a Perfetto-loadable trace JSON")
    p.add_argument("--require-spans", default=None, metavar="A,B,...",
                   help="exit non-zero unless every named span is present")

    p = sub.add_parser("serve", help="serve /metrics + /snapshot over HTTP")
    p.add_argument("--file", default=None,
                   help="snapshot JSONL to serve (merged, re-read per scrape)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8710)

    args = ap.parse_args(argv)
    if args.cmd == "snapshot":
        if not args.file and not args.url:
            ap.error("snapshot needs --file and/or --url")
        return cmd_snapshot(args)
    if args.cmd == "tail":
        return cmd_tail(args)
    if args.cmd == "summarize":
        return cmd_summarize(args)
    return cmd_serve(args)


if __name__ == "__main__":
    raise SystemExit(main())
