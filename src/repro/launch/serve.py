"""Serving driver: batched greedy decoding with a prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.models import init_params
from repro.serve import cache_bytes, greedy_decode


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"cache={cache_bytes(cfg, args.batch, max_len)/1e6:.2f} MB")

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "audio":
        kw["enc_embed"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_len, cfg.d_model))

    t0 = time.perf_counter()
    out = greedy_decode(params, cfg, prompt, steps=args.gen, max_len=max_len, **kw)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s incl. compile)")
    print("[serve] first request ids:", out[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
