"""Production mesh construction.

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_from_plan"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_plan(plan):
    """Build a mesh from an elastic MeshPlan (repro.ft.elastic)."""
    return jax.make_mesh(plan.shape, plan.axes)
