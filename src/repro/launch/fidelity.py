"""repro-fidelity — the multi-fidelity cascade's CLI (see :mod:`repro.fidelity`).

    # rank-correlation audit: does the analytic cost model order configs the
    # way measured timing does? Reports Spearman rho per kernel and flags the
    # ones too weak to screen on (screen_ok=false); --strict turns a weak
    # kernel into a non-zero exit (the CI gate)
    python -m repro.launch.fidelity audit [--kernel K] [--samples N] \
        [--rho-min R] [--json] [--out FILE] [--strict]

    # describe a kernel's default cost -> proxy -> hardware ladder: per-rung
    # budgets, promotion counts, and the dims each rung evaluates at
    python -m repro.launch.fidelity show --kernel K [--rung-budgets B0,B1,B2]

The audit measures at the reduced PROXY_DIMS by default so it is cheap
enough to pin in CI; pass --full-dims to audit at bench sizes instead.
"""

from __future__ import annotations

import argparse
import json
import os


def cmd_audit(args) -> int:
    from repro.fidelity.audit import audit_kernel
    from repro.kernels.cost import KERNEL_COST_FNS
    from repro.kernels.problems import BENCH_DIMS

    kernels = [args.kernel] if args.kernel else sorted(KERNEL_COST_FNS)
    rows = [audit_kernel(k, n_samples=args.samples, seed=args.seed,
                         repeats=args.repeats, rho_min=args.rho_min,
                         dims=BENCH_DIMS[k] if args.full_dims else None,
                         target=args.target)
            for k in kernels]
    weak = [r["kernel"] for r in rows if not r["screen_ok"]]
    out = {"rho_min": args.rho_min, "samples": args.samples,
           "seed": args.seed, "audit": rows, "weak_kernels": weak}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump(out, fh, indent=2)
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        hdr = f"{'kernel':<18} {'rho':>7} {'pairs':>6} {'dropped':>8}  verdict"
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            rho = "nan" if r["rho"] is None else f"{r['rho']:.3f}"
            verdict = "screen_ok" if r["screen_ok"] else "WEAK"
            print(f"{r['kernel']:<18} {rho:>7} {r['n_paired']:>6} "
                  f"{r['n_dropped']:>8}  {verdict}")
        if weak:
            print(f"weak: {', '.join(weak)} — cost-model ordering below "
                  f"rho_min={args.rho_min}; cascade these over the proxy "
                  f"rung instead of screening analytically")
    return 1 if (args.strict and weak) else 0


def cmd_show(args) -> int:
    from repro.fidelity import default_ladder
    from repro.kernels.problems import BENCH_DIMS, PROXY_DIMS, fidelity_ready

    kernel = args.kernel
    if not fidelity_ready(kernel):
        print(f"{kernel}: fidelity_ready=false (no cost-model entry; "
              f"cannot screen on rung 0)")
        return 1
    budgets = tuple(int(x) for x in args.rung_budgets.split(","))
    ladder = default_ladder(kernel, budgets=budgets)
    print(json.dumps({
        "kernel": kernel,
        "fidelity_ready": True,
        "dims": list(BENCH_DIMS[kernel]),
        "proxy_dims": list(PROXY_DIMS.get(kernel, BENCH_DIMS[kernel])),
        "ladder": ladder.describe(),
    }, indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-fidelity", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    aud = sub.add_parser("audit", help="cost-model rank-correlation audit")
    aud.add_argument("--kernel", default=None,
                     help="audit one kernel (default: every fidelity-ready one)")
    aud.add_argument("--samples", type=int, default=10,
                     help="configs sampled per kernel")
    aud.add_argument("--seed", type=int, default=7)
    aud.add_argument("--repeats", type=int, default=1,
                     help="timing repeats per config (min is taken)")
    aud.add_argument("--rho-min", type=float, default=0.2,
                     help="Spearman rho below which a kernel is flagged weak")
    aud.add_argument("--target", default="host", choices=["host", "tpu"],
                     help="config space flavor to sample")
    aud.add_argument("--full-dims", action="store_true",
                     help="measure at bench dims instead of proxy dims")
    aud.add_argument("--json", action="store_true")
    aud.add_argument("--out", default=None, metavar="FILE",
                     help="also write the JSON report to FILE (CI artifact)")
    aud.add_argument("--strict", action="store_true",
                     help="non-zero exit when any kernel is weak (CI gate)")
    aud.set_defaults(fn=cmd_audit)

    sh = sub.add_parser("show", help="describe a kernel's default ladder")
    sh.add_argument("--kernel", required=True)
    sh.add_argument("--rung-budgets", default="64,16,8", metavar="B0,B1,B2")
    sh.set_defaults(fn=cmd_show)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
