"""Autotuning CLI — the paper's ytopt interface (--max-evals / --learner),
now a thin adapter over :class:`repro.engine.Campaign`.

    PYTHONPATH=src python -m repro.launch.autotune --kernel syr2k \
        --max-evals 30 --learner RF --db results/syr2k_rf

Kernels are tuned on the host-timed backend (B1) at bench sizes; pass
--backend cost for the TPU-model backend (B2) at paper LARGE sizes.

--parallel N keeps N candidate evaluations in flight (constant-liar
batching over a thread pool); N=1 is the paper's serial loop, bit-for-bit.
--resume requires --db and continues a killed campaign from its JSONL
checkpoint: completed evaluations are never re-run, and the campaign
performs exactly the remaining budget.

--warm-start STORE_DIR seeds the campaign from a repro.dispatch TuningStore:
the store's nearest tuned config (by log-scale shape distance) is evaluated
first and its neighbors seed the surrogate, so a warmed campaign reaches the
prior optimum in a fraction of the cold-start budget. --store STORE_DIR
publishes this campaign's winner back (both flags may name the same dir).

--cascade runs a repro.fidelity multi-fidelity cascade instead of a flat
campaign: a wide pool is screened on the analytic cost model, the top-k
re-timed at reduced proxy dims, and only the survivors measured at full
size (--rung-budgets / --promote shape the ladder). With --db, each rung
checkpoints under <db>/rung<level>/ and --resume continues with exactly the
remaining per-rung budgets.
"""

from __future__ import annotations

import argparse
import json

from repro.core import TimingEvaluator, autotune
from repro.core.findmin import importance_report
from repro.kernels.problems import (
    bench_problem,
    make_cost_evaluator,
    problem_signature_for,
)
from repro.kernels.spaces import KERNEL_SPACES, kernel_space


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", required=True, choices=sorted(KERNEL_SPACES))
    ap.add_argument("--max-evals", type=int, default=100,
                    help="evaluation budget (paper default: 100; paper runs: 200)")
    ap.add_argument("--learner", default="RF", choices=["RF", "ET", "GBRT", "GP"])
    ap.add_argument("--backend", default="host", choices=["host", "cost"])
    ap.add_argument("--db", default=None, help="performance database directory")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--parallel", type=int, default=1, metavar="N",
                    help="candidate evaluations in flight (1 = serial paper loop)")
    ap.add_argument("--resume", action="store_true",
                    help="continue a killed campaign from --db's JSONL checkpoint")
    ap.add_argument("--warm-start", default=None, metavar="STORE_DIR",
                    help="TuningStore to warm-start from (nearest-neighbor seed)")
    ap.add_argument("--store", default=None, metavar="STORE_DIR",
                    help="TuningStore to publish this campaign's best into")
    ap.add_argument("--prune-infeasible", action="store_true",
                    help="statically prune infeasible candidates from the "
                         "acquisition pool (repro.analyze feasibility rules; "
                         "off by default — pruning changes fixed-seed "
                         "trajectories)")
    ap.add_argument("--cascade", action="store_true",
                    help="multi-fidelity cascade (repro.fidelity): screen on "
                         "the analytic cost model, re-time a reduced proxy "
                         "shape, and spend full timings only on promoted "
                         "top-k configs")
    ap.add_argument("--rung-budgets", default=None, metavar="B0,B1[,B2]",
                    help="per-rung evaluation budgets, bottom-up (2 entries "
                         "= cost->hw, 3 = cost->proxy->hw; default 64,16,8)")
    ap.add_argument("--promote", default=None, metavar="K1[,K2]",
                    help="top-k promoted from each non-top rung "
                         "(default: half the next rung's budget)")
    args = ap.parse_args(argv)

    if args.resume and not args.db:
        ap.error("--resume requires --db (the checkpoint to resume from)")
    if args.cascade and args.backend == "cost":
        ap.error("--cascade needs a timed backend above the analytic model; "
                 "--backend cost IS the cascade's rung 0")
    if (args.rung_budgets or args.promote) and not args.cascade:
        ap.error("--rung-budgets/--promote only apply with --cascade")

    if args.backend == "host":
        evaluator = TimingEvaluator(bench_problem(args.kernel), repeats=2, warmup=1)
        space = kernel_space(args.kernel, target="host", seed=args.seed)
    else:
        evaluator = make_cost_evaluator(args.kernel)
        space = kernel_space(args.kernel, target="tpu", seed=args.seed)

    sig = problem_signature_for(args.kernel, args.backend)
    warm_cfgs, warm_recs = None, None
    if args.warm_start:
        from repro.dispatch import TuningStore
        from repro.dispatch.lookup import warm_start_material
        warm_cfgs, warm_recs = warm_start_material(
            TuningStore(args.warm_start), args.kernel, sig, args.backend)
        if warm_cfgs is not None:
            print(f"warm-start: nearest store config re-evaluated first, "
                  f"{len(warm_recs or [])} neighbor(s) seed the surrogate")
        else:
            print("warm-start: store has no compatible record; cold start")

    if args.resume and not args.cascade:
        from repro.core.database import PerformanceDatabase
        k = len(PerformanceDatabase(args.db).records)
        print(f"resume: {k} record(s) checkpointed, "
              f"{max(0, args.max_evals - k)} evaluation(s) remaining")

    feasibility = None
    if args.prune_infeasible:
        from repro.analyze.feasibility import feasibility_filter
        from repro.kernels.problems import BENCH_DIMS, LARGE_SHAPES
        dims = (BENCH_DIMS if args.backend == "host" else LARGE_SHAPES)[args.kernel]
        feasibility = feasibility_filter(
            args.kernel, dims=dims,
            target="host" if args.backend == "host" else "cost")

    cascade_stats = None
    if args.cascade:
        from repro.fidelity import CascadeCampaign, default_ladder

        budgets = tuple(int(x) for x in
                        (args.rung_budgets or "64,16,8").split(","))
        promote = tuple(int(x) for x in args.promote.split(",")) \
            if args.promote else None
        ladder = default_ladder(args.kernel, budgets=budgets, promote=promote)
        if args.resume:
            from repro.core.database import PerformanceDatabase
            import os
            for rung in ladder:
                k = len(PerformanceDatabase(
                    os.path.join(args.db, f"rung{rung.level}")).records)
                print(f"resume: rung {rung.level} ({rung.name}) has {k} "
                      f"record(s), {max(0, rung.budget - k)} remaining")
        cres = CascadeCampaign(
            space, ladder, db_root=args.db, learner=args.learner,
            seed=args.seed, parallel=args.parallel,
            warm_start=warm_cfgs, warm_start_records=warm_recs,
            feasibility=feasibility, kernel=args.kernel).run()
        print(cres.summary())
        res = cres.rungs[-1]   # the hardware rung: the answer + what we publish
        cascade_stats = cres.stats
    else:
        res = autotune(space, evaluator, max_evals=args.max_evals,
                       learner=args.learner, seed=args.seed, db_path=args.db,
                       parallel=args.parallel,
                       warm_start=warm_cfgs, warm_start_records=warm_recs,
                       feasibility=feasibility)
    if feasibility is not None and res.timings:
        print(f"feasibility: pruned {res.timings.get('n_pruned', 0)} "
              f"statically-infeasible candidate(s) from the acquisition pool")

    if args.store and res.best is not None:
        from repro.dispatch import TuningRecord, TuningStore
        TuningStore(args.store).put(TuningRecord(
            kernel=args.kernel, signature=sig, backend=args.backend,
            config=dict(res.best.config), objective=float(res.best.objective),
            n_evals=len(res.db), source=f"cli:{args.db or 'ephemeral'}"))

    print(res.summary())
    out = {
        "best_config": res.best.config,
        "best_objective_sec": res.best.objective,
        "found_at_eval": res.best.index,
        "importance": importance_report(res.db),
    }
    if cascade_stats is not None:
        out["cascade"] = cascade_stats
    print(json.dumps(out, indent=2, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
