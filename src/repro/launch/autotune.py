"""Autotuning CLI — the paper's ytopt interface (--max-evals / --learner).

    PYTHONPATH=src python -m repro.launch.autotune --kernel syr2k \
        --max-evals 30 --learner RF --db results/syr2k_rf

Kernels are tuned on the host-timed backend (B1) at bench sizes; pass
--backend cost for the TPU-model backend (B2) at paper LARGE sizes.

--warm-start STORE_DIR seeds the campaign from a repro.dispatch TuningStore:
the store's nearest tuned config (by log-scale shape distance) is evaluated
first and its neighbors seed the surrogate, so a warmed campaign reaches the
prior optimum in a fraction of the cold-start budget. --store STORE_DIR
publishes this campaign's winner back (both flags may name the same dir).
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import EvalResult, TimingEvaluator, autotune
from repro.core.findmin import importance_report
from repro.kernels import model_kernels as MK
from repro.kernels import ref as R
from repro.kernels import variants as V
from repro.kernels.spaces import KERNEL_SPACES, kernel_space

BENCH_PROBLEMS = {
    "syr2k": lambda: (V.syr2k_host(R.init_syr2k(240, 200)), None),
    "mm3": lambda: (V.mm3_host(R.init_mm3(200, 180, 160, 150, 170)), None),
    "lu": lambda: (V.lu_host(R.init_lu(256)), None),
    "heat3d": lambda: (V.heat3d_host(R.init_heat3d(40), tsteps=8), None),
    "covariance": lambda: (V.covariance_host(R.init_covariance(300, 240)), None),
    "floyd_warshall": lambda: (V.floyd_warshall_host(R.init_floyd_warshall(240)), None),
    "flash_attention": lambda: (
        MK.flash_attention_host(MK.init_flash_attention(4, 128, 128, 64)), None),
    "matmul": lambda: (MK.matmul_host(MK.init_matmul(256, 192, 224)), None),
}

# problem dims behind BENCH_PROBLEMS (heat3d includes its tsteps knob)
BENCH_DIMS = {
    "syr2k": (240, 200),
    "mm3": (200, 180, 160, 150, 170),
    "lu": (256,),
    "heat3d": (40, 8),
    "covariance": (300, 240),
    "floyd_warshall": (240,),
    "flash_attention": (4, 128, 128, 64),
    "matmul": (256, 192, 224),
}


def _signature(kernel: str, backend: str):
    """Per-argument store signature — the same scheme repro.dispatch derives
    from runtime args, so published configs resolve at dispatch() time."""
    if backend == "cost":
        from benchmarks.pallas_tuning import LARGE_SHAPES
        return R.problem_signature(kernel, *LARGE_SHAPES[kernel])
    return R.problem_signature(kernel, *BENCH_DIMS[kernel])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", required=True, choices=sorted(KERNEL_SPACES))
    ap.add_argument("--max-evals", type=int, default=100,
                    help="evaluation budget (paper default: 100; paper runs: 200)")
    ap.add_argument("--learner", default="RF", choices=["RF", "ET", "GBRT", "GP"])
    ap.add_argument("--backend", default="host", choices=["host", "cost"])
    ap.add_argument("--db", default=None, help="performance database directory")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--warm-start", default=None, metavar="STORE_DIR",
                    help="TuningStore to warm-start from (nearest-neighbor seed)")
    ap.add_argument("--store", default=None, metavar="STORE_DIR",
                    help="TuningStore to publish this campaign's best into")
    args = ap.parse_args(argv)

    if args.backend == "host":
        factory, _ = BENCH_PROBLEMS[args.kernel]()
        evaluator = TimingEvaluator(factory, repeats=2, warmup=1)
        space = kernel_space(args.kernel, target="host", seed=args.seed)
    else:
        from benchmarks.pallas_tuning import LARGE_SHAPES, make_evaluator
        evaluator = make_evaluator(args.kernel)
        space = kernel_space(args.kernel, target="tpu", seed=args.seed)

    sig = _signature(args.kernel, args.backend)
    warm_cfgs, warm_recs = None, None
    if args.warm_start:
        from repro.dispatch import TuningStore, resolve, signature_distance
        ws = TuningStore(args.warm_start)
        hit = resolve(ws, args.kernel, sig, args.backend)
        if hit is not None:
            warm_cfgs = [dict(hit.config)]
            ranked = sorted(
                ws.records(kernel=args.kernel, backend=args.backend),
                key=lambda r: signature_distance(sig, r.signature))
            warm_recs = [(dict(r.config), r.objective) for r in ranked[:3]
                         if signature_distance(sig, r.signature) != float("inf")]
            print(f"warm-start: seeded from {len(warm_recs)} store record(s), "
                  f"nearest at distance {hit.distance:.3f}")
        else:
            print("warm-start: store has no compatible record; cold start")

    res = autotune(space, evaluator, max_evals=args.max_evals,
                   learner=args.learner, seed=args.seed, db_path=args.db,
                   warm_start=warm_cfgs, warm_start_records=warm_recs)

    if args.store and res.best is not None:
        from repro.dispatch import TuningRecord, TuningStore
        TuningStore(args.store).put(TuningRecord(
            kernel=args.kernel, signature=sig, backend=args.backend,
            config=dict(res.best.config), objective=float(res.best.objective),
            n_evals=len(res.db), source=f"cli:{args.db or 'ephemeral'}"))

    print(res.summary())
    print(json.dumps({
        "best_config": res.best.config,
        "best_objective_sec": res.best.objective,
        "found_at_eval": res.best.index,
        "importance": importance_report(res.db),
    }, indent=2, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
