"""Autotuning CLI — the paper's ytopt interface (--max-evals / --learner),
now a thin adapter over :class:`repro.engine.Campaign`.

    PYTHONPATH=src python -m repro.launch.autotune --kernel syr2k \
        --max-evals 30 --learner RF --db results/syr2k_rf

Kernels are tuned on the host-timed backend (B1) at bench sizes; pass
--backend cost for the TPU-model backend (B2) at paper LARGE sizes.

--parallel N keeps N candidate evaluations in flight (constant-liar
batching over a thread pool); N=1 is the paper's serial loop, bit-for-bit.
--resume requires --db and continues a killed campaign from its JSONL
checkpoint: completed evaluations are never re-run, and the campaign
performs exactly the remaining budget.

--warm-start STORE_DIR seeds the campaign from a repro.dispatch TuningStore:
the store's nearest tuned config (by log-scale shape distance) is evaluated
first and its neighbors seed the surrogate, so a warmed campaign reaches the
prior optimum in a fraction of the cold-start budget. --store STORE_DIR
publishes this campaign's winner back (both flags may name the same dir).
"""

from __future__ import annotations

import argparse
import json

from repro.core import TimingEvaluator, autotune
from repro.core.findmin import importance_report
from repro.kernels.problems import (
    bench_problem,
    make_cost_evaluator,
    problem_signature_for,
)
from repro.kernels.spaces import KERNEL_SPACES, kernel_space


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", required=True, choices=sorted(KERNEL_SPACES))
    ap.add_argument("--max-evals", type=int, default=100,
                    help="evaluation budget (paper default: 100; paper runs: 200)")
    ap.add_argument("--learner", default="RF", choices=["RF", "ET", "GBRT", "GP"])
    ap.add_argument("--backend", default="host", choices=["host", "cost"])
    ap.add_argument("--db", default=None, help="performance database directory")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--parallel", type=int, default=1, metavar="N",
                    help="candidate evaluations in flight (1 = serial paper loop)")
    ap.add_argument("--resume", action="store_true",
                    help="continue a killed campaign from --db's JSONL checkpoint")
    ap.add_argument("--warm-start", default=None, metavar="STORE_DIR",
                    help="TuningStore to warm-start from (nearest-neighbor seed)")
    ap.add_argument("--store", default=None, metavar="STORE_DIR",
                    help="TuningStore to publish this campaign's best into")
    ap.add_argument("--prune-infeasible", action="store_true",
                    help="statically prune infeasible candidates from the "
                         "acquisition pool (repro.analyze feasibility rules; "
                         "off by default — pruning changes fixed-seed "
                         "trajectories)")
    args = ap.parse_args(argv)

    if args.resume and not args.db:
        ap.error("--resume requires --db (the checkpoint to resume from)")

    if args.backend == "host":
        evaluator = TimingEvaluator(bench_problem(args.kernel), repeats=2, warmup=1)
        space = kernel_space(args.kernel, target="host", seed=args.seed)
    else:
        evaluator = make_cost_evaluator(args.kernel)
        space = kernel_space(args.kernel, target="tpu", seed=args.seed)

    sig = problem_signature_for(args.kernel, args.backend)
    warm_cfgs, warm_recs = None, None
    if args.warm_start:
        from repro.dispatch import TuningStore
        from repro.dispatch.lookup import warm_start_material
        warm_cfgs, warm_recs = warm_start_material(
            TuningStore(args.warm_start), args.kernel, sig, args.backend)
        if warm_cfgs is not None:
            print(f"warm-start: nearest store config re-evaluated first, "
                  f"{len(warm_recs or [])} neighbor(s) seed the surrogate")
        else:
            print("warm-start: store has no compatible record; cold start")

    if args.resume:
        from repro.core.database import PerformanceDatabase
        k = len(PerformanceDatabase(args.db).records)
        print(f"resume: {k} record(s) checkpointed, "
              f"{max(0, args.max_evals - k)} evaluation(s) remaining")

    feasibility = None
    if args.prune_infeasible:
        from repro.analyze.feasibility import feasibility_filter
        from repro.kernels.problems import BENCH_DIMS, LARGE_SHAPES
        dims = (BENCH_DIMS if args.backend == "host" else LARGE_SHAPES)[args.kernel]
        feasibility = feasibility_filter(
            args.kernel, dims=dims,
            target="host" if args.backend == "host" else "cost")

    res = autotune(space, evaluator, max_evals=args.max_evals,
                   learner=args.learner, seed=args.seed, db_path=args.db,
                   parallel=args.parallel,
                   warm_start=warm_cfgs, warm_start_records=warm_recs,
                   feasibility=feasibility)
    if feasibility is not None and res.timings:
        print(f"feasibility: pruned {res.timings.get('n_pruned', 0)} "
              f"statically-infeasible candidate(s) from the acquisition pool")

    if args.store and res.best is not None:
        from repro.dispatch import TuningRecord, TuningStore
        TuningStore(args.store).put(TuningRecord(
            kernel=args.kernel, signature=sig, backend=args.backend,
            config=dict(res.best.config), objective=float(res.best.objective),
            n_evals=len(res.db), source=f"cli:{args.db or 'ephemeral'}"))

    print(res.summary())
    print(json.dumps({
        "best_config": res.best.config,
        "best_objective_sec": res.best.objective,
        "found_at_eval": res.best.index,
        "importance": importance_report(res.db),
    }, indent=2, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
