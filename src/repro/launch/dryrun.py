import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init). Everything else follows.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, SHAPES, cell_supported, get_config   # noqa: E402
from repro.launch.cells import lower_cell, plan_cell                  # noqa: E402
from repro.launch.mesh import make_production_mesh                    # noqa: E402
from repro.perf.roofline import analyze_compiled                      # noqa: E402

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell, print memory_analysis() and
cost_analysis(), and record roofline terms to a JSON results file.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k --multi-pod

Results append incrementally to --out (crash-safe: rerunning skips done
cells unless --force)."""


def run_cell(arch: str, shape_name: str, multi_pod: bool, knobs: dict | None = None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    ok, reason = cell_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "x".join(map(str, mesh.devices.shape)),
                "status": "skipped", "reason": reason}
    t0 = time.perf_counter()
    plan = plan_cell(arch, shape_name, mesh, knobs)
    lowered, aux = lower_cell(plan, mesh)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    rep = analyze_compiled(compiled, chips=plan.chips,
                           model_flops=aux["model_flops"])
    try:
        mem = compiled.memory_analysis()
        mem_row = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception:  # noqa: BLE001
        mem_row = {}

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "status": "ok",
        "seconds": {"lower": round(t_lower, 1), "compile": round(t_compile, 1)},
        "knobs": aux["knobs"],
        "memory_analysis": mem_row,
        "roofline": rep.row(),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the (2,16,16) 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run each cell on single-pod AND multi-pod meshes")
    ap.add_argument("--all", action="store_true", help="all 40 cells")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true", help="redo finished cells")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done: dict = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for row in json.load(f):
                done[(row["arch"], row["shape"], row["mesh"])] = row
    results = list(done.values())

    n_fail = 0
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_name)
                if key in done:
                    print(f"[skip-done] {key}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...", flush=True)
                try:
                    row = run_cell(arch, shape, multi_pod)
                except Exception as e:  # noqa: BLE001
                    row = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc(limit=5)}
                    n_fail += 1
                results.append(row)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = row["status"]
                extra = ""
                if status == "ok":
                    r = row["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" bound={r['bound_sec']:.4f}s"
                             f" frac={r['roofline_fraction']:.2f}")
                print(f"[{status}] {arch} x {shape} x {mesh_name}{extra}", flush=True)

    print(f"done: {len(results)} rows, {n_fail} failures -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
