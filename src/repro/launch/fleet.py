"""repro-fleet — cross-host TuningStore replication CLI.

    # one-shot anti-entropy cycle (pull + merge + push) through a shared dir
    python -m repro.launch.fleet sync --store results/store \
        --transport file:/mnt/shared/fleet

    # push-only / pull-only halves of the cycle
    python -m repro.launch.fleet push --store results/store --transport file:...
    python -m repro.launch.fleet pull --store results/store --transport file:...

    # replication state: host id, version vector, pending ops, last-sync age
    python -m repro.launch.fleet status --store results/store [--transport ...]

    # serve this host's oplog over localhost HTTP (peers use --transport
    # http://host:port); --interval N also runs the anti-entropy loop
    python -m repro.launch.fleet serve --store results/store --port 8700

Transports: ``file:<dir>`` (shared directory, object-store idiom: one
append-only file per host) or ``http://host:port`` (a peer's ``serve``
endpoint). All commands print a JSON summary on stdout.
"""

from __future__ import annotations

import argparse
import json

from repro.dispatch import TuningStore
from repro.fleet import Replica, SyncAgent, transport_from_spec


def _replica(args) -> Replica:
    return Replica(TuningStore(args.store))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-fleet", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def add(name, *, transport_required):
        p = sub.add_parser(name)
        p.add_argument("--store", required=True, help="TuningStore directory")
        p.add_argument("--transport", required=transport_required,
                       help="file:<dir> or http://host:port")
        return p

    add("push", transport_required=True)
    add("pull", transport_required=True)
    add("sync", transport_required=True)
    add("status", transport_required=False)
    serve = add("serve", transport_required=False)
    serve.add_argument("--host", default="127.0.0.1")
    serve.add_argument("--port", type=int, default=8700)
    serve.add_argument("--interval", type=float, default=None, metavar="SEC",
                       help="also run the anti-entropy loop against "
                            "--transport every SEC seconds")
    args = ap.parse_args(argv)

    replica = _replica(args)
    transport = transport_from_spec(args.transport) if args.transport else None

    if args.cmd == "status":
        out = replica.status(transport)
        # an HTTP peer can report its own status (incl. the serving
        # process's obs histograms — the numbers that matter for a daemon
        # running `serve --interval`); file transports have no process to ask
        if transport is not None and hasattr(transport, "status"):
            try:
                out["peer"] = transport.status()
            except Exception as e:  # noqa: BLE001 — status must not fail hard
                out["peer"] = {"error": repr(e)}
        print(json.dumps(out, indent=2))
        return 0

    if args.cmd == "serve":
        from repro.fleet import FleetServer

        agent = None
        if args.interval is not None:
            if transport is None:
                ap.error("--interval requires --transport")
            agent = SyncAgent(replica, transport,
                              interval_sec=args.interval).start()
        server = FleetServer(replica, host=args.host, port=args.port)
        print(json.dumps({"serving": server.url, "host": replica.host_id}))
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            if agent is not None:
                agent.stop()
            server.stop()
        return 0

    if args.cmd == "push":
        published = transport.push(replica.oplog)
        out = {"published": published, "pending": transport.pending(replica.oplog)}
    elif args.cmd == "pull":
        applied = replica.ingest(transport.pull(replica.oplog))
        out = {"applied": applied}
    else:  # sync: one full anti-entropy cycle
        out = SyncAgent(replica, transport).sync_once()
        if "error" in out:
            print(json.dumps(out, indent=2))
            return 1
    out["host"] = replica.host_id
    out["records"] = len(replica.store)
    print(json.dumps(out, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
