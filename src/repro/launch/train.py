"""Training driver: end-to-end LM training on the available devices.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance: checkpoints every --ckpt-every steps (async), resumes from
the latest checkpoint at startup, monitors per-step stragglers.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config, get_reduced
from repro.data import SyntheticLM, make_batch
from repro.ft import StragglerMonitor
from repro.models import init_params
from repro.train import cosine_lr, init_train_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = init_train_state(params)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={cfg.name} params={n/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        if latest_step(args.ckpt_dir) is not None:
            state, start = restore(args.ckpt_dir, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, lr=args.lr, accum=args.accum,
                                      remat=args.remat))
    stream = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    mon = StragglerMonitor()

    t0 = time.perf_counter()
    for s in range(start, args.steps):
        batch = make_batch(stream, s)
        mon.start()
        params, opt, m = step_fn(params, opt, batch)
        jax.block_until_ready(m["loss"])
        dur, slow = mon.stop()
        if slow:
            print(f"[train] step {s}: straggler ({dur:.2f}s vs EWMA {mon.ewma:.2f}s)")
        if s % args.log_every == 0 or s == args.steps - 1:
            tok_s = args.batch * args.seq / max(dur, 1e-9)
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} {tok_s:,.0f} tok/s")
        if ckpt and (s + 1) % args.ckpt_every == 0:
            ckpt.save({"params": params, "opt": opt}, s + 1)
    if ckpt:
        ckpt.save({"params": params, "opt": opt}, args.steps)
        ckpt.wait()
    print(f"[train] done in {time.perf_counter()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
