"""Dry-run cells: input specs (ShapeDtypeStruct stand-ins) and lowering per
(architecture x shape x mesh) — shared by dryrun.py, the roofline harness,
and the distributed-config autotuner.

``lower_cell`` builds the jitted step with fully-specified in_shardings and
returns the (lowered, chips, model_flops) triple; nothing is allocated.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, cell_supported, get_config
from repro.models.common import ArchConfig
from repro.models.model import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    loss_fn,
)
from repro.parallel.sharding import (
    ShardingProfile,
    batch_specs,
    cache_specs,
    make_profile,
    named,
    param_specs,
)
from repro.train.optim import adamw_init
from repro.train.step import make_train_step

__all__ = ["CellPlan", "input_specs", "plan_cell", "lower_cell", "DEFAULT_KNOBS"]

# per-cell tunable knobs (the distributed-config autotuner's space)
DEFAULT_KNOBS = dict(
    accum=1,            # gradient-accumulation microbatches
    remat="full",       # none | dots | full
    attn_chunk=512,     # flash-style query chunk
    ssm_chunk=128,      # SSD chunk length
    mla_absorb=True,    # MLA decode schedule
    moment_dtype="float32",
    seq_parallel=False, # shard the residual stream's seq dim over `model`
)


def _accum_default(cfg: ArchConfig, shape: ShapeSpec, n_data: int) -> int:
    """Keep per-microbatch device tokens <= ~8k for the big archs."""
    per_dev_batch = max(shape.global_batch // max(n_data, 1), 1)
    tokens = per_dev_batch * shape.seq_len
    if cfg.param_count() > 30e9:
        target = 8_192
    elif cfg.param_count() > 3e9:
        target = 16_384
    else:
        target = 65_536
    accum = 1
    while tokens // accum > target and per_dev_batch % (accum * 2) == 0:
        accum *= 2
    return accum


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: ShapeSpec
    cfg: ArchConfig
    profile: ShardingProfile
    knobs: dict
    chips: int

    @property
    def kind(self) -> str:
        return self.shape.kind


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((B, 3, S), i32)
        if cfg.family == "audio":
            specs["enc_embed"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_len, cfg.d_model), cfg.dtype)
        return specs
    # decode: one new token against a seq-length cache
    return {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def plan_cell(arch: str, shape_name: str, mesh: Mesh,
              knobs: dict | None = None) -> CellPlan:
    cfg = get_config(arch)
    if knobs and "cfg_overrides" in knobs:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **knobs["cfg_overrides"])
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name}: {reason}")
    profile = make_profile(mesh, shape.kind, shape.global_batch)
    merged = dict(DEFAULT_KNOBS)
    n_data = 1
    for a in profile.batch_axes:
        n_data *= mesh.shape[a]
    merged["accum"] = _accum_default(cfg, shape, n_data)
    if knobs:
        merged.update(knobs)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]
    return CellPlan(arch, shape, cfg, profile, merged, chips)


def model_flops(plan: CellPlan) -> float:
    """6*N_active*D for train; 2*N_active*D for a forward/prefill; 2*N_active
    per token for decode."""
    cfg = plan.cfg
    n = cfg.active_param_count()
    B, S = plan.shape.global_batch, plan.shape.seq_len
    if plan.kind == "train":
        return 6.0 * n * B * S
    if plan.kind == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B  # decode: one token per request


def _abstract_cache(cfg: ArchConfig, B: int, S: int):
    return jax.eval_shape(lambda: init_cache(cfg, B, S))


def lower_cell(plan: CellPlan, mesh: Mesh):
    """Lower (do not compile) the cell's step. Returns (lowered, aux) where
    aux has chips / model_flops / spec trees for reporting."""
    cfg, shape, profile, knobs = plan.cfg, plan.shape, plan.profile, plan.knobs
    params_abs = abstract_params(cfg)
    p_specs = param_specs(params_abs, mesh, profile, cfg)
    p_shard = named(mesh, p_specs)
    inputs = input_specs(cfg, shape)

    if plan.kind == "train":
        opt_abs = jax.eval_shape(functools.partial(
            adamw_init, moment_dtype=jnp.dtype(knobs["moment_dtype"])), params_abs)
        o_specs = {
            "m": p_specs, "v": p_specs, "step": P(),
        }
        o_shard = named(mesh, o_specs)
        b_specs = batch_specs(inputs, mesh, profile)
        b_shard = named(mesh, b_specs)
        b_axes = profile.batch_axes or None
        sp_axis = profile.tp_axis if knobs.get("seq_parallel") else None
        act_spec = P(b_axes, sp_axis, None)
        logits_spec = P(b_axes, None,
                        profile.tp_axis if cfg.vocab_size %
                        mesh.shape[profile.tp_axis] == 0 else None)
        step = make_train_step(cfg, accum=knobs["accum"], remat=knobs["remat"],
                               attn_chunk=knobs["attn_chunk"],
                               ssm_chunk=knobs["ssm_chunk"],
                               act_spec=act_spec, logits_spec=logits_spec)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
        )
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, inputs)
    elif plan.kind == "prefill":
        b_specs = batch_specs(inputs, mesh, profile)
        b_shard = named(mesh, b_specs)

        b_axes = profile.batch_axes or None

        def prefill_fn(params, batch):
            logits, _ = forward(
                params, batch, cfg, remat="none",
                attn_chunk=knobs["attn_chunk"], ssm_chunk=knobs["ssm_chunk"],
                act_spec=P(b_axes, None, None),
                logits_spec=P(b_axes, None,
                              profile.tp_axis if cfg.vocab_size %
                              mesh.shape[profile.tp_axis] == 0 else None))
            return logits

        jitted = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
        with mesh:
            lowered = jitted.lower(params_abs, inputs)
    else:  # decode
        B, S = shape.global_batch, shape.seq_len
        cache_abs = _abstract_cache(cfg, B, S)
        c_specs = cache_specs(cache_abs, mesh, profile, cfg)
        c_shard = named(mesh, c_specs)
        tok_shard = named(mesh, P(profile.batch_axes or None, None))

        def serve_step(params, cache, token, pos):
            return decode_step(params, cache, token, pos, cfg,
                               mla_absorb=knobs["mla_absorb"])

        jitted = jax.jit(
            serve_step,
            in_shardings=(p_shard, c_shard, tok_shard, None),
            out_shardings=(None, c_shard),
        )
        with mesh:
            lowered = jitted.lower(params_abs, cache_abs, inputs["token"],
                                   inputs["pos"])

    aux = {
        "chips": plan.chips,
        "model_flops": model_flops(plan),
        "arch": plan.arch,
        "shape": shape.name,
        "kind": plan.kind,
        "knobs": dict(knobs),
    }
    return lowered, aux
