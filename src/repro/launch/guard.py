"""repro-guard — resilience-layer inspection CLI.

    # watch state from a store: drift quarantines, reasons, affected keys
    python -m repro.launch.guard status --store results/store \
        [--obs results/obs.jsonl]

    # offline audit: re-run the drift policy over a recorded obs snapshot
    # log and print the decisions the live watcher made (or would make)
    python -m repro.launch.guard replay --obs results/obs.jsonl \
        --store results/store [--drift-factor 3.0] [--hysteresis 2] \
        [--min-samples 8] [--interval 10]

    # the fault-point catalog; --spec validates a REPRO_FAULTS string
    python -m repro.launch.guard faults [--spec "eval.hang:times=1"]

``status`` is the offline complement of the live view
(``DispatchService.telemetry()["guard"]`` / ``repro-fleet status``):
it reads only durable state — quarantine tombstones and, with ``--obs``,
the ``guard_*`` counters of the newest snapshot — so it works against a
store directory with no serving process attached. ``replay`` makes drift
decisions auditable: the policy core is pure, so the same snapshots and
baselines always reproduce the same quarantine calls. All commands print
JSON on stdout.
"""

from __future__ import annotations

import argparse
import json

from repro.dispatch import TuningStore
from repro.dispatch.signature import signature_key
from repro.guard import (
    CATALOG,
    WatchPolicy,
    guard_counters,
    install_env_faults,
    replay_decisions,
)


def _baselines(store: TuningStore) -> dict:
    store.refresh()
    return {(r.kernel, signature_key(r.signature), r.backend):
            float(r.objective) for r in store.records()}


def _read_snapshots(path: str) -> list[dict]:
    from repro.obs.export import read_snapshot_file

    return read_snapshot_file(path, merge=False)


def _policy(args) -> WatchPolicy:
    return WatchPolicy(interval_sec=args.interval,
                       drift_factor=args.drift_factor,
                       hysteresis=args.hysteresis,
                       cooldown_sec=args.cooldown,
                       min_samples=args.min_samples)


def _add_policy_args(p: argparse.ArgumentParser) -> None:
    d = WatchPolicy()
    p.add_argument("--interval", type=float, default=d.interval_sec,
                   help="seconds per snapshot window (replay clock)")
    p.add_argument("--drift-factor", type=float, default=d.drift_factor,
                   help="quarantine when window p50 > factor x stored baseline")
    p.add_argument("--hysteresis", type=int, default=d.hysteresis,
                   help="consecutive breaching windows before acting")
    p.add_argument("--cooldown", type=float, default=d.cooldown_sec,
                   help="seconds between actions on the same key")
    p.add_argument("--min-samples", type=int, default=d.min_samples,
                   help="ignore windows with fewer executions")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-guard", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    st = sub.add_parser("status")
    st.add_argument("--store", required=True, help="TuningStore directory")
    st.add_argument("--obs", default=None,
                    help="obs snapshot JSONL: report guard_* counters")

    rp = sub.add_parser("replay")
    rp.add_argument("--store", required=True,
                    help="TuningStore directory (drift baselines)")
    rp.add_argument("--obs", required=True, help="obs snapshot JSONL to audit")
    _add_policy_args(rp)

    fl = sub.add_parser("faults")
    fl.add_argument("--spec", default=None,
                    help="validate a REPRO_FAULTS spec without running")

    args = ap.parse_args(argv)

    if args.cmd == "faults":
        out = {"catalog": {name: dict(meta) for name, meta in CATALOG.items()}}
        if args.spec is not None:
            try:
                n = install_env_faults(args.spec)
                from repro.guard import active_faults, clear_faults

                out["spec"] = {"armed": n, "faults": [
                    {"point": f.point, "times": f.times, "every": f.every,
                     "delay_sec": f.delay_sec, "hang": f.hang,
                     "raises": f.raises, "where": f.where}
                    for f in active_faults().values()]}
                clear_faults()
            except Exception as e:  # noqa: BLE001 — validation must report
                print(json.dumps({"error": repr(e)}, indent=2))
                return 1
        print(json.dumps(out, indent=2))
        return 0

    store = TuningStore(args.store)

    if args.cmd == "status":
        quars = store.quarantines()
        drift = [q for q in quars if q["reason"].startswith("drift:")]
        out = {
            "quarantines": len(quars),
            "drift_quarantines": drift,
            "other_quarantines": [q for q in quars if q not in drift],
            "baseline_keys": len(_baselines(store)),
        }
        if args.obs:
            snaps = _read_snapshots(args.obs)
            out["obs_snapshots"] = len(snaps)
            if snaps:
                latest = snaps[-1].get("snapshot", snaps[-1])
                out["guard_counters"] = guard_counters(latest)
        print(json.dumps(out, indent=2))
        return 0

    # replay
    snaps = _read_snapshots(args.obs)
    decisions = replay_decisions(snaps, _baselines(store), _policy(args))
    print(json.dumps({
        "snapshots": len(snaps),
        "windows": max(0, len(snaps) - 1),
        "policy": {"drift_factor": args.drift_factor,
                   "hysteresis": args.hysteresis,
                   "cooldown_sec": args.cooldown,
                   "min_samples": args.min_samples},
        "decisions": decisions,
    }, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
