"""repro.launch — mesh construction, dry-run, training/serving/autotuning CLIs."""

from repro.launch.mesh import make_mesh_from_plan, make_production_mesh

__all__ = ["make_mesh_from_plan", "make_production_mesh"]
