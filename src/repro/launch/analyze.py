"""repro-analyze — static analysis CLI (see :mod:`repro.analyze`).

    # audit every kernel's config space against the canonical shape table:
    # what fraction of sampled configs is statically infeasible (errors) or
    # pathological (warnings, e.g. the Floyd-Warshall-style padding blowup)?
    python -m repro.launch.analyze space [--kernel K] [--samples N] \
        [--json] [--out FILE]

    # concurrency lint over the codebase (lock order, guarded mutations,
    # monotonic clocks, daemon threads); non-zero exit when findings exceed
    # --max-findings — the CI gate
    python -m repro.launch.analyze lint [PATH ...] [--max-findings N] [--json]

Both commands print JSON with ``--json``; ``space --out FILE`` additionally
writes the audit next to the BENCH artifacts for CI upload.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np


def _default_lint_paths() -> list[str]:
    import repro

    return [os.path.dirname(os.path.abspath(repro.__file__))]


def cmd_lint(args) -> int:
    from repro.analyze.lint import lint_paths

    paths = args.paths or _default_lint_paths()
    findings = lint_paths(paths)
    if args.json:
        print(json.dumps({
            "paths": paths,
            "n_findings": len(findings),
            "max_findings": args.max_findings,
            "findings": [f.to_dict() for f in findings],
        }, indent=2))
    else:
        for f in findings:
            print(f.format())
        print(f"{len(findings)} finding(s) "
              f"(budget: {args.max_findings})")
    return 0 if len(findings) <= args.max_findings else 1


def _audit_kernel(kernel: str, target: str, dims: tuple, samples: int,
                  seed: int) -> dict:
    from repro.analyze.feasibility import check_config
    from repro.kernels.spaces import kernel_space

    space = kernel_space(
        kernel, target="host" if target == "host" else "tpu", seed=seed)
    rng = np.random.default_rng(seed)
    cfgs = [space.default_configuration()]
    cfgs += space.sample_configurations(samples, rng)
    n_error = n_warn = 0
    codes: dict[str, int] = {}
    for cfg in cfgs:
        verdict = check_config(kernel, cfg, dims=dims, target=target)
        if not verdict.ok:
            n_error += 1
        elif verdict.warnings:
            n_warn += 1
        for f in verdict.findings:
            codes[f.code] = codes.get(f.code, 0) + 1
    n = len(cfgs)
    return {
        "kernel": kernel,
        "target": target,
        "dims": list(dims),
        "n_sampled": n,
        "n_infeasible": n_error,
        "n_pathological": n_warn,
        "infeasible_fraction": round(n_error / n, 4),
        "pathological_fraction": round(n_warn / n, 4),
        "codes": dict(sorted(codes.items(), key=lambda kv: -kv[1])),
    }


def cmd_space(args) -> int:
    from repro.kernels.problems import (
        BENCH_DIMS,
        LARGE_SHAPES,
        fidelity_readiness,
    )

    kernels = [args.kernel] if args.kernel else sorted(BENCH_DIMS)
    rows = []
    for kernel in kernels:
        # host spaces at bench dims (backend B1), TPU spaces at the paper's
        # LARGE dims under the analytic cost model (backend B2)
        rows.append(_audit_kernel(kernel, "host", BENCH_DIMS[kernel],
                                  args.samples, args.seed))
        rows.append(_audit_kernel(kernel, "cost", LARGE_SHAPES[kernel],
                                  args.samples, args.seed))
    # cost-model coverage (repro.fidelity): a dispatch-registered kernel
    # without a cost-model entry cannot screen on the cascade's analytic
    # rung — surface it as a reviewable fact, machine-readable per kernel
    coverage = fidelity_readiness()
    for r in rows:
        r["fidelity_ready"] = coverage.get(r["kernel"], False)
    out = {"samples_per_space": args.samples, "seed": args.seed, "audit": rows,
           "fidelity": {
               "coverage": coverage,
               "missing_cost_model": sorted(
                   k for k, ok in coverage.items() if not ok),
           }}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump(out, fh, indent=2)
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        hdr = (f"{'kernel':<16} {'target':<6} {'infeasible':>10} "
               f"{'pathological':>12} {'fidelity':>8}  top codes")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            top = ", ".join(f"{c}({n})" for c, n
                            in list(r["codes"].items())[:3]) or "-"
            ready = "ready" if r["fidelity_ready"] else "NO-COST"
            print(f"{r['kernel']:<16} {r['target']:<6} "
                  f"{r['infeasible_fraction']:>9.1%} "
                  f"{r['pathological_fraction']:>11.1%} {ready:>8}  {top}")
        missing = out["fidelity"]["missing_cost_model"]
        if missing:
            print(f"fidelity: {len(missing)} dispatch-registered kernel(s) "
                  f"lack a cost model: {', '.join(missing)}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-analyze", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("space", help="config-space feasibility audit")
    sp.add_argument("--kernel", default=None,
                    help="audit one kernel (default: all registered)")
    sp.add_argument("--samples", type=int, default=512,
                    help="sampled configs per (kernel, target) space")
    sp.add_argument("--seed", type=int, default=1234)
    sp.add_argument("--json", action="store_true",
                    help="print the full audit as JSON")
    sp.add_argument("--out", default=None, metavar="FILE",
                    help="also write the JSON audit to FILE (CI artifact)")
    sp.set_defaults(fn=cmd_space)

    lp = sub.add_parser("lint", help="concurrency lint (REP101-REP104)")
    lp.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: the repro package)")
    lp.add_argument("--max-findings", type=int, default=0,
                    help="max findings before a non-zero exit (CI gate)")
    lp.add_argument("--json", action="store_true")
    lp.set_defaults(fn=cmd_lint)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
