"""Crash-safe JSONL primitives shared by the performance database and the
dispatch tuning store.

The failure mode both care about: a writer dies mid-append, leaving a torn
(newline-less) final line. A later append must not concatenate onto that
tail — it would merge two records into one unparseable line and silently
lose both. :func:`repair_torn_tail` terminates the tail so the torn fragment
becomes an isolated invalid line that loaders can skip, and every append
stays line-delimited.
"""

from __future__ import annotations

import json
import os
from typing import Any

__all__ = ["repair_torn_tail", "append_jsonl"]


def repair_torn_tail(path: str) -> bool:
    """Terminate a torn final line with a newline. Returns True on repair.
    Call before appending to (or after crash-loading) a JSONL file."""
    if not os.path.exists(path) or os.path.getsize(path) == 0:
        return False
    with open(path, "rb+") as f:
        f.seek(-1, os.SEEK_END)
        if f.read(1) == b"\n":
            return False
        f.write(b"\n")
        return True


def append_jsonl(path: str, obj: Any, fsync: bool = False) -> int:
    """Append one JSON object as one line; returns bytes written."""
    line = json.dumps(obj) + "\n"
    with open(path, "a") as f:
        f.write(line)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    return len(line.encode())
