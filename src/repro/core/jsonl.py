"""Crash-safe JSONL primitives shared by the performance database and the
dispatch tuning store.

The failure mode both care about: a writer dies mid-append, leaving a torn
(newline-less) final line. A later append must not concatenate onto that
tail — it would merge two records into one unparseable line and silently
lose both. :func:`repair_torn_tail` terminates the tail so the torn fragment
becomes an isolated invalid line that loaders can skip, and every append
stays line-delimited.
"""

from __future__ import annotations

import json
import os
from typing import Any, Iterator

# guard.faults is stdlib-only (and repro.guard's __init__ is lazy), so this
# bottom-layer module can host the torn-write chaos point without a cycle
from repro.guard.faults import FaultInjected, fault_hit

__all__ = ["repair_torn_tail", "append_jsonl", "iter_jsonl_tail"]


def repair_torn_tail(path: str) -> bool:
    """Terminate a torn final line with a newline. Returns True on repair.
    Call before appending to (or after crash-loading) a JSONL file."""
    if not os.path.exists(path) or os.path.getsize(path) == 0:
        return False
    with open(path, "rb+") as f:
        f.seek(-1, os.SEEK_END)
        if f.read(1) == b"\n":
            return False
        f.write(b"\n")
        return True


def append_jsonl(path: str, obj: Any, fsync: bool = False) -> int:
    """Append one JSON object as one line; returns bytes written."""
    line = json.dumps(obj) + "\n"
    _maybe_tear(path, line)
    with open(path, "a") as f:
        f.write(line)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    return len(line.encode())


def _maybe_tear(path: str, line: str) -> None:
    """The ``store.torn_write`` chaos fault: when armed (repro.guard.faults),
    simulate a writer dying mid-append — half the line lands on disk with no
    newline, then the writer "crashes". Every durable-log append in the tree
    funnels through :func:`append_jsonl`, so one injection point covers the
    tuning store, the fleet oplog, and the obs snapshot log."""
    if fault_hit("store.torn_write", path=path) is None:
        return
    with open(path, "a") as f:
        f.write(line[: max(1, len(line) // 2)])
        f.flush()
    raise FaultInjected(f"store.torn_write: died mid-append to {path}")


def iter_jsonl_tail(path: str, offset: int) -> Iterator[tuple[Any, int]]:
    """Tail complete JSONL lines from byte ``offset``: yields
    ``(obj, end_offset)`` per line — ``obj`` is None for a blank or
    unparseable line (its bytes still advance the offset) — and stops
    *before* a torn final line, so a writer mid-append is retried at the
    caller's next tail. A missing file yields nothing.

    This is the one incremental-reader loop shared by the tuning store, the
    fleet oplog, and the fleet file transport; the subtleties (advance by
    encoded byte length before stripping, never step past a newline-less
    tail) live here exactly once."""
    if not os.path.exists(path):
        return
    with open(path) as f:
        f.seek(offset)
        for line in f:
            if not line.endswith("\n"):
                return
            offset += len(line.encode())
            line = line.strip()
            if not line:
                yield None, offset
                continue
            try:
                yield json.loads(line), offset
            except json.JSONDecodeError:
                yield None, offset
