"""ConfigurationSpace: structured parameter spaces for the autotuner.

This is the ConfigSpace analog the paper builds its ``input_space`` from
(Sec. 4.1): categorical hyperparameters (pragma on/off choices), ordinal
hyperparameters (tile-size sequences), and algebraic conditions between them
(``CS.InCondition`` — e.g. "pack array B only when array A is packed").

Configurations are plain ``dict``s mapping parameter name -> value. Parameters
deactivated by an unsatisfied condition are *absent* from the dict; feature
encoding maps them to a dedicated "inactive" slot so surrogate models can learn
across the hierarchy.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

__all__ = [
    "Categorical",
    "Ordinal",
    "Integer",
    "Float",
    "Constant",
    "EqualsCondition",
    "InCondition",
    "ForbiddenClause",
    "ConfigurationSpace",
    "config_key",
]


# ---------------------------------------------------------------------------
# Hyperparameter kinds
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Categorical:
    """Unordered finite choice (the paper's pragma-or-nothing parameters)."""

    name: str
    choices: tuple
    default: Any = None

    def __post_init__(self):
        object.__setattr__(self, "choices", tuple(self.choices))
        if len(set(self.choices)) != len(self.choices):
            raise ValueError(f"{self.name}: duplicate choices")
        if self.default is None:
            object.__setattr__(self, "default", self.choices[0])
        if self.default not in self.choices:
            raise ValueError(f"{self.name}: default {self.default!r} not a choice")

    @property
    def size(self) -> int:
        return len(self.choices)

    def sample(self, rng: np.random.Generator):
        return self.choices[int(rng.integers(len(self.choices)))]

    def sample_quantile(self, q: float):
        idx = min(int(q * len(self.choices)), len(self.choices) - 1)
        return self.choices[idx]

    def validate(self, value) -> bool:
        return value in self.choices

    # feature encoding: one-hot over choices (+1 inactive slot added by space)
    def n_features(self) -> int:
        return len(self.choices)

    def encode(self, value) -> np.ndarray:
        out = np.zeros(len(self.choices))
        out[self.choices.index(value)] = 1.0
        return out


@dataclasses.dataclass(frozen=True)
class Ordinal:
    """Ordered finite sequence (the paper's 11-entry tile-size lists)."""

    name: str
    sequence: tuple
    default: Any = None

    def __post_init__(self):
        object.__setattr__(self, "sequence", tuple(self.sequence))
        if len(set(self.sequence)) != len(self.sequence):
            raise ValueError(f"{self.name}: duplicate sequence entries")
        if self.default is None:
            object.__setattr__(self, "default", self.sequence[0])
        if self.default not in self.sequence:
            raise ValueError(f"{self.name}: default {self.default!r} not in sequence")

    @property
    def size(self) -> int:
        return len(self.sequence)

    def sample(self, rng: np.random.Generator):
        return self.sequence[int(rng.integers(len(self.sequence)))]

    def sample_quantile(self, q: float):
        idx = min(int(q * len(self.sequence)), len(self.sequence) - 1)
        return self.sequence[idx]

    def validate(self, value) -> bool:
        return value in self.sequence

    def n_features(self) -> int:
        return 1

    def encode(self, value) -> np.ndarray:
        # normalized rank keeps the *order* information (tile sizes are ordered)
        rank = self.sequence.index(value)
        return np.array([rank / max(len(self.sequence) - 1, 1)])


@dataclasses.dataclass(frozen=True)
class Integer:
    """Uniform (optionally log-uniform) integer range, inclusive bounds."""

    name: str
    low: int
    high: int
    default: int | None = None
    log: bool = False

    def __post_init__(self):
        if self.low > self.high:
            raise ValueError(f"{self.name}: low > high")
        if self.log and self.low <= 0:
            raise ValueError(f"{self.name}: log scale requires low > 0")
        if self.default is None:
            object.__setattr__(self, "default", self.low)
        if not (self.low <= self.default <= self.high):
            raise ValueError(f"{self.name}: default outside range")

    @property
    def size(self) -> int:
        return self.high - self.low + 1

    def sample(self, rng: np.random.Generator):
        if self.log:
            lo, hi = math.log(self.low), math.log(self.high + 1)
            return int(min(self.high, math.floor(math.exp(rng.uniform(lo, hi)))))
        return int(rng.integers(self.low, self.high + 1))

    def sample_quantile(self, q: float):
        if self.log:
            lo, hi = math.log(self.low), math.log(self.high + 1)
            return int(min(self.high, math.floor(math.exp(lo + q * (hi - lo)))))
        return int(min(self.high, self.low + math.floor(q * (self.high - self.low + 1))))

    def validate(self, value) -> bool:
        return isinstance(value, (int, np.integer)) and self.low <= value <= self.high

    def n_features(self) -> int:
        return 1

    def encode(self, value) -> np.ndarray:
        if self.log:
            lo, hi = math.log(self.low), math.log(self.high)
            x = (math.log(max(value, self.low)) - lo) / max(hi - lo, 1e-12)
        else:
            x = (value - self.low) / max(self.high - self.low, 1e-12)
        return np.array([x])


@dataclasses.dataclass(frozen=True)
class Float:
    """Uniform (optionally log-uniform) float range."""

    name: str
    low: float
    high: float
    default: float | None = None
    log: bool = False

    def __post_init__(self):
        if self.low > self.high:
            raise ValueError(f"{self.name}: low > high")
        if self.default is None:
            object.__setattr__(self, "default", self.low)

    @property
    def size(self) -> float:
        return math.inf

    def sample(self, rng: np.random.Generator):
        if self.log:
            return float(math.exp(rng.uniform(math.log(self.low), math.log(self.high))))
        return float(rng.uniform(self.low, self.high))

    def sample_quantile(self, q: float):
        if self.log:
            lo, hi = math.log(self.low), math.log(self.high)
            return float(math.exp(lo + q * (hi - lo)))
        return float(self.low + q * (self.high - self.low))

    def validate(self, value) -> bool:
        return self.low <= value <= self.high

    def n_features(self) -> int:
        return 1

    def encode(self, value) -> np.ndarray:
        if self.log:
            lo, hi = math.log(self.low), math.log(self.high)
            return np.array([(math.log(value) - lo) / max(hi - lo, 1e-12)])
        return np.array([(value - self.low) / max(self.high - self.low, 1e-12)])


@dataclasses.dataclass(frozen=True)
class Constant:
    name: str
    value: Any

    @property
    def default(self):
        return self.value

    @property
    def size(self) -> int:
        return 1

    def sample(self, rng):
        return self.value

    def sample_quantile(self, q):
        return self.value

    def validate(self, value) -> bool:
        return value == self.value

    def n_features(self) -> int:
        return 0

    def encode(self, value) -> np.ndarray:
        return np.zeros(0)


Hyperparameter = Categorical | Ordinal | Integer | Float | Constant


# ---------------------------------------------------------------------------
# Conditions & forbidden clauses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InCondition:
    """``child`` is active only when ``parent``'s value is in ``values``.

    Mirrors ``CS.InCondition`` from the paper's syr2k space: packing B is only
    considered when A is packed.
    """

    child: str
    parent: str
    values: tuple

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))

    def satisfied(self, config: Mapping[str, Any]) -> bool:
        return config.get(self.parent) in self.values


def EqualsCondition(child: str, parent: str, value) -> InCondition:
    return InCondition(child, parent, (value,))


@dataclasses.dataclass(frozen=True)
class ForbiddenClause:
    """Reject configurations for which ``predicate(config)`` is True."""

    predicate: Callable[[Mapping[str, Any]], bool]
    description: str = ""

    def violated(self, config: Mapping[str, Any]) -> bool:
        return bool(self.predicate(config))


# ---------------------------------------------------------------------------
# ConfigurationSpace
# ---------------------------------------------------------------------------


def config_key(config: Mapping[str, Any]) -> tuple:
    """Canonical hashable identity of a configuration (for the perf DB)."""
    return tuple(sorted((k, repr(v)) for k, v in config.items()))


class ConfigurationSpace:
    """A structured space with conditional activation, seeded like the paper's
    ``CS.ConfigurationSpace(seed=1234)``."""

    def __init__(self, seed: int = 1234):
        self._params: dict[str, Hyperparameter] = {}
        self._conditions: list[InCondition] = []
        self._forbidden: list[ForbiddenClause] = []
        self._rng = np.random.default_rng(seed)
        self.seed = seed
        # structure caches (the sampler/encoder hot path walks these per
        # config): invalidated whenever a parameter or condition is added
        self._topo_cache: list[str] | None = None
        self._conds_for_cache: dict[str, list[InCondition]] | None = None
        self._sorted_names_cache: list[str] | None = None
        self._sample_plan_cache: list | None = None
        self._decl_sorted_cache: bool | None = None

    def _invalidate_structure_caches(self) -> None:
        self._topo_cache = None
        self._conds_for_cache = None
        self._sorted_names_cache = None
        self._sample_plan_cache = None
        self._decl_sorted_cache = None

    def _sorted_names(self) -> list[str]:
        if self._sorted_names_cache is None:
            self._sorted_names_cache = sorted(self._params)
        return self._sorted_names_cache

    def _decl_sorted(self) -> bool:
        if self._decl_sorted_cache is None:
            self._decl_sorted_cache = list(self._params) == self._sorted_names()
        return self._decl_sorted_cache

    def _sample_plan(self):
        """Per-parameter draw plan for the sampling hot path: finite choice
        sets (Categorical/Ordinal) inline to ``choices[int(rng.integers(n))]``
        — the identical call on the identical stream, minus the method
        dispatch — Constants skip the rng entirely (as their ``sample``
        does), and everything else keeps its ``sample`` method."""
        plan = self._sample_plan_cache
        if plan is None:
            plan = []
            for name, hp in self._params.items():
                if isinstance(hp, Categorical):
                    plan.append((name, 0, hp.choices))
                elif isinstance(hp, Ordinal):
                    plan.append((name, 0, hp.sequence))
                elif isinstance(hp, Constant):
                    plan.append((name, 1, hp.value))
                else:
                    plan.append((name, 2, hp.sample))
            self._sample_plan_cache = plan
        return plan

    def _draw_raw(self, rng: np.random.Generator) -> dict:
        """One full raw assignment, drawn parameter-by-parameter in
        declaration order — the exact RNG consumption of
        ``{n: hp.sample(rng) for n, hp in self._params.items()}``."""
        ri = rng.integers
        draws = {}
        for name, kind, data in self._sample_plan():
            if kind == 0:
                draws[name] = data[int(ri(len(data)))]
            elif kind == 1:
                draws[name] = data
            else:
                draws[name] = data(rng)
        return draws

    # -- construction -------------------------------------------------------

    def add_hyperparameter(self, hp: Hyperparameter) -> Hyperparameter:
        if hp.name in self._params:
            raise ValueError(f"duplicate hyperparameter {hp.name!r}")
        self._params[hp.name] = hp
        self._invalidate_structure_caches()
        return hp

    def add_hyperparameters(self, hps: Iterable[Hyperparameter]) -> None:
        for hp in hps:
            self.add_hyperparameter(hp)

    def add_condition(self, cond: InCondition) -> None:
        for ref in (cond.child, cond.parent):
            if ref not in self._params:
                raise ValueError(f"condition references unknown parameter {ref!r}")
        if cond.child == cond.parent:
            raise ValueError("self-condition")
        self._conditions.append(cond)
        self._invalidate_structure_caches()

    def add_forbidden(self, clause: ForbiddenClause) -> None:
        self._forbidden.append(clause)

    # -- introspection -------------------------------------------------------

    @property
    def param_names(self) -> list[str]:
        return list(self._params)

    def __getitem__(self, name: str) -> Hyperparameter:
        return self._params[name]

    def __len__(self) -> int:
        return len(self._params)

    def cardinality(self) -> float:
        """Total number of raw grid points (ignoring conditions), as the paper
        reports space sizes (e.g. 2*2*2*11*11*11 = 10,648 for syr2k)."""
        total = 1.0
        for hp in self._params.values():
            total *= hp.size
        return total

    def _conditions_for(self, name: str) -> list[InCondition]:
        cache = self._conds_for_cache
        if cache is None:
            cache = {n: [] for n in self._params}
            for c in self._conditions:
                cache[c.child].append(c)
            self._conds_for_cache = cache
        return cache[name]

    def _topo_order(self) -> list[str]:
        # parents before children so activation can be decided in one pass;
        # memoized — the sampler calls this once per drawn configuration
        if self._topo_cache is not None:
            return self._topo_cache
        order, seen = [], set()

        def visit(name: str, stack: tuple = ()):  # DFS over condition parents
            if name in seen:
                return
            if name in stack:
                raise ValueError(f"condition cycle at {name!r}")
            for c in self._conditions_for(name):
                visit(c.parent, stack + (name,))
            seen.add(name)
            order.append(name)

        for name in self._params:
            visit(name)
        self._topo_cache = order
        return order

    def active_params(self, config: Mapping[str, Any]) -> list[str]:
        """Names of parameters active under ``config``'s parent assignments."""
        active = []
        for name in self._topo_order():
            conds = self._conditions_for(name)
            if all(c.satisfied(config) for c in conds):
                active.append(name)
        return active

    def is_valid(self, config: Mapping[str, Any]) -> bool:
        try:
            self.validate(config)
            return True
        except ValueError:
            return False

    def validate(self, config: Mapping[str, Any]) -> None:
        active = set(self.active_params(config))
        for name in config:
            if name not in self._params:
                raise ValueError(f"unknown parameter {name!r}")
            if name not in active:
                raise ValueError(f"inactive parameter {name!r} present")
        for name in active:
            if name not in config:
                raise ValueError(f"active parameter {name!r} missing")
            if not self._params[name].validate(config[name]):
                raise ValueError(f"invalid value for {name!r}: {config[name]!r}")
        for clause in self._forbidden:
            if clause.violated(config):
                raise ValueError(f"forbidden: {clause.description or clause}")

    # -- sampling ------------------------------------------------------------

    def default_configuration(self) -> dict:
        cfg: dict[str, Any] = {}
        for name in self._topo_order():
            if all(c.satisfied(cfg) for c in self._conditions_for(name)):
                cfg[name] = self._params[name].default
        return dict(sorted(cfg.items()))

    def _finish(self, draws: Mapping[str, Any]) -> dict:
        """Apply conditional activation to a full raw assignment."""
        if not self._conditions:  # unconditional space: every draw is active
            if self._decl_sorted():
                # declaration order is already sorted: the draw dict IS the
                # finished config (same keys, same order)
                return draws if isinstance(draws, dict) else dict(draws)
            return {name: draws[name] for name in self._sorted_names()}
        cfg: dict[str, Any] = {}
        conds_for = self._conditions_for
        for name in self._topo_order():
            if all(c.satisfied(cfg) for c in conds_for(name)):
                cfg[name] = draws[name]
        return dict(sorted(cfg.items()))

    def sample_configuration(self, rng: np.random.Generator | None = None) -> dict:
        rng = rng or self._rng
        forbidden = self._forbidden
        for _ in range(1000):
            cfg = self._finish(self._draw_raw(rng))
            if not forbidden or not any(f.violated(cfg) for f in forbidden):
                return cfg
        raise RuntimeError("forbidden clauses reject every sampled configuration")

    def sample_configurations(self, n: int, rng: np.random.Generator | None = None) -> list[dict]:
        return [self.sample_configuration(rng) for _ in range(n)]

    def latin_hypercube(self, n: int, rng: np.random.Generator | None = None) -> list[dict]:
        """LHS initialization (the paper's alternative init sampler): one
        stratified quantile per parameter per sample, shuffled independently."""
        rng = rng or self._rng
        names = list(self._params)
        # stratified quantiles, independently permuted per dimension
        grid = {}
        for name in names:
            q = (np.arange(n) + rng.uniform(0.0, 1.0, size=n)) / n
            rng.shuffle(q)
            grid[name] = q
        out = []
        for i in range(n):
            draws = {n_: self._params[n_].sample_quantile(float(grid[n_][i])) for n_ in names}
            cfg = self._finish(draws)
            if any(f.violated(cfg) for f in self._forbidden):
                cfg = self.sample_configuration(rng)  # fall back for rare rejects
            out.append(cfg)
        return out

    # -- feature encoding (for surrogate models) ------------------------------

    def n_features(self) -> int:
        total = 0
        for name, hp in self._params.items():
            total += hp.n_features()
            if self._conditions_for(name):
                total += 1  # "inactive" indicator slot
        return total

    def encode(self, config: Mapping[str, Any]) -> np.ndarray:
        """Fixed-length numeric vector; inactive conditionals get a zero block
        plus an inactive-indicator 1."""
        parts = []
        for name, hp in self._params.items():
            conditional = bool(self._conditions_for(name))
            if name in config:
                parts.append(hp.encode(config[name]))
                if conditional:
                    parts.append(np.zeros(1))
            else:
                parts.append(np.zeros(hp.n_features()))
                if conditional:
                    parts.append(np.ones(1))
        return np.concatenate(parts) if parts else np.zeros(0)

    def encode_many(self, configs: Sequence[Mapping[str, Any]]) -> np.ndarray:
        """Batch feature encoding: one (n, n_features) array filled
        column-block by column-block per parameter, instead of n per-config
        ``encode`` calls each concatenating a dozen small arrays. Row values
        are identical to ``encode`` — the same per-element arithmetic, just
        applied across the batch (log-scaled parameters keep their scalar
        ``math.log`` path so not even the last ulp moves)."""
        n = len(configs)
        if not n:
            return np.zeros((0, self.n_features()))
        out = np.zeros((n, self.n_features()))
        col = 0
        for name, hp in self._params.items():
            w = hp.n_features()
            if w:
                present = np.fromiter((name in c for c in configs), bool, count=n)
                rows = np.flatnonzero(present)
                if len(rows):
                    vals = [configs[i][name] for i in rows]
                    if isinstance(hp, Categorical):
                        ch = hp.choices.index
                        out[rows, col + np.fromiter((ch(v) for v in vals),
                                                    np.int64, count=len(rows))] = 1.0
                    elif isinstance(hp, Ordinal):
                        sq = hp.sequence.index
                        ranks = np.fromiter((sq(v) for v in vals),
                                            np.float64, count=len(rows))
                        out[rows, col] = ranks / max(len(hp.sequence) - 1, 1)
                    elif isinstance(hp, (Integer, Float)) and not hp.log:
                        arr = np.fromiter(vals, np.float64, count=len(rows))
                        out[rows, col] = (arr - hp.low) / max(hp.high - hp.low, 1e-12)
                    else:  # log-scaled (math.log semantics) or exotic kinds
                        for i, v in zip(rows, vals):
                            out[i, col:col + w] = hp.encode(v)
            col += w
            if self._conditions_for(name):
                # inactive conditionals get their indicator slot set
                for i, c in enumerate(configs):
                    if name not in c:
                        out[i, col] = 1.0
                col += 1
        return out

    # -- neighborhood (for local perturbation in the search) ------------------

    def mutate(self, config: Mapping[str, Any], rng: np.random.Generator | None = None) -> dict:
        """Perturb one active parameter; re-resolve activation."""
        rng = rng or self._rng
        draws = self._draw_raw(rng)
        draws.update({k: v for k, v in config.items()})
        active = [n for n in config if self._params[n].size > 1]
        if active:
            victim = active[int(rng.integers(len(active)))]
            hp = self._params[victim]
            for _ in range(20):
                new = hp.sample(rng)
                if new != config.get(victim):
                    break
            draws[victim] = new
        cfg = self._finish(draws)
        if any(f.violated(cfg) for f in self._forbidden):
            return self.sample_configuration(rng)
        return cfg
