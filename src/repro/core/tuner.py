"""High-level autotuning API: the framework's user-facing entry point.

``autotune()`` wires a ConfigurationSpace + evaluator + learner into a full
:class:`repro.engine.Campaign` (the paper's --max-evals / --learner CLI
options map 1:1, plus ``parallel`` for batched concurrent evaluation), and
``compare_learners()`` runs the paper's four-learner study.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping

from repro.core.plopper import EvalResult
from repro.core.search import SearchResult, run_search
from repro.core.space import ConfigurationSpace
from repro.core.surrogates import LEARNERS

__all__ = ["autotune", "compare_learners"]


def autotune(
    space: ConfigurationSpace,
    evaluator: Callable[[Mapping[str, Any]], EvalResult],
    max_evals: int = 100,
    learner: str = "RF",
    seed: int = 1234,
    db_path: str | None = None,
    parallel: int = 1,
    **kw,
) -> SearchResult:
    """Run one autotuning campaign. ``learner`` in {RF, ET, GBRT, GP} (paper
    default: RF); ``max_evals`` is the paper's -max-evals (default 100).
    ``parallel`` > 1 keeps that many evaluations in flight (constant-liar
    batching over a thread pool; the evaluator must be thread-safe);
    ``parallel=1`` is the paper's serial loop, bit-for-bit."""
    return run_search(
        space, evaluator, max_evals=max_evals, learner=learner, seed=seed,
        db_path=db_path, parallel=parallel, **kw,
    )


def compare_learners(
    space: ConfigurationSpace,
    evaluator: Callable[[Mapping[str, Any]], EvalResult],
    max_evals: int = 100,
    learners: tuple[str, ...] = LEARNERS,
    seed: int = 1234,
    db_root: str | None = None,
    **kw,
) -> dict[str, SearchResult]:
    """The paper's Sec. 4 methodology: run the same campaign under each of the
    four surrogate models and compare best objective / eval-found-at."""
    out: dict[str, SearchResult] = {}
    for learner in learners:
        db_path = f"{db_root}/{learner}" if db_root else None
        out[learner] = autotune(
            space, evaluator, max_evals=max_evals, learner=learner, seed=seed,
            db_path=db_path, **kw,
        )
    return out
