"""findMin.py (Sec. 2.3 step 8): mine the performance database for the best
configuration and report it."""

from __future__ import annotations

import json
import os
import sys

from repro.core.database import PerformanceDatabase, Record

__all__ = ["find_min", "load_database", "main"]


def load_database(db_path: str) -> PerformanceDatabase:
    return PerformanceDatabase(db_path)


def find_min(db: PerformanceDatabase) -> Record | None:
    return db.best()


def importance_report(db: PerformanceDatabase, top: int = 5) -> list[tuple[str, float]]:
    """Step 9's 'identify the most important features': rank parameters by the
    spread of mean objective across their observed values (one-way effect)."""
    recs = db.evaluated()
    if not recs:
        return []
    names = sorted({k for r in recs for k in r.config})
    scores = []
    for name in names:
        by_value: dict = {}
        for r in recs:
            by_value.setdefault(repr(r.config.get(name)), []).append(r.objective)
        means = [sum(v) / len(v) for v in by_value.values() if v]
        if len(means) > 1:
            scores.append((name, max(means) - min(means)))
    scores.sort(key=lambda kv: -kv[1])
    return scores[:top]


def main(argv: list[str] | None = None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        print("usage: python -m repro.core.findmin <db_dir>", file=sys.stderr)
        return 2
    db_path = argv[0]
    if not os.path.isdir(db_path):
        print(f"no such database directory: {db_path}", file=sys.stderr)
        return 2
    db = load_database(db_path)
    best = find_min(db)
    if best is None:
        print("database holds no successful evaluations")
        return 1
    print(json.dumps({
        "best_objective": best.objective,
        "at_evaluation": best.index,
        "config": best.config,
        "n_records": len(db),
        "importance": importance_report(db),
    }, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
