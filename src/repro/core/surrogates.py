"""The paper's four supervised learners, implemented from scratch on numpy.

ytopt (via scikit-optimize) offers Random Forests (RF, the default), Extra
Trees (ET), Gradient-Boosted Regression Trees (GBRT), and Gaussian Processes
(GP) as Bayesian-optimization surrogates. No sklearn exists in this container,
so we implement the four models directly; each exposes

    fit(X, y)                      X: (n, d) float array, y: (n,)
    predict(X) -> (mu, sigma)      per-point mean and uncertainty

Uncertainty sources mirror scikit-optimize's choices:
  * RF / ET  — spread across ensemble members,
  * GBRT     — three quantile-loss ensembles (0.16 / 0.50 / 0.84),
  * GP       — exact posterior variance (RBF kernel + noise, Cholesky).

All fits at autotuning scale (n <= a few hundred, d <= ~100) are millisecond-
level, so clarity wins over micro-optimization.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "RegressionTree",
    "RandomForest",
    "ExtraTrees",
    "GradientBoostedTrees",
    "GaussianProcess",
    "make_learner",
    "LEARNERS",
]


# ---------------------------------------------------------------------------
# CART regression tree (variance-reduction splits)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: "_Node | None" = None
    right: "_Node | None" = None
    value: float = 0.0
    is_leaf: bool = True


class RegressionTree:
    """CART with MSE (variance-reduction) splits.

    ``splitter='best'`` scans candidate thresholds per feature (RF / GBRT);
    ``splitter='random'`` draws one uniform threshold per feature (Extra Trees).
    """

    def __init__(
        self,
        max_depth: int = 12,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_features: float | str | None = None,
        splitter: str = "best",
        rng: np.random.Generator | None = None,
    ):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.splitter = splitter
        self.rng = rng or np.random.default_rng(0)
        self.root: _Node | None = None

    # -- fitting --------------------------------------------------------------

    def _n_features_to_try(self, d: int) -> int:
        mf = self.max_features
        if mf is None or mf == 1.0:
            return d
        if mf == "sqrt":
            return max(1, int(np.sqrt(d)))
        if mf == "log2":
            return max(1, int(np.log2(d))) if d > 1 else 1
        if isinstance(mf, float):
            return max(1, int(mf * d))
        return d

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self.root = self._build(X, y, depth=0)
        return self

    def _build(self, X: np.ndarray, y: np.ndarray, depth: int) -> _Node:
        node = _Node(value=float(y.mean()), is_leaf=True)
        n, d = X.shape
        if (
            depth >= self.max_depth
            or n < self.min_samples_split
            or n < 2 * self.min_samples_leaf
            or np.allclose(y, y[0])
        ):
            return node

        feats = self.rng.permutation(d)[: self._n_features_to_try(d)]
        best = None  # (score, feature, threshold, mask)
        for f in feats:
            col = X[:, f]
            lo, hi = col.min(), col.max()
            if lo == hi:
                continue
            if self.splitter == "random":
                thresholds = [self.rng.uniform(lo, hi)]
            else:
                uniq = np.unique(col)
                mids = (uniq[1:] + uniq[:-1]) / 2.0
                if len(mids) > 32:  # cap threshold scan; plenty at tuning scale
                    mids = mids[np.linspace(0, len(mids) - 1, 32).astype(int)]
                thresholds = mids
            for t in thresholds:
                mask = col <= t
                nl = int(mask.sum())
                nr = n - nl
                if nl < self.min_samples_leaf or nr < self.min_samples_leaf:
                    continue
                yl, yr = y[mask], y[~mask]
                score = nl * yl.var() + nr * yr.var()  # SSE up to constants
                if best is None or score < best[0]:
                    best = (score, f, t, mask)

        if best is None:
            return node
        _, f, t, mask = best
        node.is_leaf = False
        node.feature = int(f)
        node.threshold = float(t)
        node.left = self._build(X[mask], y[mask], depth + 1)
        node.right = self._build(X[~mask], y[~mask], depth + 1)
        return node

    # -- prediction -------------------------------------------------------------

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.empty(len(X))
        for i, x in enumerate(X):
            node = self.root
            while not node.is_leaf:
                node = node.left if x[node.feature] <= node.threshold else node.right
            out[i] = node.value
        return out


# ---------------------------------------------------------------------------
# Random Forest / Extra Trees
# ---------------------------------------------------------------------------


class RandomForest:
    """Bagged CART ensemble; sigma = std across member predictions."""

    name = "RF"
    bootstrap = True
    splitter = "best"
    max_features: float | str = "sqrt"

    def __init__(self, n_estimators: int = 32, max_depth: int = 12, seed: int = 0,
                 min_samples_leaf: int = 1):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.rng = np.random.default_rng(seed)
        self.trees: list[RegressionTree] = []

    def fit(self, X: np.ndarray, y: np.ndarray):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = len(X)
        self.trees = []
        for _ in range(self.n_estimators):
            if self.bootstrap:
                idx = self.rng.integers(0, n, size=n)
            else:
                idx = np.arange(n)
            tree = RegressionTree(
                max_depth=self.max_depth,
                max_features=self.max_features,
                splitter=self.splitter,
                min_samples_leaf=self.min_samples_leaf,
                rng=np.random.default_rng(int(self.rng.integers(2**31))),
            )
            tree.fit(X[idx], y[idx])
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        preds = np.stack([t.predict(X) for t in self.trees])  # (T, n)
        mu = preds.mean(axis=0)
        sigma = preds.std(axis=0) + 1e-9
        return mu, sigma


class ExtraTrees(RandomForest):
    """Extremely-randomized trees: no bootstrap, random split thresholds."""

    name = "ET"
    bootstrap = False
    splitter = "random"
    max_features = 1.0


# ---------------------------------------------------------------------------
# Gradient-boosted regression trees with quantile loss
# ---------------------------------------------------------------------------


class _QuantileGBT:
    """One boosted ensemble minimizing pinball loss at quantile ``alpha``."""

    def __init__(self, alpha: float, n_estimators: int, lr: float, max_depth: int, seed: int):
        self.alpha = alpha
        self.n_estimators = n_estimators
        self.lr = lr
        self.max_depth = max_depth
        self.rng = np.random.default_rng(seed)
        self.base = 0.0
        self.trees: list[RegressionTree] = []

    def fit(self, X, y):
        self.base = float(np.quantile(y, self.alpha))
        pred = np.full(len(y), self.base)
        self.trees = []
        for _ in range(self.n_estimators):
            resid = y - pred
            # negative gradient of pinball loss
            grad = np.where(resid > 0, self.alpha, self.alpha - 1.0)
            tree = RegressionTree(
                max_depth=self.max_depth,
                rng=np.random.default_rng(int(self.rng.integers(2**31))),
            )
            tree.fit(X, grad)
            # line-search-free step (standard GBM-with-quantile shortcut):
            # refit leaf values to the quantile of residuals they cover
            self._requantile_leaves(tree.root, X, resid, np.arange(len(y)))
            step = tree.predict(X)
            pred = pred + self.lr * step
            self.trees.append(tree)
        return self

    def _requantile_leaves(self, node: _Node, X, resid, idx):
        if node.is_leaf:
            node.value = float(np.quantile(resid[idx], self.alpha)) if len(idx) else 0.0
            return
        mask = X[idx, node.feature] <= node.threshold
        self._requantile_leaves(node.left, X, resid, idx[mask])
        self._requantile_leaves(node.right, X, resid, idx[~mask])

    def predict(self, X):
        out = np.full(len(X), self.base)
        for tree in self.trees:
            out = out + self.lr * tree.predict(X)
        return out


class GradientBoostedTrees:
    """skopt-style GBRT surrogate: quantile ensembles at 0.16 / 0.50 / 0.84."""

    name = "GBRT"

    def __init__(self, n_estimators: int = 64, lr: float = 0.15, max_depth: int = 4, seed: int = 0):
        self.models = {
            a: _QuantileGBT(a, n_estimators, lr, max_depth, seed + i)
            for i, a in enumerate((0.16, 0.50, 0.84))
        }

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        for m in self.models.values():
            m.fit(X, y)
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        lo = self.models[0.16].predict(X)
        mid = self.models[0.50].predict(X)
        hi = self.models[0.84].predict(X)
        sigma = np.maximum((hi - lo) / 2.0, 1e-9)
        return mid, sigma


# ---------------------------------------------------------------------------
# Gaussian process (RBF + white noise, exact Cholesky inference)
# ---------------------------------------------------------------------------


class GaussianProcess:
    """Exact GP regression; length-scale picked by marginal likelihood over a
    small log grid (no gradient optimizer needed at n<=500)."""

    name = "GP"

    def __init__(self, length_scales=(0.1, 0.2, 0.5, 1.0, 2.0, 5.0), noise: float = 1e-4,
                 seed: int = 0):
        self.length_scales = tuple(length_scales)
        self.noise = noise
        self._X = None
        self._alpha = None
        self._L = None
        self._ls = 1.0
        self._amp = 1.0
        self._ymean = 0.0
        self._ystd = 1.0

    @staticmethod
    def _k(X1, X2, ls):
        d2 = ((X1[:, None, :] - X2[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / (ls * ls))

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self._ymean = float(y.mean())
        self._ystd = float(y.std()) or 1.0
        yn = (y - self._ymean) / self._ystd
        n = len(X)
        best = None
        for ls in self.length_scales:
            K = self._k(X, X, ls) + (self.noise + 1e-10) * np.eye(n)
            try:
                L = np.linalg.cholesky(K)
            except np.linalg.LinAlgError:
                continue
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
            # log marginal likelihood (up to constants)
            lml = -0.5 * yn @ alpha - np.log(np.diag(L)).sum()
            if best is None or lml > best[0]:
                best = (lml, ls, L, alpha)
        if best is None:  # fully degenerate data
            ls = self.length_scales[-1]
            K = self._k(X, X, ls) + 1e-2 * np.eye(n)
            L = np.linalg.cholesky(K)
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
            best = (0.0, ls, L, alpha)
        _, self._ls, self._L, self._alpha = best
        self._X = X
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        Ks = self._k(X, self._X, self._ls)  # (m, n)
        mu = Ks @ self._alpha
        v = np.linalg.solve(self._L, Ks.T)  # (n, m)
        var = np.maximum(1.0 - (v**2).sum(axis=0), 1e-12)
        return mu * self._ystd + self._ymean, np.sqrt(var) * self._ystd + 1e-9


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

LEARNERS = ("RF", "ET", "GBRT", "GP")


def make_learner(name: str, seed: int = 0):
    name = name.upper()
    if name == "RF":
        return RandomForest(seed=seed)
    if name == "ET":
        return ExtraTrees(seed=seed)
    if name == "GBRT":
        return GradientBoostedTrees(seed=seed)
    if name == "GP":
        return GaussianProcess(seed=seed)
    raise ValueError(f"unknown learner {name!r}; options: {LEARNERS}")
