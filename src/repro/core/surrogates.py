"""The paper's four supervised learners, implemented from scratch on numpy.

ytopt (via scikit-optimize) offers Random Forests (RF, the default), Extra
Trees (ET), Gradient-Boosted Regression Trees (GBRT), and Gaussian Processes
(GP) as Bayesian-optimization surrogates. No sklearn is used in this repo,
so we implement the four models directly; each exposes

    fit(X, y)                      X: (n, d) float array, y: (n,)
    predict(X) -> (mu, sigma)      per-point mean and uncertainty

Uncertainty sources mirror scikit-optimize's choices:
  * RF / ET  — spread across ensemble members,
  * GBRT     — three quantile-loss ensembles (0.16 / 0.50 / 0.84),
  * GP       — exact posterior variance (RBF kernel + noise, Cholesky).

The fit/predict hot path is vectorized — CART splits are found with a
per-feature argsort + prefix-sum SSE scan, fitted trees flatten into
``(feature, threshold, left, right, value)`` arrays so whole candidate pools
route through iterative level-wise gathers, and the GP supports incremental
Cholesky extension across ``tell``s — while staying bit-identical (trees) or
within fp tolerance (GP) to the straightforward recursive reference (see
tests/test_surrogate_parity.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

try:  # scipy ships with jax; fall back to dense solves without it
    from scipy.linalg import solve_triangular as _scipy_solve_triangular

    def _solve_lower(L, b):
        return _scipy_solve_triangular(L, b, lower=True, check_finite=False)

    def _solve_lower_t(L, b):
        return _scipy_solve_triangular(L, b, lower=True, trans="T",
                                       check_finite=False)
except ImportError:  # pragma: no cover - scipy is a jax dependency
    def _solve_lower(L, b):
        return np.linalg.solve(L, b)

    def _solve_lower_t(L, b):
        return np.linalg.solve(L.T, b)

__all__ = [
    "RegressionTree",
    "RandomForest",
    "ExtraTrees",
    "GradientBoostedTrees",
    "GaussianProcess",
    "make_learner",
    "LEARNERS",
]


# ---------------------------------------------------------------------------
# CART regression tree (variance-reduction splits)
# ---------------------------------------------------------------------------


_LINSPACE32_CACHE: dict[int, np.ndarray] = {}


def _linspace32(m: int) -> np.ndarray:
    """Memoized ``np.linspace(0, m-1, 32).astype(int)`` (the threshold-scan
    cap): identical indices, no per-node linspace allocation."""
    sel = _LINSPACE32_CACHE.get(m)
    if sel is None:
        sel = _LINSPACE32_CACHE[m] = np.linspace(0, m - 1, 32).astype(int)
    return sel


def _is_const_target(y: np.ndarray) -> bool:
    """``np.allclose(y, y[0])`` with the isclose machinery stripped: the
    identical |y - y0| <= atol + rtol*|y0| test for finite pivots (every BO
    objective — failures are capped upstream), falling back to allclose on a
    non-finite pivot."""
    y0 = y[0]
    if np.isfinite(y0):
        return bool((np.abs(y - y0) <= 1e-8 + 1e-5 * abs(y0)).all())
    return bool(np.allclose(y, y0))


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: "_Node | None" = None
    right: "_Node | None" = None
    value: float = 0.0
    is_leaf: bool = True


@dataclasses.dataclass
class _FlatTree:
    """A fitted tree as arrays: node ``i`` routes rows with
    ``x[feature[i]] <= threshold[i]`` to ``left[i]`` else ``right[i]``;
    ``feature[i] == -1`` marks a leaf holding ``value[i]``."""

    feature: np.ndarray    # (m,) int32, -1 at leaves
    threshold: np.ndarray  # (m,) float64
    left: np.ndarray       # (m,) int32
    right: np.ndarray      # (m,) int32
    value: np.ndarray      # (m,) float64
    depth: int             # deepest internal node + 1: bounds the gather loop


def _flatten_tree(root: _Node) -> _FlatTree:
    nodes: list[_Node] = []
    depths: list[int] = []

    def visit(node: _Node, depth: int) -> int:
        i = len(nodes)
        nodes.append(node)
        depths.append(depth)
        return i

    # preorder with explicit child back-patching
    feature, threshold, left, right, value = [], [], [], [], []
    stack = [(root, 0, -1, False)]  # (node, depth, parent index, is_right)
    while stack:
        node, depth, parent, is_right = stack.pop()
        i = visit(node, depth)
        if parent >= 0:
            (right if is_right else left)[parent] = i
        feature.append(-1 if node.is_leaf else node.feature)
        threshold.append(node.threshold)
        left.append(i)   # leaves self-loop, halting their rows' traversal
        right.append(i)
        value.append(node.value)
        if not node.is_leaf:
            stack.append((node.right, depth + 1, i, True))
            stack.append((node.left, depth + 1, i, False))
    return _FlatTree(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float64),
        left=np.asarray(left, np.int32),
        right=np.asarray(right, np.int32),
        value=np.asarray(value, np.float64),
        depth=max((d for d, f in zip(depths, feature) if f >= 0), default=-1) + 1,
    )


def _levelwise_gather(feature, threshold, left, right, value, depth, idx, X):
    """Iterative tree traversal shared by single-tree and ensemble predict:
    rows advance one level per step via masked gathers, applying the same
    ``x <= threshold`` comparison a recursive walk would (bit-identical
    routing; leaves self-loop so finished rows just hold position).
    ``idx`` carries the starting node per slot and is broadcast against the
    trailing row axis of ``X``."""
    rows = np.arange(len(X)).reshape((1,) * (idx.ndim - 1) + (-1,))
    for _ in range(depth):
        f = feature[idx]
        live = f >= 0
        if not live.any():
            break
        xv = X[rows, np.where(live, f, 0)]
        go_left = xv <= threshold[idx]
        idx = np.where(live, np.where(go_left, left[idx], right[idx]), idx)
    return value[idx]


class _FlatEnsemble:
    """All of an ensemble's trees concatenated into one flat node table, so
    ``predict_matrix`` routes every (tree, row) pair through one iterative
    level-wise gather loop instead of per-row Python recursion."""

    def __init__(self, trees: "list[RegressionTree]"):
        flats = [t.flat() for t in trees]
        sizes = np.array([len(f.feature) for f in flats])
        offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)
        self.roots = offsets
        self.feature = np.concatenate([f.feature for f in flats])
        self.threshold = np.concatenate([f.threshold for f in flats])
        self.left = np.concatenate([f.left + o for f, o in zip(flats, offsets)])
        self.right = np.concatenate([f.right + o for f, o in zip(flats, offsets)])
        self.value = np.concatenate([f.value for f in flats])
        self.depth = max((f.depth for f in flats), default=0)

    def predict_matrix(self, X: np.ndarray) -> np.ndarray:
        """(T, n) member predictions, bit-identical to stacking per-tree
        recursive descents."""
        X = np.asarray(X, dtype=np.float64)
        idx = np.repeat(self.roots[:, None], len(X), axis=1)  # (T, n)
        return _levelwise_gather(self.feature, self.threshold, self.left,
                                 self.right, self.value, self.depth, idx, X)


class RegressionTree:
    """CART with MSE (variance-reduction) splits.

    ``splitter='best'`` scans candidate thresholds per feature (RF / GBRT);
    ``splitter='random'`` draws one uniform threshold per feature (Extra Trees).

    The split search is one vectorized pass: per tried feature, an argsort +
    prefix-sum scan scores every candidate threshold at once. Prefix-sum SSE
    drifts from the reference ``nl*var(yl) + nr*var(yr)`` by a few ulps, so
    every candidate within a small tolerance of the scan minimum is re-scored
    with the exact reference arithmetic, in reference iteration order — the
    selected (feature, threshold) is bit-identical to the nested-loop
    implementation, including tie-breaking and RNG consumption order.
    """

    # rescore everything within this relative band of the scan minimum; the
    # actual prefix-sum drift is ~n*eps (<=1e-13 rel at tuning scale), so the
    # band is ~1e5x generous and usually holds 1-2 candidates
    _RESCORE_RTOL = 1e-8

    def __init__(
        self,
        max_depth: int = 12,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_features: float | str | None = None,
        splitter: str = "best",
        rng: np.random.Generator | None = None,
    ):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.splitter = splitter
        self.rng = rng or np.random.default_rng(0)
        self.root: _Node | None = None
        self._flat: _FlatTree | None = None

    # -- fitting --------------------------------------------------------------

    def _n_features_to_try(self, d: int) -> int:
        mf = self.max_features
        if mf is None or mf == 1.0:
            return d
        if mf == "sqrt":
            return max(1, int(np.sqrt(d)))
        if mf == "log2":
            return max(1, int(np.log2(d))) if d > 1 else 1
        if isinstance(mf, float):
            return max(1, int(mf * d))
        return d

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self.root = self._build(X, y, np.arange(len(y)), depth=0)
        self._flat = None
        return self

    def _find_best_split(self, Xf, y, feats):
        """Vectorized argmin over all (feature, threshold) candidates in the
        node-local feature matrix ``Xf`` (rows = node samples, columns =
        tried features in permuted order). Returns ``(feature, threshold,
        left-mask)`` or None.

        One argsort + prefix-sum pass scores every threshold of every tried
        feature — candidate midpoints, left-counts, and uniqueness all derive
        from the sorted matrix, and every (feature, threshold) pair is scored
        in one flat array expression (no per-feature Python loop). The
        'random' splitter's uniform draws are one vectorized call over the
        non-constant features — numpy Generators fill array draws in the same
        stream order as sequential scalar draws, so RNG consumption matches
        the reference loop exactly."""
        n = len(y)
        msl = self.min_samples_leaf
        F = Xf.shape[1]
        order = np.argsort(Xf, axis=0, kind="stable")
        cols = np.arange(F)
        Xs = Xf[order, cols]                                # per-column sorted
        ys = y[order]
        cs1 = np.cumsum(ys, axis=0)
        cs2 = np.cumsum(ys * ys, axis=0)
        t1 = cs1[-1]
        t2 = cs2[-1]

        if self.splitter == "random":
            nonconst = np.flatnonzero(Xs[0] != Xs[-1])
            if len(nonconst) == 0:
                return None
            # one draw per non-constant feature, in feature order — the same
            # values the reference's per-feature scalar draws produce
            th = self.rng.uniform(Xs[0, nonconst], Xs[-1, nonconst])
            cand_col = nonconst
            # |{x <= t}|, the exact semantics of the reference mask count
            nl = (Xs[:, nonconst] <= th).sum(axis=0)
        else:
            neq = Xs[1:] != Xs[:-1]                         # (n-1, F)
            # flat candidates in reference order: feature-major, ascending
            # threshold (nonzero on the transpose walks columns in order)
            cand_col, bnd = np.nonzero(neq.T)
            if len(bnd) == 0:
                return None  # all tried features constant
            th = (Xs[bnd + 1, cand_col] + Xs[bnd, cand_col]) / 2.0  # midpoints
            # left-count per candidate: the cumulative count of its lower
            # unique value — except when the fp midpoint rounds onto the
            # upper unique value, where ``col <= t`` swallows that group too
            # (same-column next boundary, or n at the column's last candidate)
            nxt = np.empty(len(bnd), np.int64)
            nxt[-1] = n
            same = cand_col[1:] == cand_col[:-1]
            nxt[:-1] = np.where(same, bnd[1:] + 1, n)
            nl = np.where(th == Xs[bnd + 1, cand_col], nxt, bnd + 1)
            per_col = np.bincount(cand_col, minlength=F)
            if per_col.max() > 32:  # cap threshold scan; plenty at tuning scale
                keep = np.ones(len(bnd), bool)
                start = 0
                for j, c in enumerate(per_col):
                    if c > 32:
                        keep[start:start + c] = False
                        keep[start + _linspace32(int(c))] = True
                    start += c
                cand_col, th, nl = cand_col[keep], th[keep], nl[keep]

        nr = n - nl
        last = nl - 1  # nl >= 1 always: the smallest value is a left row
        s1 = cs1[last, cand_col]
        s2 = cs2[last, cand_col]
        # nr == 0 (threshold at/above the max) is masked below; max(nr, 1)
        # only keeps the division from warning on those masked slots
        sse = (s2 - s1 * s1 / nl) + ((t2[cand_col] - s2)
                                     - (t1[cand_col] - s1) ** 2 / np.maximum(nr, 1))
        sse[(nl < msl) | (nr < msl)] = np.inf
        vmin = sse.min()
        if not np.isfinite(vmin):
            return None

        # prefix-sum SSE drifts from the reference ``nl*var(yl) + nr*var(yr)``
        # by a few ulps: gather every candidate within the tolerance band of
        # the scan minimum (the flat order IS reference iteration order) and,
        # only when there is more than one, re-score them with the exact
        # reference arithmetic so strict-< tie-breaking picks the identical
        # winner
        scale = abs(float(t2[0])) + float(t1[0]) ** 2 / n + 1.0
        near = np.flatnonzero(sse <= vmin + self._RESCORE_RTOL * scale)
        if len(near) == 1:
            j, t = int(cand_col[near[0]]), float(th[near[0]])
        else:
            # identical partitions score bitwise-identically and strict-<
            # keeps the first, so only the first candidate per distinct
            # left-mask needs the reference var-scoring
            seen: list[np.ndarray] = []
            best = None
            for ci in near:
                j_c = int(cand_col[ci])
                t_c = float(th[ci])
                mask = Xf[:, j_c] <= t_c
                if any(np.array_equal(mask, m) for m in seen):
                    continue
                seen.append(mask)
                nl_e = int(mask.sum())
                nr_e = n - nl_e
                if nl_e < msl or nr_e < msl:
                    continue
                yl, yr = y[mask], y[~mask]
                score = nl_e * yl.var() + nr_e * yr.var()  # SSE up to constants
                if best is None or score < best[0]:
                    best = (score, j_c, t_c)
            if best is None:
                return None
            _, j, t = best
        return int(feats[j]), t, Xf[:, j] <= t

    def _build(self, X: np.ndarray, y: np.ndarray, idx: np.ndarray,
               depth: int) -> _Node:
        """Recursive CART over the rows ``idx`` of the full (X, y): children
        partition the index array instead of copying full-width data slices.
        Row order inside ``idx`` matches what boolean-mask slicing would
        produce, so every reduction sees the reference element order."""
        yn = y[idx]
        node = _Node(value=float(yn.mean()), is_leaf=True)
        n = len(idx)
        if (
            depth >= self.max_depth
            or n < self.min_samples_split
            or n < 2 * self.min_samples_leaf
            or _is_const_target(yn)
        ):
            return node

        d = X.shape[1]
        feats = self.rng.permutation(d)[: self._n_features_to_try(d)]
        split = self._find_best_split(X[np.ix_(idx, feats)], yn, feats)
        if split is None:
            return node
        f, t, mask = split
        node.is_leaf = False
        node.feature = f
        node.threshold = t
        node.left = self._build(X, y, idx[mask], depth + 1)
        node.right = self._build(X, y, idx[~mask], depth + 1)
        return node

    # -- prediction -------------------------------------------------------------

    def flat(self) -> _FlatTree:
        if self._flat is None:
            self._flat = _flatten_tree(self.root)
        return self._flat

    def invalidate_flat(self) -> None:
        """Leaf values were mutated in place (GBRT requantile): drop the
        cached array form so the next predict re-flattens."""
        self._flat = None

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        flat = self.flat()
        return _levelwise_gather(flat.feature, flat.threshold, flat.left,
                                 flat.right, flat.value, flat.depth,
                                 np.zeros(len(X), np.int32), X)


# ---------------------------------------------------------------------------
# Lockstep forest fitting: many independent trees, one numpy stream
# ---------------------------------------------------------------------------
#
# A single CART build is a sequential chain — each node's RNG draw and split
# depend on its parent's outcome, in DFS order — so per-node work cannot be
# batched *within* a tree without changing RNG consumption. But ensemble
# members are mutually independent (each owns its Generator), so T trees can
# advance in lockstep: every round pops one DFS node per tree and fuses all
# popped nodes' split searches into flat segmented array ops (one lexsort,
# two cumsums, one SSE expression for every (node, feature, threshold)
# candidate of the round). Per-tree draws still happen at node-visit time in
# exact DFS order, and all result-bearing reductions (leaf means, rescores)
# run per node with the reference arithmetic, so every tree is bit-identical
# to RegressionTree.fit on the same data and rng — only wall-clock changes.


class _LockstepForest:
    def __init__(self, X, y, prototype: "RegressionTree"):
        self.X = np.asarray(X, dtype=np.float64)
        self.y = np.asarray(y, dtype=np.float64)
        p = prototype
        self.max_depth = p.max_depth
        self.mss = p.min_samples_split
        self.msl = p.min_samples_leaf
        self.splitter = p.splitter
        self.n_try = p._n_features_to_try(self.X.shape[1])
        self.rescore_rtol = p._RESCORE_RTOL

    def fit(self, roots: "list[np.ndarray]", rngs: "list") -> "list[_FlatTree]":
        """Fit one tree per (root row-index set, rng); returns the trees
        directly in array (:class:`_FlatTree`) form — no ``_Node`` objects or
        post-hoc flattening on this path. Row indices address the shared X/y
        (bootstrap duplicates are plain repeated indices)."""
        X, y = self.X, self.y
        d = X.shape[1]
        F = self.n_try
        msl, mss = self.msl, self.mss
        T = len(roots)
        # per-tree flat node tables, appended in creation order (traversal
        # does not care about node ordering, only about link indices)
        feat = [[] for _ in range(T)]
        thr = [[] for _ in range(T)]
        left = [[] for _ in range(T)]
        right = [[] for _ in range(T)]
        val = [[] for _ in range(T)]
        maxdep = [0] * T

        def leaf_value(vals: np.ndarray) -> float:
            # pairwise-summation mean is sequential below 3 elements: the
            # scalar path is bit-identical and skips the numpy dispatch
            k = len(vals)
            if k == 1:
                return float(vals[0])
            if k == 2:
                return (float(vals[0]) + float(vals[1])) / 2.0
            return float(vals.mean())

        def add_node(t, parent, is_right, f, tval, v) -> int:
            i = len(feat[t])
            feat[t].append(f)
            thr[t].append(tval)
            left[t].append(i)   # self-loop; split nodes are re-linked below
            right[t].append(i)
            val[t].append(v)
            if parent >= 0:
                (right[t] if is_right else left[t])[parent] = i
            return i

        # DFS stacks: (parent index, is_right, row-idx, depth); popping
        # left-first reproduces the recursive preorder, so per-tree rng
        # draws line up exactly with the reference recursion. Roots get the
        # same trivial-leaf screen children get at push time.
        stacks = [[] for _ in range(T)]
        for t, r in enumerate(roots):
            r = np.asarray(r)
            if self.max_depth <= 0 or len(r) < mss or len(r) < 2 * msl:
                add_node(t, -1, False, -1, 0.0, leaf_value(y[r]))
            else:
                stacks[t].append((-1, False, r, 0))
        live = list(range(T))
        while live:
            # -- phase A: one batch-needing node per tree; trivial leaves
            # (depth/size bounds) were resolved at push time, so each pop is
            # a node that at least needs the constant-target check
            cand = []  # [t, parent, is_right, idx, depth]
            next_live = []
            for t in live:
                stack = stacks[t]
                if stack:
                    cand.append(stack.pop())
                    ct = cand[-1]
                    cand[-1] = [t, ct[0], ct[1], ct[2], ct[3]]
                if stack or cand and cand[-1][0] == t:
                    next_live.append(t)
            live = next_live
            if not cand:
                continue

            # -- phase B: batched constant-target check (exact
            # _is_const_target semantics; reduceat of booleans is order-free)
            sizes = np.array([len(c[3]) for c in cand])
            starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
            rows = np.concatenate([c[3] for c in cand])
            yn = y[rows]
            y0 = yn[starts]
            if np.isfinite(y0).all():
                ok = np.abs(yn - np.repeat(y0, sizes)) <= \
                    1e-8 + 1e-5 * np.repeat(np.abs(y0), sizes)
                const = np.logical_and.reduceat(ok, starts)
            else:  # pragma: no cover - capped objectives are always finite
                const = np.array([_is_const_target(yn[s:s + z])
                                  for s, z in zip(starts, sizes)])
            keep = []
            for b, c in enumerate(cand):
                if const[b]:
                    t, parent, is_right, idx, _ = c
                    add_node(t, parent, is_right, -1, 0.0,
                             leaf_value(yn[starts[b]:starts[b] + sizes[b]]))
                else:
                    keep.append(c)
            if not keep:
                continue
            if len(keep) != len(cand):
                sizes = np.array([len(c[3]) for c in keep])
                starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
                rows = np.concatenate([c[3] for c in keep])
                yn = y[rows]

            # -- phase C: per-node feature draws, tree-local rng, DFS order
            feats = np.stack([rngs[c[0]].permutation(d)[:F] for c in keep])

            # -- phase D: one fused split search for every popped node
            splits = self._batched_split(rows, yn, sizes, starts, feats,
                                         [rngs[c[0]] for c in keep])

            # -- phase E: attach winners, push children (right below left);
            # children that are leaves by the depth/size bounds alone are
            # attached immediately instead of round-tripping the stack
            for b, c in enumerate(keep):
                t, parent, is_right, idx, depth = c
                win = splits[b]
                if win is None:
                    add_node(t, parent, is_right, -1, 0.0,
                             leaf_value(yn[starts[b]:starts[b] + sizes[b]]))
                    continue
                f_local, tval, mask = win
                i = add_node(t, parent, is_right, int(feats[b, f_local]),
                             float(tval), 0.0)
                cdep = depth + 1
                if cdep > maxdep[t]:
                    maxdep[t] = cdep
                for child_right, cidx in ((True, idx[~mask]), (False, idx[mask])):
                    n_c = len(cidx)
                    if cdep >= self.max_depth or n_c < mss or n_c < 2 * msl:
                        add_node(t, i, child_right, -1, 0.0, leaf_value(y[cidx]))
                    else:
                        stacks[t].append((i, child_right, cidx, cdep))
        return [
            _FlatTree(
                feature=np.asarray(feat[t], np.int32),
                threshold=np.asarray(thr[t], np.float64),
                left=np.asarray(left[t], np.int32),
                right=np.asarray(right[t], np.int32),
                value=np.asarray(val[t], np.float64),
                depth=maxdep[t],
            )
            for t in range(T)
        ]

    def _batched_split(self, rows, yn, sizes, starts, feats, node_rngs):
        """Flat segmented version of RegressionTree._find_best_split for B
        nodes at once. Returns per node ``(local feature, threshold, mask)``
        or None. SSE values are ranking-only (global cumsums drift a few more
        ulps than per-node ones); winners within the tolerance band are
        re-scored per node with the exact reference arithmetic.

        NOTE: this deliberately mirrors RegressionTree._find_best_split —
        the midpoint-collision left-count fix, 32-candidate cap, rescore
        band, and mask-dedup must stay bit-synchronized between the two (the
        single-tree path still exists because GBRT's leaf requantile needs
        the _Node form); tests/test_surrogate_parity.py pins both against
        the same reference."""
        X = self.X
        B, F = feats.shape
        msl = self.msl
        Xf = X[rows[:, None], np.repeat(feats, sizes, axis=0)]   # (R, F)

        if self.splitter == "random":
            cand = self._random_candidates(Xf, yn, sizes, starts, node_rngs)
        else:
            cand = self._best_candidates(Xf, yn, sizes)
        if cand is None:
            return [None] * B
        cand_b, cand_j, th, nl, s1, s2, t1c, t2c, nall, scale = cand

        nr = nall - nl
        sse = (s2 - s1 * s1 / nl) + ((t2c - s2)
                                     - (t1c - s1) ** 2
                                     / np.maximum(nr, 1))
        sse[(nl < msl) | (nr < msl)] = np.inf
        bounds = np.searchsorted(cand_b, np.arange(B + 1))
        out = []
        for b in range(B):
            lo_i, hi_i = int(bounds[b]), int(bounds[b + 1])
            if hi_i == lo_i:
                out.append(None)
                continue
            sse_b = sse[lo_i:hi_i]
            vmin = sse_b.min()
            if not np.isfinite(vmin):
                out.append(None)
                continue
            near = np.flatnonzero(sse_b <= vmin + self.rescore_rtol * scale[b])
            s0, n_b = starts[b], sizes[b]
            Xf_b = Xf[s0:s0 + n_b]
            if len(near) == 1:
                ci = lo_i + near[0]
                j = int(cand_j[ci])
                t = float(th[ci])
                out.append((j, t, Xf_b[:, j] <= t))
            else:
                y_b = yn[s0:s0 + n_b]
                # near-ties are usually the *same partition* reached through
                # different features (complementary one-hot columns): their
                # exact scores are bitwise equal, and strict-< keeps the
                # first, so only the first candidate per distinct left-mask
                # ever needs the reference var-scoring
                seen: list[np.ndarray] = []
                best = None
                for ci in lo_i + near:
                    j_c = int(cand_j[ci])
                    t_c = float(th[ci])
                    mask = Xf_b[:, j_c] <= t_c
                    if any(np.array_equal(mask, m) for m in seen):
                        continue
                    seen.append(mask)
                    nl_e = int(mask.sum())
                    nr_e = n_b - nl_e
                    if nl_e < msl or nr_e < msl:
                        continue
                    yl, yr = y_b[mask], y_b[~mask]
                    score = nl_e * yl.var() + nr_e * yr.var()
                    if best is None or score < best[0]:
                        best = (score, j_c, t_c)
                if best is None:
                    out.append(None)
                else:
                    _, j, t = best
                    out.append((j, t, Xf_b[:, j] <= t))
        return out

    def _best_candidates(self, Xf, yn, sizes):
        """Candidate arrays for the 'best' splitter: every (node, column)
        group is sorted and every unique-value boundary scored. Returns
        ``(cand_b, cand_j, th, nl, s1, s2, t1, t2, n, scale)`` per candidate
        (node totals broadcast per candidate; ``scale`` per node) or None."""
        B = len(sizes)
        F = Xf.shape[1]
        seg = np.repeat(np.arange(B), sizes)
        segcol = (seg[:, None] * F + np.arange(F)).ravel()       # C-order
        vals = Xf.ravel()
        yrep = np.repeat(yn, F)
        perm = np.lexsort((vals, segcol))  # stable: group, value, position
        vs = vals[perm]
        ysrt = yrep[perm]
        cs1 = np.cumsum(ysrt)
        cs2 = np.cumsum(ysrt * ysrt)
        gsizes = np.repeat(sizes, F)                 # per (node, col) group
        gstarts = np.concatenate([[0], np.cumsum(gsizes)[:-1]])
        gends = gstarts + gsizes - 1
        prev1 = np.where(gstarts > 0, cs1[gstarts - 1], 0.0)
        prev2 = np.where(gstarts > 0, cs2[gstarts - 1], 0.0)
        t1g = cs1[gends] - prev1
        t2g = cs2[gends] - prev2
        nseg = np.repeat(sizes, F)                   # node size per group

        bm = vs[1:] != vs[:-1]
        bm[gstarts[1:] - 1] = False              # kill cross-group edges
        cand_pos = np.nonzero(bm)[0]
        if len(cand_pos) == 0:
            return None
        cand_group = segcol[perm[cand_pos]]
        th = (vs[cand_pos + 1] + vs[cand_pos]) / 2.0
        base = cand_pos + 1 - gstarts[cand_group]
        nxt = np.empty(len(base), np.int64)
        nxt[-1] = nseg[cand_group[-1]]
        same = cand_group[1:] == cand_group[:-1]
        nxt[:-1] = np.where(same, base[1:], nseg[cand_group[:-1]])
        # fp midpoints that round onto the upper unique value swallow
        # that group too, exactly like the reference's ``col <= t`` mask
        nl = np.where(th == vs[cand_pos + 1], nxt, base)
        percol = np.bincount(cand_group, minlength=B * F)
        if percol.max() > 32:  # cap threshold scan per feature
            keepm = np.ones(len(th), bool)
            s = 0
            for g, c in enumerate(percol):
                if c > 32:
                    keepm[s:s + c] = False
                    keepm[s + _linspace32(int(c))] = True
                s += c
            cand_group, th, nl = cand_group[keepm], th[keepm], nl[keepm]

        s1 = cs1[gstarts[cand_group] + nl - 1] - prev1[cand_group]
        s2 = cs2[gstarts[cand_group] + nl - 1] - prev2[cand_group]
        cand_b = cand_group // F
        cand_j = cand_group - cand_b * F
        # per-node tolerance band from the node's first tried column
        scale = np.abs(t2g[::F]) + t1g[::F] ** 2 / sizes + 1.0
        return (cand_b, cand_j, th, nl, s1, s2,
                t1g[cand_group], t2g[cand_group], nseg[cand_group], scale)

    def _random_candidates(self, Xf, yn, sizes, starts, node_rngs):
        """Candidate arrays for the 'random' splitter (ET), with the
        nonsplittable-column prefilter: a column constant within its node can
        never split it, yet ET's all-features policy (max_features=1.0)
        previously dragged every such column through the segmented sort,
        keeping per-round arrays ~4x wider than RF's. Per-(node, column)
        min/max — the same values as the sorted first/last elements — screen
        dead columns out first, so only splittable groups are sorted and
        scanned. Draw values, draw order, and candidate order are unchanged:
        the reference draws one uniform per non-constant column in column
        order, and nonconst detection via min != max is exact."""
        B = len(sizes)
        F = Xf.shape[1]
        lo = np.minimum.reduceat(Xf, starts, axis=0)             # (B, F)
        hi = np.maximum.reduceat(Xf, starts, axis=0)
        live = lo != hi
        th_rows = []
        for b in range(B):
            nc = np.flatnonzero(live[b])
            if len(nc):
                # vectorized draw == the reference's sequential scalars
                th_rows.append(node_rngs[b].uniform(lo[b, nc], hi[b, nc]))
        kept = np.flatnonzero(live.ravel())          # live (node, col) groups
        if len(kept) == 0:
            return None
        th = np.concatenate(th_rows)
        cand_b = kept // F
        cand_j = kept - cand_b * F
        gsz = sizes[cand_b]
        gstarts = np.concatenate([[0], np.cumsum(gsz)[:-1]])
        gends = gstarts + gsz - 1
        srow = np.repeat(starts[cand_b], gsz) + \
            (np.arange(int(gsz.sum())) - np.repeat(gstarts, gsz))
        vals = Xf[srow, np.repeat(cand_j, gsz)]
        seg = np.repeat(np.arange(len(kept)), gsz)
        perm = np.lexsort((vals, seg))  # stable: group, value, position
        vs = vals[perm]
        ysrt = yn[srow][perm]
        cs1 = np.cumsum(ysrt)
        cs2 = np.cumsum(ysrt * ysrt)
        prev1 = np.where(gstarts > 0, cs1[gstarts - 1], 0.0)
        prev2 = np.where(gstarts > 0, cs2[gstarts - 1], 0.0)
        # |{x <= t}| per group: boolean reduceat is an exact count
        nl = np.add.reduceat(vs <= np.repeat(th, gsz), gstarts, dtype=np.int64)
        s1 = cs1[gstarts + nl - 1] - prev1
        s2 = cs2[gstarts + nl - 1] - prev2
        t1c = cs1[gends] - prev1
        t2c = cs2[gends] - prev2
        # per-node tolerance scale over the node's own rows (ranking-only,
        # like the sse values: the rescore band absorbs summation-order ulps)
        t1n = np.add.reduceat(yn, starts)
        t2n = np.add.reduceat(yn * yn, starts)
        scale = np.abs(t2n) + t1n * t1n / sizes + 1.0
        return cand_b, cand_j, th, nl, s1, s2, t1c, t2c, gsz, scale


# ---------------------------------------------------------------------------
# Random Forest / Extra Trees
# ---------------------------------------------------------------------------


class RandomForest:
    """Bagged CART ensemble; sigma = std across member predictions."""

    name = "RF"
    bootstrap = True
    splitter = "best"
    max_features: float | str = "sqrt"

    def __init__(self, n_estimators: int = 32, max_depth: int = 12, seed: int = 0,
                 min_samples_leaf: int = 1):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.rng = np.random.default_rng(seed)
        self.trees: list[RegressionTree] = []
        self._ens: _FlatEnsemble | None = None

    def fit(self, X: np.ndarray, y: np.ndarray):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = len(X)
        self.trees = []
        self._ens = None
        # draw every tree's bootstrap rows and generator seed first, in the
        # exact order the sequential loop consumed the ensemble rng, then let
        # the lockstep engine advance all trees at once (each tree's own rng
        # is still consumed at node-visit time in DFS order)
        roots, rngs = [], []
        for _ in range(self.n_estimators):
            if self.bootstrap:
                idx = self.rng.integers(0, n, size=n)
            else:
                idx = np.arange(n)
            tree = RegressionTree(
                max_depth=self.max_depth,
                max_features=self.max_features,
                splitter=self.splitter,
                min_samples_leaf=self.min_samples_leaf,
                rng=np.random.default_rng(int(self.rng.integers(2**31))),
            )
            roots.append(idx)
            rngs.append(tree.rng)
            self.trees.append(tree)
        engine = _LockstepForest(X, y, self.trees[0])
        for tree, flat in zip(self.trees, engine.fit(roots, rngs)):
            tree.root = None  # array-form only on the ensemble path
            tree._flat = flat
        return self

    def _ensemble(self) -> _FlatEnsemble:
        if self._ens is None:
            self._ens = _FlatEnsemble(self.trees)
        return self._ens

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        preds = self._ensemble().predict_matrix(X)  # (T, n)
        mu = preds.mean(axis=0)
        sigma = preds.std(axis=0) + 1e-9
        return mu, sigma


class ExtraTrees(RandomForest):
    """Extremely-randomized trees: no bootstrap, random split thresholds."""

    name = "ET"
    bootstrap = False
    splitter = "random"
    max_features = 1.0


# ---------------------------------------------------------------------------
# Gradient-boosted regression trees with quantile loss
# ---------------------------------------------------------------------------


class _QuantileGBT:
    """One boosted ensemble minimizing pinball loss at quantile ``alpha``."""

    def __init__(self, alpha: float, n_estimators: int, lr: float, max_depth: int, seed: int):
        self.alpha = alpha
        self.n_estimators = n_estimators
        self.lr = lr
        self.max_depth = max_depth
        self.rng = np.random.default_rng(seed)
        self.base = 0.0
        self.trees: list[RegressionTree] = []
        self._ens: _FlatEnsemble | None = None

    def fit(self, X, y):
        self.base = float(np.quantile(y, self.alpha))
        pred = np.full(len(y), self.base)
        self.trees = []
        self._ens = None
        for _ in range(self.n_estimators):
            resid = y - pred
            # negative gradient of pinball loss
            grad = np.where(resid > 0, self.alpha, self.alpha - 1.0)
            tree = RegressionTree(
                max_depth=self.max_depth,
                rng=np.random.default_rng(int(self.rng.integers(2**31))),
            )
            tree.fit(X, grad)
            # line-search-free step (standard GBM-with-quantile shortcut):
            # refit leaf values to the quantile of residuals they cover
            self._requantile_leaves(tree.root, X, resid, np.arange(len(y)))
            tree.invalidate_flat()
            step = tree.predict(X)
            pred = pred + self.lr * step
            self.trees.append(tree)
        return self

    def _requantile_leaves(self, node: _Node, X, resid, idx):
        if node.is_leaf:
            node.value = float(np.quantile(resid[idx], self.alpha)) if len(idx) else 0.0
            return
        mask = X[idx, node.feature] <= node.threshold
        self._requantile_leaves(node.left, X, resid, idx[mask])
        self._requantile_leaves(node.right, X, resid, idx[~mask])

    def predict(self, X):
        out = np.full(len(X), self.base)
        if not self.trees:
            return out
        if self._ens is None:
            self._ens = _FlatEnsemble(self.trees)
        preds = self._ens.predict_matrix(X)  # (T, n)
        # accumulate tree-by-tree: same summation order as sequential boosting
        for t in range(len(self.trees)):
            out = out + self.lr * preds[t]
        return out


class GradientBoostedTrees:
    """skopt-style GBRT surrogate: quantile ensembles at 0.16 / 0.50 / 0.84."""

    name = "GBRT"

    def __init__(self, n_estimators: int = 64, lr: float = 0.15, max_depth: int = 4, seed: int = 0):
        self.models = {
            a: _QuantileGBT(a, n_estimators, lr, max_depth, seed + i)
            for i, a in enumerate((0.16, 0.50, 0.84))
        }

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        for m in self.models.values():
            m.fit(X, y)
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        lo = self.models[0.16].predict(X)
        mid = self.models[0.50].predict(X)
        hi = self.models[0.84].predict(X)
        sigma = np.maximum((hi - lo) / 2.0, 1e-9)
        return mid, sigma


# ---------------------------------------------------------------------------
# Gaussian process (RBF + white noise, exact Cholesky inference)
# ---------------------------------------------------------------------------


class GaussianProcess:
    """Exact GP regression; length-scale picked by marginal likelihood over a
    small log grid (no gradient optimizer needed at n<=500).

    ``partial_fit`` supports the BO loop's append-mostly refits: the Cholesky
    factor of the kernel matrix is cached across calls and extended one row at
    a time over the longest unchanged row-prefix of X (the factor of a leading
    principal submatrix is the matching prefix of L), so a ``tell`` costs
    O(n^2) instead of a full O(grid * n^3) refit. The length-scale grid only
    reruns — a full refactorization, which also bounds fp drift — every
    ``refit_every`` added rows, or when the incremental extension goes
    numerically degenerate.
    """

    name = "GP"

    def __init__(self, length_scales=(0.1, 0.2, 0.5, 1.0, 2.0, 5.0), noise: float = 1e-4,
                 seed: int = 0, refit_every: int = 16, full_fit_below: int = 32):
        self.length_scales = tuple(length_scales)
        self.noise = noise
        self.refit_every = refit_every
        # below this size a full grid fit is near-free and length-scale
        # selection is still volatile: always refit so early-campaign
        # behavior tracks the per-ask-grid reference closely
        self.full_fit_below = full_fit_below
        self._X = None
        self._alpha = None
        self._L = None
        self._Linv = None
        self._jitter = noise + 1e-10
        self._ls = 1.0
        self._amp = 1.0
        self._ymean = 0.0
        self._ystd = 1.0
        self._n_at_select = 0  # training size when the ls grid last ran

    @staticmethod
    def _sqdist(X1, X2):
        # gemm-based ||a-b||^2, accumulated in place (one (m, n) buffer
        # instead of four); clamped — cancellation can go ~-1e-14
        aa = np.einsum("ij,ij->i", X1, X1)
        bb = np.einsum("ij,ij->i", X2, X2)
        d2 = X1 @ X2.T
        d2 *= -2.0
        d2 += aa[:, None]
        d2 += bb[None, :]
        return np.maximum(d2, 0.0, out=d2)

    @classmethod
    def _k(cls, X1, X2, ls):
        d2 = cls._sqdist(X1, X2)
        d2 *= -0.5 / (ls * ls)
        return np.exp(d2, out=d2)

    def _normalize_targets(self, y):
        self._ymean = float(y.mean())
        self._ystd = float(y.std()) or 1.0
        return (y - self._ymean) / self._ystd

    def fit(self, X, y):
        """Full fit: length-scale model selection over the grid, one Cholesky
        per candidate scale (the squared-distance matrix is hoisted out)."""
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        yn = self._normalize_targets(y)
        n = len(X)
        d2 = self._sqdist(X, X)
        self._jitter = self.noise + 1e-10
        jitter = self._jitter * np.eye(n)
        best = None
        for ls in self.length_scales:
            K = np.exp(-0.5 * d2 / (ls * ls)) + jitter
            try:
                L = np.linalg.cholesky(K)
            except np.linalg.LinAlgError:
                continue
            alpha = _solve_lower_t(L, _solve_lower(L, yn))
            # log marginal likelihood (up to constants)
            lml = -0.5 * yn @ alpha - np.log(np.diag(L)).sum()
            if best is None or lml > best[0]:
                best = (lml, ls, L, alpha)
        if best is None:  # fully degenerate data
            ls = self.length_scales[-1]
            self._jitter = 1e-2  # remembered so incremental rows extend the
            K = np.exp(-0.5 * d2 / (ls * ls)) + self._jitter * np.eye(n)
            L = np.linalg.cholesky(K)  # same (heavily jittered) kernel
            alpha = _solve_lower_t(L, _solve_lower(L, yn))
            best = (0.0, ls, L, alpha)
        _, self._ls, self._L, self._alpha = best
        self._Linv = _solve_lower(self._L, np.eye(n))
        self._X = X.copy()
        self._n_at_select = n
        return self

    def _common_prefix(self, X) -> int:
        m = min(len(X), len(self._X))
        if m == 0:
            return 0
        eq = (X[:m] == self._X[:m]).all(axis=1)
        return m if eq.all() else int(np.argmin(eq))

    def partial_fit(self, X, y):
        """Incremental refit for append-mostly training sets (the BO loop:
        real observations append; liar/pending rows churn only at the tail).
        Reuses ``L[:m, :m]`` for the longest unchanged prefix ``m`` and
        extends row-by-row; targets are re-normalized and alpha recomputed
        against the cached factor either way."""
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = len(X)
        if (
            self._L is None
            or self._X is None
            or n < self.full_fit_below
            or n - self._n_at_select >= self.refit_every
        ):
            return self.fit(X, y)
        m = self._common_prefix(X)
        if m == 0:
            return self.fit(X, y)
        ls = self._ls
        diag = 1.0 + self._jitter  # k(x,x) + the jitter fit() actually used
        L = np.zeros((n, n))
        L[:m, :m] = self._L[:m, :m]
        Linv = np.zeros((n, n))
        Linv[:m, :m] = self._Linv[:m, :m]
        for i in range(m, n):
            k = self._k(X[:i], X[i:i + 1], ls)[:, 0]
            c = Linv[:i, :i] @ k           # == solve(L[:i,:i], k), O(i^2)
            d2 = diag - c @ c
            if d2 <= 1e-12:  # numerically degenerate: full refit reruns grid
                return self.fit(X, y)
            d = np.sqrt(d2)
            L[i, :i] = c
            L[i, i] = d
            # the matching inverse-factor row: [[L,0],[c^T,d]]^-1 appends
            # [-(c^T Linv)/d, 1/d], keeping predict() a pure gemm
            Linv[i, :i] = (c @ Linv[:i, :i]) / -d
            Linv[i, i] = 1.0 / d
        yn = self._normalize_targets(y)
        self._alpha = Linv.T @ (Linv @ yn)
        self._L = L
        self._Linv = Linv
        self._X = X.copy()
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        Ks = self._k(X, self._X, self._ls)  # (m, n)
        mu = Ks @ self._alpha
        v = self._Linv @ Ks.T  # == solve(L, Ks.T) as one gemm, (n, m)
        var = np.maximum(1.0 - (v**2).sum(axis=0), 1e-12)
        return mu * self._ystd + self._ymean, np.sqrt(var) * self._ystd + 1e-9


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

LEARNERS = ("RF", "ET", "GBRT", "GP")


def make_learner(name: str, seed: int = 0):
    name = name.upper()
    if name == "RF":
        return RandomForest(seed=seed)
    if name == "ET":
        return ExtraTrees(seed=seed)
    if name == "GBRT":
        return GradientBoostedTrees(seed=seed)
    if name == "GP":
        return GaussianProcess(seed=seed)
    raise ValueError(f"unknown learner {name!r}; options: {LEARNERS}")
