"""Plopper: turn a configuration into a measurable program and score it.

In the paper the plopper substitutes ``#P0..#Pm`` into a code mold, invokes
``clang`` and runs the binary (exe.pl). Here the "mold" is a *variant factory*
— a Python callable ``factory(config) -> (fn, args)`` that closes over the
configuration to build a concrete JAX program — and two evaluation backends
replace compile-and-run:

  * :class:`TimingEvaluator` (backend B1) — jit, warm up, and wall-clock the
    variant on this host. This is exactly the role the paper's Core-i7 plays.
  * :class:`CostModelEvaluator` (backend B2) — ``.lower().compile()`` the
    variant for the TPU-target mesh and score it with the three-term roofline
    model (compute / memory / collective seconds from the compiled HLO). Used
    where no hardware exists to time (the whole point of a structural model).

Both catch per-candidate failures and return a penalty instead of raising:
one broken configuration must not kill a 200-evaluation campaign. That is the
fault-tolerance contract the search loop relies on.
"""

from __future__ import annotations

import dataclasses
import time
import traceback
from typing import Any, Callable, Mapping

import jax

__all__ = [
    "EvalResult",
    "TimingEvaluator",
    "CostModelEvaluator",
    "DeadlineEvaluator",
    "PENALTY",
]

PENALTY = float(1.0e9)


@dataclasses.dataclass
class EvalResult:
    objective: float
    ok: bool
    info: dict


class TimingEvaluator:
    """Backend B1: measured wall-clock of the jitted variant on this host.

    ``factory(config)`` must return ``(fn, args)``; ``fn(*args)`` is jitted,
    warmed up ``warmup`` times, then timed ``repeats`` times; the *minimum* is
    reported (the paper reports the smallest execution time of repeated runs).
    """

    def __init__(self, factory: Callable[[Mapping[str, Any]], tuple], repeats: int = 3,
                 warmup: int = 1, penalty: float = PENALTY, jit: bool = True):
        self.factory = factory
        self.repeats = repeats
        self.warmup = warmup
        self.penalty = penalty
        self.jit = jit

    def __call__(self, config: Mapping[str, Any]) -> EvalResult:
        try:
            fn, args = self.factory(config)
            run = jax.jit(fn) if self.jit else fn
            for _ in range(self.warmup):
                out = run(*args)
            jax.block_until_ready(out)
            times = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                out = run(*args)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
            return EvalResult(min(times), True, {"times_sec": times})
        except Exception as e:  # noqa: BLE001 — any failure becomes a penalty
            return EvalResult(
                self.penalty, False,
                {"error": f"{type(e).__name__}: {e}", "trace": traceback.format_exc(limit=3)},
            )


class CostModelEvaluator:
    """Backend B2: structural roofline score of the compiled TPU-target program.

    ``factory(config)`` must return a *thunk* producing a
    ``jax.stages.Lowered`` (so compilation happens inside the failure guard).
    ``score(lowered) -> (seconds, info)`` defaults to the repo's three-term
    roofline (see repro.perf.roofline); injectable for tests.
    """

    def __init__(self, factory: Callable[[Mapping[str, Any]], Callable[[], Any]],
                 score: Callable[[Any], tuple[float, dict]] | None = None,
                 penalty: float = PENALTY):
        if score is None:
            from repro.perf.roofline import score_lowered  # lazy: avoids cycle
            score = score_lowered
        self.factory = factory
        self.score = score
        self.penalty = penalty

    def __call__(self, config: Mapping[str, Any]) -> EvalResult:
        try:
            thunk = self.factory(config)
            lowered = thunk()
            seconds, info = self.score(lowered)
            return EvalResult(float(seconds), True, info)
        except Exception as e:  # noqa: BLE001
            return EvalResult(
                self.penalty, False,
                {"error": f"{type(e).__name__}: {e}", "trace": traceback.format_exc(limit=3)},
            )


class DeadlineEvaluator:
    """Straggler mitigation for evaluation campaigns: give up on a candidate
    whose evaluation exceeds ``deadline_sec`` and penalize it.

    Wall-clock is checked *after* the inner call returns (JAX work is not
    preemptible from Python), so the deadline converts stragglers into
    penalized records rather than hung campaigns on *subsequent* candidates:
    any candidate observed to exceed the deadline is recorded as failed, and
    the measured time still feeds the DB so findMin never selects it.
    """

    def __init__(self, inner: Callable[[Mapping[str, Any]], EvalResult], deadline_sec: float):
        self.inner = inner
        self.deadline_sec = deadline_sec

    def __call__(self, config: Mapping[str, Any]) -> EvalResult:
        t0 = time.perf_counter()
        res = self.inner(config)
        wall = time.perf_counter() - t0
        if wall > self.deadline_sec:
            info = dict(res.info)
            info["straggler_wall_sec"] = wall
            return EvalResult(max(res.objective, self.inner_penalty()), False, info)
        return res

    def inner_penalty(self) -> float:
        return getattr(self.inner, "penalty", PENALTY)
