"""Bayesian-optimization search loop (Sec. 2.2/2.3 of the paper).

Loop semantics reproduce ytopt's behavior, including the paper's observed
learner asymmetry:

  * initialization — a small batch of random or Latin-hypercube samples is
    evaluated to seed the performance database;
  * iteration — fit the surrogate on the DB, draw a candidate pool, rank by
    the LCB acquisition, and select;
  * duplicate handling — RF/ET/GBRT consult the performance DB and *re-select*
    until a fresh configuration is found, so they spend the full evaluation
    budget. GP (as shipped in ytopt at the time) does not: a duplicate
    proposal is recorded as skipped and still consumes budget, which is why
    the paper's GP run "finishes only 66 of the 200 evaluations" on syr2k.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import numpy as np

from repro.core import acquisition as acq_mod
from repro.core import surrogates
from repro.core.database import FAILED, OK, SKIPPED_DUPLICATE, PerformanceDatabase, Record
from repro.core.plopper import EvalResult
from repro.core.space import ConfigurationSpace, config_key

__all__ = ["SearchResult", "BayesianSearch", "run_search"]


@dataclasses.dataclass
class SearchResult:
    db: PerformanceDatabase
    best: Record | None
    n_evaluated: int
    n_skipped: int
    n_failed: int
    learner: str
    # optimizer-overhead telemetry (CATBench-style): cumulative seconds the
    # campaign spent inside ask/tell vs waiting on evaluations. None for
    # results not produced by a Campaign.
    timings: dict | None = None

    def summary(self) -> str:
        b = self.best
        head = (
            f"[{self.learner}] evals={self.n_evaluated} skipped={self.n_skipped} "
            f"failed={self.n_failed}"
        )
        if b is None:
            return head + " best=<none>"
        return head + f" best={b.objective:.6g} @eval#{b.index} config={b.config}"


class BayesianSearch:
    """ask/tell Bayesian optimizer over a :class:`ConfigurationSpace`.

    Supports batched proposals: ``ask(n)`` returns ``n`` distinct candidates
    using a constant-liar fill-in — each proposal is registered as a
    *pending* evaluation whose objective is lied to be the mean of the
    observed values, so refitting the surrogate between in-batch proposals
    steers later candidates away from (already-claimed) regions, the qLCB
    batch strategy. ``tell``/``tell_skipped`` clear the pending entry. With
    an empty pending set, ``ask()`` is bit-for-bit the serial single-point
    proposal loop, which is how ``q=1`` campaigns reproduce legacy serial
    trajectories exactly.
    """

    def __init__(
        self,
        space: ConfigurationSpace,
        learner: str = "RF",
        acq: str = "LCB",
        kappa: float = 1.96,
        n_initial: int = 10,
        init_method: str = "lhs",
        n_candidates: int = 512,
        seed: int = 1234,
        db: PerformanceDatabase | None = None,
        prior_records: list[tuple[Mapping[str, Any], float]] | None = None,
        feasibility: Callable[[Mapping[str, Any]], bool] | None = None,
    ):
        self.space = space
        self.learner_name = learner.upper()
        self.acq = acq_mod.make_acquisition(acq)
        self.kappa = kappa
        self.init_method = init_method
        self.n_candidates = n_candidates
        # static feasibility predicate (repro.analyze): candidates it
        # rejects are pruned from the pool before acquisition scoring, so
        # the optimizer never spends surrogate evaluations on configs that
        # cannot build. Opt-in (None = off) — pruning changes which configs
        # reach the acquisition argsort, and the bit-identical legacy
        # trajectory contract covers the default-off path.
        self.feasibility = feasibility
        self.n_pruned = 0  # statically-infeasible candidates discarded
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.db = db if db is not None else PerformanceDatabase()
        self._init_queue: list[dict] = []
        self._model = None
        # hot-path caches: encoded training rows by record index (the DB is
        # append-only, so rows never go stale), the persistent GP whose
        # Cholesky factor extends incrementally across tells, and — inside an
        # ask(n) batch — the sampled-and-encoded base candidate pool
        self._enc_by_index: dict[int, np.ndarray] = {}
        self._gp: surrogates.GaussianProcess | None = None
        self._batch_active = False
        self._pool_base: tuple[list[dict], np.ndarray] | None = None
        # configs proposed but not yet told: config_key -> config. They act
        # as constant-liar observations in _training_data and are excluded
        # from re-proposal, enabling n candidates in flight at once.
        self._pending: dict[tuple, dict] = {}
        # warm start: (config, objective) pairs from a prior campaign (e.g. a
        # TuningStore nearest neighbor) become virtual observations — they seed
        # the surrogate without consuming evaluation budget, and each prior
        # replaces one random initialization sample. Priors occupy the leading
        # training rows (see _training_data); note this row layout changed in
        # the vectorization PR (records-first before), so *warm-started*
        # trajectories differ from older runs — the bit-identity contract
        # covers prior-free campaigns, which are the paper's.
        self._prior_X, self._prior_y = self._encode_priors(prior_records or [])
        self.n_priors = 0 if self._prior_y is None else len(self._prior_y)
        self.n_initial = max(1, n_initial - self.n_priors) if self.n_priors else n_initial

    def _encode_priors(self, records):
        """Encode prior (config, objective) pairs as virtual observations.

        A config may appear more than once — a multi-fidelity cascade
        (repro.fidelity) observes the same schedule at several rungs. Priors
        are deduped by canonical config key so a config contributes exactly
        one training row: callers list records in ascending fidelity order,
        and the *last* (highest-fidelity) objective wins, at the first
        occurrence's row position so the prior-row layout stays stable.
        Configs already recorded in the DB are dropped entirely — a resumed
        campaign's real observation at the current fidelity would otherwise
        be double-counted against its own lower-rung prior.
        """
        by_key: dict[tuple, tuple[np.ndarray, float]] = {}
        for cfg, obj in records:
            try:  # foreign configs (other space revisions) are skipped, not fatal
                self.space.validate(cfg)
                if self.db.contains(cfg):
                    continue
                # dict insertion order keeps the first occurrence's position;
                # assignment keeps the last occurrence's (highest-rung) value
                key = config_key(cfg)
                enc = by_key[key][0] if key in by_key else self.space.encode(cfg)
                by_key[key] = (enc, float(obj))
            except Exception:
                continue
        if not by_key:
            return None, None
        X = np.stack([enc for enc, _ in by_key.values()])
        y = np.array([obj for _, obj in by_key.values()])
        return X, y

    # GP is the learner that does NOT consult the DB to re-select on duplicates
    @property
    def dedups_against_db(self) -> bool:
        return self.learner_name != "GP"

    # -- ask -------------------------------------------------------------------

    def _initial_batch(self) -> list[dict]:
        n = self.n_initial
        if self.init_method == "lhs":
            return self.space.latin_hypercube(n, self.rng)
        return self.space.sample_configurations(n, self.rng)

    def _training_data(self):
        """All recorded evaluations; failures are clipped to a soft penalty so
        the surrogate learns to avoid the region without its scale exploding.
        Pending (in-flight) configs are appended as constant-liar rows whose
        objective is the mean of the real observations, so a batch's later
        proposals see its earlier ones as already claimed."""
        recs = [r for r in self.db.records if r.status in (OK, FAILED)]
        if not recs:
            if self._prior_X is not None:
                return self._liar_augment(self._prior_X, self._prior_y)
            return (None, None) if not self._pending else self._liar_augment(None, None)
        ok_vals = [r.objective for r in recs if r.status == OK]
        cap = (max(ok_vals) * 2.0 + 1e-9) if ok_vals else 1.0
        X = self._encode_records(recs)
        y = np.array([min(r.objective, cap) for r in recs])
        if self._prior_X is not None:
            # priors lead so the row layout is [fixed priors, append-only
            # records, liar tail]: each tell extends the matrix instead of
            # inserting mid-array, which is what lets the GP's incremental
            # Cholesky reuse its cached prefix on warm-started campaigns
            X = np.concatenate([self._prior_X, X])
            y = np.concatenate([self._prior_y, y])
        return self._liar_augment(X, y)

    def _encode_records(self, recs) -> np.ndarray:
        """Encoded feature rows for DB records, memoized by record index (the
        DB is append-only): each record is encoded exactly once per campaign
        instead of once per ask. Row values are identical to
        ``space.encode_many([r.config for r in recs])``."""
        rows = []
        for r in recs:
            row = self._enc_by_index.get(r.index)
            if row is None:
                row = self._enc_by_index[r.index] = self.space.encode(r.config)
            rows.append(row)
        if not rows:
            return np.zeros((0, self.space.n_features()))
        return np.stack(rows)

    def _liar_augment(self, X, y):
        """Append one (encoded config, lied objective) row per pending eval.
        No-op — returning X, y untouched — when nothing is pending, which is
        what keeps ``q=1`` campaigns identical to the legacy serial loop."""
        if not self._pending:
            return X, y
        Xp = self.space.encode_many(list(self._pending.values()))
        lie = float(np.mean(y)) if y is not None and len(y) else 0.0
        yp = np.full(len(Xp), lie)
        if X is None:
            return Xp, yp
        return np.concatenate([X, Xp]), np.concatenate([y, yp])

    # -- pending (in-flight) bookkeeping ---------------------------------------

    def mark_pending(self, config: Mapping[str, Any]) -> None:
        """Register an in-flight evaluation (no-op for configs already in the
        DB — a real observation beats a lie)."""
        key = config_key(config)
        if key not in self._pending and not self.db.contains(config):
            self._pending[key] = dict(config)

    def clear_pending(self, config: Mapping[str, Any]) -> None:
        self._pending.pop(config_key(config), None)

    def is_pending(self, config: Mapping[str, Any]) -> bool:
        return config_key(config) in self._pending

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def _is_fresh(self, config: Mapping[str, Any]) -> bool:
        return not self.db.contains(config) and not self.is_pending(config)

    def _candidate_pool(self) -> tuple[list[dict], np.ndarray]:
        """Candidate pool plus its encoded feature matrix. Inside an
        ``ask(n)`` batch the ``n_candidates`` base samples are drawn and
        encoded exactly once (the first model-guided proposal caches them);
        later proposals only draw fresh mutation candidates around the
        incumbent — their constant-liar rows already steer them apart, so
        re-sampling the whole pool per proposal bought nothing but CPU."""
        if self._batch_active and self._pool_base is not None:
            base, Xb = self._pool_base
        else:
            base = self.space.sample_configurations(self.n_candidates, self.rng)
            Xb = self.space.encode_many(base)
            # prune before caching so a batch pays the feasibility sweep of
            # the base pool once, and n_pruned counts each config once
            base, Xb = self._apply_feasibility(base, Xb)
            if self._batch_active:
                self._pool_base = (base, Xb)
        best = self.db.best()
        if best is not None:  # local perturbations around incumbent
            extra = [self.space.mutate(best.config, self.rng)
                     for _ in range(self.n_candidates // 8)]
            if extra:
                Xe = self.space.encode_many(extra)
                extra, Xe = self._apply_feasibility(extra, Xe)
            if extra:
                return base + extra, np.concatenate([Xb, Xe])
        return list(base), Xb

    def _apply_feasibility(self, pool: list[dict], X: np.ndarray):
        """Drop statically-infeasible candidates (and their feature rows)
        before they reach the surrogate. Sampling already consumed the RNG,
        so pruning never perturbs the stream; with the predicate unset this
        is an identity pass. If *every* candidate is infeasible the raw pool
        survives as a fallback — proposing a doomed config (which tell()
        records as failed) beats proposing nothing."""
        if self.feasibility is None or not pool:
            return pool, X
        mask = np.fromiter((bool(self.feasibility(c)) for c in pool),
                           dtype=bool, count=len(pool))
        n_bad = int(len(pool) - mask.sum())
        if n_bad == 0:
            return pool, X
        self.n_pruned += n_bad
        if not mask.any():
            return pool, X
        return [c for c, keep in zip(pool, mask) if keep], X[mask]

    def ask(self, n: int | None = None) -> dict | list[dict]:
        """Propose the next candidate(s). ``ask()`` returns a single config
        (legacy serial API, no pending registration). ``ask(n)`` returns a
        list of ``n`` configs, each registered pending with a constant-liar
        observation so they can be evaluated concurrently; callers must
        ``tell``/``tell_skipped`` each one to release its pending slot.
        The base candidate pool is sampled and encoded once per batch, so
        ``ask(1)`` consumes RNG exactly like the legacy serial ``ask()``."""
        if n is None:
            return self._ask_one()
        batch = []
        self._batch_active, self._pool_base = True, None
        try:
            for _ in range(n):
                cfg = self._ask_one()
                self.mark_pending(cfg)
                batch.append(cfg)
        finally:
            self._batch_active, self._pool_base = False, None
        return batch

    def _ask_one(self) -> dict:
        # 1) initialization phase (pending evals count toward the quota)
        if len(self.db) + self.n_pending < self.n_initial:
            if not self._init_queue:
                self._init_queue = self._initial_batch()
            while self._init_queue:
                cfg = self._init_queue.pop(0)
                if not self.dedups_against_db or self._is_fresh(cfg):
                    return cfg
            return self.space.sample_configuration(self.rng)

        # 2) model-guided phase
        X, y = self._training_data()
        if X is None or len(np.unique(y)) < 2:
            return self.space.sample_configuration(self.rng)
        seed = int(self.rng.integers(2**31))  # drawn even on the GP-reuse path
        if self.learner_name == "GP":
            # persistent GP: the cached Cholesky factor extends incrementally
            # over the unchanged row-prefix instead of refitting the whole
            # length-scale grid on every proposal (see GaussianProcess)
            if self._gp is None:
                self._gp = surrogates.make_learner("GP", seed=seed)
            model = self._gp.partial_fit(X, y)
        else:
            model = surrogates.make_learner(self.learner_name, seed=seed)
            model.fit(X, y)
        self._model = model

        pool, Xc = self._candidate_pool()
        mu, sigma = model.predict(Xc)
        best = self.db.best()
        scores = self.acq(mu, sigma, kappa=self.kappa,
                          best=best.objective if best else float(np.min(y)))
        order = np.argsort(scores)

        if self.dedups_against_db:
            for i in order:
                if self._is_fresh(pool[int(i)]):
                    return pool[int(i)]
            return self.space.sample_configuration(self.rng)  # pool exhausted
        # GP path: return the argmin even if it repeats a previous evaluation
        return pool[int(order[0])]

    # -- tell ------------------------------------------------------------------

    def tell(self, config: Mapping[str, Any], result: EvalResult) -> Record:
        self.clear_pending(config)
        status = OK if result.ok else FAILED
        return self.db.add(config, result.objective, status=status, info=result.info)

    def tell_skipped(self, config: Mapping[str, Any]) -> Record:
        self.clear_pending(config)
        prior = self.db.lookup(config)
        obj = prior.objective if prior else float("nan")
        return self.db.add(config, obj, status=SKIPPED_DUPLICATE,
                           info={"duplicate_of": prior.index if prior else None})


def run_search(
    space: ConfigurationSpace,
    evaluator: Callable[[Mapping[str, Any]], EvalResult],
    max_evals: int = 100,
    learner: str = "RF",
    seed: int = 1234,
    db_path: str | None = None,
    n_initial: int = 10,
    init_method: str = "lhs",
    kappa: float = 1.96,
    acq: str = "LCB",
    callback: Callable[[Record], None] | None = None,
    warm_start: list | None = None,
    warm_start_records: list[tuple[Mapping[str, Any], float]] | None = None,
    parallel: int = 1,
    executor=None,
    feasibility: Callable[[Mapping[str, Any]], bool] | None = None,
) -> SearchResult:
    """Run a full campaign (Sec. 2.3 steps 4-8) — a thin adapter over
    :class:`repro.engine.Campaign`. Resumable: if ``db_path`` already holds
    records, the campaign continues from them. ``warm_start`` configs (e.g.
    the known default schedule, or a TuningStore best) are evaluated first so
    the surrogate — and the final best — always include them.
    ``warm_start_records`` are already-measured (config, objective) pairs
    from prior campaigns: they seed the surrogate as virtual observations and
    shrink the random-initialization phase, so a warm-started campaign
    converges in far fewer evaluations. ``parallel`` > 1 evaluates that many
    candidates concurrently (constant-liar batching, thread-pool executor);
    ``parallel=1`` reproduces the legacy serial trajectory bit-for-bit."""
    from repro.engine import Campaign  # deferred: engine builds on this module

    return Campaign(
        space, evaluator, max_evals=max_evals, learner=learner, seed=seed,
        db_path=db_path, n_initial=n_initial, init_method=init_method,
        kappa=kappa, acq=acq, callback=callback, warm_start=warm_start,
        warm_start_records=warm_start_records, parallel=parallel,
        executor=executor, feasibility=feasibility,
    ).run()
