"""Performance database: the autotuner's memory and its fault-tolerance log.

Mirrors ytopt's two output files (Sec. 2.3 step 6): ``results.csv`` (one row
per evaluation: parameter values, objective, elapsed wall-clock) and
``results.jsonl`` (full records, one JSON object per line, appended per
evaluation so a campaign's persistence cost stays O(n) instead of the old
rewrite-the-whole-JSON-array O(n²); legacy ``results.json`` directories are
still loadable and are migrated on first open). The DB also provides the duplicate check the
paper describes ("At the evaluation stage, check the performance database to
make sure that this chosen configuration is new") and is the resume log: a
search restarted on the same DB path continues where it stopped, which is the
checkpoint/restart story for long autotuning campaigns.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import os
import time
from typing import Any, Iterable, Mapping

from repro.core.jsonl import append_jsonl, repair_torn_tail
from repro.core.space import config_key

__all__ = ["Record", "PerformanceDatabase"]

OK = "ok"
FAILED = "failed"
SKIPPED_DUPLICATE = "skipped-duplicate"


@dataclasses.dataclass
class Record:
    index: int
    config: dict
    objective: float
    elapsed_sec: float
    status: str = OK
    info: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "index": self.index,
            "config": self.config,
            "objective": self.objective,
            "elapsed_sec": self.elapsed_sec,
            "status": self.status,
            "info": self.info,
        }

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "Record":
        return cls(
            index=int(d["index"]),
            config=dict(d["config"]),
            objective=float(d["objective"]),
            elapsed_sec=float(d["elapsed_sec"]),
            status=str(d.get("status", OK)),
            info=dict(d.get("info", {})),
        )


class PerformanceDatabase:
    """In-memory DB with optional persistent ``results.csv``/``results.json``."""

    def __init__(self, path: str | None = None, param_names: Iterable[str] | None = None):
        self.path = path
        self.param_names = list(param_names) if param_names else []
        self.records: list[Record] = []
        self._seen: dict[tuple, int] = {}
        self._t0 = time.perf_counter()
        if path:
            os.makedirs(path, exist_ok=True)
            self._maybe_load()

    # -- core API ---------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.records)

    def contains(self, config: Mapping[str, Any]) -> bool:
        return config_key(config) in self._seen

    def lookup(self, config: Mapping[str, Any]) -> Record | None:
        idx = self._seen.get(config_key(config))
        return self.records[idx] if idx is not None else None

    def add(
        self,
        config: Mapping[str, Any],
        objective: float,
        elapsed_sec: float | None = None,
        status: str = OK,
        info: Mapping[str, Any] | None = None,
    ) -> Record:
        rec = Record(
            index=len(self.records),
            config=dict(config),
            objective=float(objective),
            elapsed_sec=float(
                elapsed_sec if elapsed_sec is not None else time.perf_counter() - self._t0
            ),
            status=status,
            info=dict(info or {}),
        )
        self.records.append(rec)
        key = config_key(config)
        if key not in self._seen:  # first occurrence wins lookup
            self._seen[key] = rec.index
        if self.path:
            # deferred import: obs sits above core in the layering, and the
            # span is only worth paying for on the persistent path
            from repro.obs.trace import span as obs_span

            with obs_span("db.checkpoint", index=rec.index):
                self._append_csv(rec)
                self._append_jsonl(rec)
        return rec

    # -- analysis (findMin.py role lives in findmin.py, built on these) ----------

    def evaluated(self) -> list[Record]:
        return [r for r in self.records if r.status == OK]

    def best(self) -> Record | None:
        ok = self.evaluated()
        return min(ok, key=lambda r: r.objective) if ok else None

    def best_trajectory(self) -> list[float]:
        """Running best objective per evaluation (the red line in Figs 3-11)."""
        out, cur = [], float("inf")
        for r in self.records:
            if r.status == OK:
                cur = min(cur, r.objective)
            out.append(cur)
        return out

    # -- persistence --------------------------------------------------------------

    def _csv_path(self) -> str:
        return os.path.join(self.path, "results.csv")

    def _json_path(self) -> str:
        return os.path.join(self.path, "results.json")

    def _jsonl_path(self) -> str:
        return os.path.join(self.path, "results.jsonl")

    def _ensure_param_names(self, config: Mapping[str, Any]) -> None:
        for k in config:
            if k not in self.param_names:
                self.param_names.append(k)

    def _append_csv(self, rec: Record) -> None:
        self._ensure_param_names(rec.config)
        path = self._csv_path()
        new = not os.path.exists(path)
        with open(path, "a", newline="") as f:
            w = csv.writer(f)
            if new:
                w.writerow(self.param_names + ["objective", "elapsed_sec", "status"])
            w.writerow(
                [json.dumps(rec.config.get(k)) for k in self.param_names]
                + [rec.objective, rec.elapsed_sec, rec.status]
            )

    def _append_jsonl(self, rec: Record) -> None:
        # each record is a crash-safe resume point
        append_jsonl(self._jsonl_path(), rec.to_json())

    def _load_records(self, data: Iterable[Mapping[str, Any]]) -> None:
        for d in data:
            rec = Record.from_json(d)
            rec.index = len(self.records)
            self.records.append(rec)
            key = config_key(rec.config)
            self._seen.setdefault(key, rec.index)

    def _maybe_load(self) -> None:
        jsonl = self._jsonl_path()
        if os.path.exists(jsonl):
            # terminate any torn tail first so later appends stay
            # line-delimited instead of merging into the fragment
            repair_torn_tail(jsonl)
            with open(jsonl) as f:
                rows = []
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rows.append(json.loads(line))
                    except json.JSONDecodeError:
                        continue  # isolated torn fragment from a crash
            self._load_records(rows)
            return
        legacy = self._json_path()
        if not os.path.exists(legacy):
            return
        with open(legacy) as f:
            self._load_records(json.load(f))
        # migrate once so future appends extend the full history
        tmp = jsonl + ".tmp"
        with open(tmp, "w") as f:
            for r in self.records:
                f.write(json.dumps(r.to_json()) + "\n")
        os.replace(tmp, jsonl)
