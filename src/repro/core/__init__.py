"""repro.core — the paper's contribution: a Bayesian-optimization autotuner
for JAX/Pallas program schedules (ytopt-lineage, rebuilt for TPU targets)."""

from repro.core.acquisition import expected_improvement, lcb, make_acquisition
from repro.core.database import PerformanceDatabase, Record
from repro.core.findmin import find_min, importance_report
from repro.core.plopper import (
    PENALTY,
    CostModelEvaluator,
    DeadlineEvaluator,
    EvalResult,
    TimingEvaluator,
)
from repro.core.search import BayesianSearch, SearchResult, run_search
from repro.core.space import (
    Categorical,
    ConfigurationSpace,
    Constant,
    EqualsCondition,
    Float,
    ForbiddenClause,
    InCondition,
    Integer,
    Ordinal,
    config_key,
)
from repro.core.surrogates import (
    LEARNERS,
    ExtraTrees,
    GaussianProcess,
    GradientBoostedTrees,
    RandomForest,
    RegressionTree,
    make_learner,
)
from repro.core.tuner import autotune, compare_learners

__all__ = [
    "Categorical", "ConfigurationSpace", "Constant", "EqualsCondition", "Float",
    "ForbiddenClause", "InCondition", "Integer", "Ordinal", "config_key",
    "RegressionTree", "RandomForest", "ExtraTrees", "GradientBoostedTrees",
    "GaussianProcess", "make_learner", "LEARNERS",
    "lcb", "expected_improvement", "make_acquisition",
    "PerformanceDatabase", "Record",
    "EvalResult", "TimingEvaluator", "CostModelEvaluator", "DeadlineEvaluator", "PENALTY",
    "BayesianSearch", "SearchResult", "run_search",
    "autotune", "compare_learners", "find_min", "importance_report",
]
