"""Acquisition functions for Bayesian optimization.

The paper uses the lower-confidence-bound (LCB) acquisition: minimize
``mu - kappa * sigma`` so uncertainty draws the search toward unexplored,
potentially-better regions while the surrogate mean exploits known-good ones.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["lcb", "expected_improvement", "ACQUISITIONS", "make_acquisition"]


def lcb(mu: np.ndarray, sigma: np.ndarray, kappa: float = 1.96, **_) -> np.ndarray:
    """Lower confidence bound. Smaller is more promising (we minimize)."""
    return mu - kappa * sigma


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _norm_cdf(z):
    # Abramowitz–Stegun style erf; avoids a scipy dependency
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def expected_improvement(mu: np.ndarray, sigma: np.ndarray, best: float = 0.0, xi: float = 0.01,
                         **_) -> np.ndarray:
    """Negated EI for minimization (smaller return = more promising)."""
    sigma = np.maximum(sigma, 1e-12)
    z = (best - xi - mu) / sigma
    ei = (best - xi - mu) * _norm_cdf(z) + sigma * _norm_pdf(z)
    return -ei


ACQUISITIONS = ("LCB", "EI")


def make_acquisition(name: str):
    name = name.upper()
    if name == "LCB":
        return lcb
    if name == "EI":
        return expected_improvement
    raise ValueError(f"unknown acquisition {name!r}; options: {ACQUISITIONS}")
