"""Shadow evaluation: live traffic as free tuning hardware.

For an epsilon fraction of eager dispatch executions the instrumented
wrapper blocks until the result is ready and hands the true wall time to
:meth:`ShadowEvaluator.on_shadow`, which ``tell``s it into the
:class:`TuningStore` (the store's strict-improvement ``put`` is the
accept test). A sub-fraction of those shadow samples additionally builds
and times a *challenger* config — a store neighbor or a seeded space
sample — on the live arguments, promoting it (put + hot-swap
invalidate) when it beats the incumbent.

Sampling is deterministic (per-signature call counters, not RNG): every
``round(1/epsilon)``-th execution is shadowed, every
``round(1/challenger_fraction)``-th shadow tries a challenger. Shadowing
never breaks serving: every failure path is swallowed into a counter.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

__all__ = ["ShadowPolicy", "ShadowEvaluator"]


@dataclasses.dataclass(frozen=True)
class ShadowPolicy:
    epsilon: float = 0.01             # fraction of executions shadow-timed
    challenger_fraction: float = 0.1  # fraction of shadow samples that race a challenger
    challenger_neighbors: int = 3     # store neighbors considered as challengers
    seed: int = 0                     # space-sample challenger stream

    def shadow_period(self) -> int:
        return max(1, round(1.0 / self.epsilon)) if self.epsilon > 0 else 0

    def challenger_period(self) -> int:
        return (max(1, round(1.0 / self.challenger_fraction))
                if self.challenger_fraction > 0 else 0)


class ShadowEvaluator:
    def __init__(self, service, policy: ShadowPolicy = ShadowPolicy()):
        self.service = service
        self.policy = policy
        self._period = policy.shadow_period()           # hoisted off the hot path
        self._challenger_period = policy.challenger_period()
        self._lock = threading.Lock()
        self._calls: Dict[Tuple[str, str], int] = {}   # per-signature executions
        self._samples: Dict[Tuple[str, str], int] = {}  # per-signature shadows
        self._rng = np.random.default_rng(policy.seed)
        self.stats: Dict[str, int] = {
            "shadow_evals": 0, "shadow_tells": 0, "shadow_skipped": 0,
            "shadow_errors": 0, "challenger_evals": 0, "challenger_promoted": 0,
            "challenger_infeasible": 0,
        }

    # -- sampling decision (serving hot path: every dispatch execution pays
    # this, so it is deliberately lock-free — each get/set is atomic under
    # the GIL, and a racing increment can at worst lose a count, which only
    # nudges *when* the next shadow sample lands, never correctness; the
    # lock stays on the cold paths (stats, challenger RNG))
    def shadow_mode(self, kernel: str, sig_key: str) -> Optional[str]:
        """None (don't shadow) | "observe" | "challenger" for this call."""
        period = self._period
        if period == 0:
            return None
        k = (kernel, sig_key)
        n = self._calls.get(k, 0) + 1
        self._calls[k] = n
        if n % period != 0:
            return None
        s = self._samples.get(k, 0) + 1
        self._samples[k] = s
        ch = self._challenger_period
        return "challenger" if (ch and s % ch == 0) else "observe"

    # -- the measurement sink ---------------------------------------------
    def on_shadow(self, kernel: str, sig, config: dict, static_kw: dict,
                  args: tuple, measured_sec: float, mode: str) -> None:
        """Handle one shadow measurement. Never raises."""
        svc = self.service
        try:
            import jax

            if any(isinstance(a, jax.core.Tracer) for a in args):
                # jit tracing of a serve step, not a real execution: a
                # trace-time measurement is meaningless and a challenger
                # build inside a trace would be catastrophic
                self._count("shadow_skipped")
                return
            self._count("shadow_evals")
            svc.metrics.add("guard_shadow_evals_total", kernel=kernel)
            from repro.dispatch.store import TuningRecord

            if svc.store is not None and self._tell(TuningRecord(
                    kernel=kernel, signature=tuple(sig), backend=svc.backend,
                    config=dict(config), objective=float(measured_sec),
                    n_evals=1, source="shadow")):
                self._count("shadow_tells")
            if mode == "challenger":
                self._challenge(kernel, sig, config, static_kw, args)
        except Exception:  # noqa: BLE001 — shadowing must never break serving
            self._count("shadow_errors")
            svc.metrics.add("guard_shadow_errors_total", kernel=kernel)

    def _tell(self, rec) -> bool:
        return bool(self.service.store.put(rec))

    # -- challenger path ---------------------------------------------------
    def _pick_challenger(self, kernel: str, sig, config: dict) -> Optional[dict]:
        from repro.core.space import config_key
        from repro.dispatch.registry import get as get_variant
        from repro.dispatch.signature import signature_distance

        svc = self.service
        incumbent = config_key(config)
        if svc.store is not None:
            ranked = sorted(
                (r for r in svc.store.records(kernel=kernel, backend=svc.backend)
                 if signature_distance(tuple(sig), r.signature) != float("inf")),
                key=lambda r: signature_distance(tuple(sig), r.signature))
            for r in ranked[: self.policy.challenger_neighbors]:
                if config_key(r.config) != incumbent:
                    return dict(r.config)
        space = get_variant(kernel).space(svc.target)
        for _ in range(8):  # resample past the incumbent
            cand = space.sample_configuration(self._rng)
            if config_key(cand) != incumbent:
                return cand
        return None

    def _challenge(self, kernel: str, sig, config: dict, static_kw: dict,
                   args: tuple) -> None:
        import jax

        from repro.analyze.feasibility import check_config
        from repro.dispatch.registry import get as get_variant
        from repro.dispatch.store import TuningRecord

        svc = self.service
        cand = self._pick_challenger(kernel, sig, config)
        if cand is None:
            return
        verdict = check_config(kernel, cand, signature=tuple(sig),
                               target=svc.target)
        if not verdict.ok:
            self._count("challenger_infeasible")
            return
        spec = get_variant(kernel)
        built = spec.builder(cand, **static_kw)
        fn = jax.jit(built) if svc.jit else built
        jax.block_until_ready(fn(*args))  # compile + warm
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        self._count("challenger_evals")
        svc.metrics.add("guard_challenger_evals_total", kernel=kernel)
        if svc.store is not None and self._tell(TuningRecord(
                kernel=kernel, signature=tuple(sig), backend=svc.backend,
                config=dict(cand), objective=float(dt), n_evals=1,
                source="shadow_challenger")):
            self._count("challenger_promoted")
            svc.metrics.add("guard_challenger_promoted_total", kernel=kernel)
            svc.invalidate(kernel, tuple(sig))

    def _count(self, key: str) -> None:
        with self._lock:
            self.stats[key] += 1

    def snapshot_stats(self) -> Dict[str, Any]:
        with self._lock:
            return dict(self.stats)
