"""Deterministic fault injection for chaos testing.

A *fault point* is a named hook compiled into production code paths
(``fault_point("eval.crash")``).  When no fault is armed the hook is a
dict-emptiness check — effectively free — so the points stay in the
shipped code rather than living only in test monkeypatches.

Faults are armed either programmatically::

    with inject("dispatch.latency", delay_sec=0.05, where={"kernel": "syr2k"}):
        ...

or from the environment (picked up at import time and by ``install_env_faults``)::

    REPRO_FAULTS="eval.crash:times=2;transport.partition"

Activation is deterministic: ``times=N`` fires on the first N matching
hits, ``every=K`` fires on every K-th hit, ``where`` restricts firing to
call sites whose context labels contain the given substrings.  Hang
faults block on an Event with a bounded ``hang_max_sec`` and are released
when the arming context exits, so a "hung" worker thread never outlives
the test that created it.

This module is intentionally self-contained (stdlib only) so that
low-level modules such as ``repro.core.jsonl`` can import it without
creating layering cycles.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Optional

__all__ = [
    "CATALOG",
    "Fault",
    "FaultInjected",
    "active_faults",
    "clear_faults",
    "fault_hit",
    "fault_point",
    "inject",
    "install_env_faults",
]


class FaultInjected(Exception):
    """Raised by a fault point armed with ``raises=True``."""


# Named injection points and their default behavior when armed without
# explicit parameters (env var or bare inject(name)).  Call sites may
# reference points not listed here, but these are the supported set —
# ``repro-guard faults`` prints this catalog.
CATALOG: Dict[str, Dict[str, Any]] = {
    "eval.hang": {"hang": True,
                  "doc": "evaluator blocks until released (bounded by hang_max_sec)"},
    "eval.crash": {"raises": True,
                   "doc": "evaluator raises FaultInjected"},
    "eval.slow": {"delay_sec": 0.25,
                  "doc": "evaluator sleeps delay_sec (pathological slowdown)"},
    "dispatch.latency": {"delay_sec": 0.05,
                         "doc": "served executable sleeps delay_sec (latency inflation)"},
    "transport.flake": {"raises": True, "times": 1,
                        "doc": "one transport op raises ConnectionError, then heals"},
    "transport.partition": {"raises": True,
                            "doc": "every transport op raises ConnectionError"},
    "store.torn_write": {"times": 1,
                         "doc": "next JSONL append writes a torn half-line then dies"},
}


@dataclasses.dataclass
class Fault:
    """One armed fault: firing rule + behavior."""

    point: str
    times: Optional[int] = None      # fire on first N matching hits (None = unlimited)
    every: int = 1                   # fire on every K-th matching hit
    where: Optional[Dict[str, str]] = None  # substring filters on call-site context
    delay_sec: float = 0.0           # sleep before raising/returning
    hang: bool = False               # block on the release event
    hang_max_sec: float = 30.0       # upper bound on a hang
    raises: bool = False             # raise exc after delay/hang
    exc: type = FaultInjected

    # mutable state
    hits: int = 0
    fired: int = 0

    def __post_init__(self) -> None:
        self.release_event = threading.Event()

    def matches(self, ctx: Dict[str, Any]) -> bool:
        if not self.where:
            return True
        return all(v in str(ctx.get(k, "")) for k, v in self.where.items())

    def release(self) -> None:
        """Unblock any thread parked on this fault's hang."""
        self.release_event.set()


_lock = threading.Lock()
_ACTIVE: Dict[str, Fault] = {}


def _arm(fault: Fault) -> Fault:
    with _lock:
        _ACTIVE[fault.point] = fault
    return fault


def _disarm(point: str) -> None:
    with _lock:
        fault = _ACTIVE.pop(point, None)
    if fault is not None:
        fault.release()


def clear_faults() -> None:
    """Disarm everything (releases pending hangs)."""
    with _lock:
        faults = list(_ACTIVE.values())
        _ACTIVE.clear()
    for f in faults:
        f.release()


def active_faults() -> Dict[str, Fault]:
    with _lock:
        return dict(_ACTIVE)


def fault_hit(point: str, **ctx: Any) -> Optional[Fault]:
    """Return the armed fault if this hit fires, without applying behavior.

    For call sites with fault-specific semantics (e.g. the torn-write
    point in ``append_jsonl`` writes half a line itself).
    """
    if not _ACTIVE:
        return None
    with _lock:
        fault = _ACTIVE.get(point)
        if fault is None or not fault.matches(ctx):
            return None
        fault.hits += 1
        if fault.hits % max(fault.every, 1) != 0:
            return None
        if fault.times is not None and fault.fired >= fault.times:
            return None
        fault.fired += 1
        return fault


def fault_point(point: str, **ctx: Any) -> bool:
    """Production hook: apply the armed fault's behavior, if any.

    Returns True if a fault fired.  Near-zero cost when nothing is armed.
    """
    if not _ACTIVE:
        return False
    fault = fault_hit(point, **ctx)
    if fault is None:
        return False
    if fault.delay_sec > 0.0:
        # interruptible sleep: released early when the fault is disarmed
        fault.release_event.wait(fault.delay_sec)
    if fault.hang:
        fault.release_event.wait(fault.hang_max_sec)
    if fault.raises:
        raise fault.exc(f"injected fault: {point}")
    return True


@contextmanager
def inject(point: str, **kw: Any) -> Iterator[Fault]:
    """Arm ``point`` for the duration of the block.

    Unspecified behavior fields default to the CATALOG entry for the
    point.  On exit the fault is disarmed and any parked hang released.
    """
    fault = _arm(_build(point, kw))
    try:
        yield fault
    finally:
        _disarm(point)


def _build(point: str, kw: Dict[str, Any]) -> Fault:
    defaults = {k: v for k, v in CATALOG.get(point, {}).items() if k != "doc"}
    merged = {**defaults, **kw}
    if merged.get("raises") and "exc" not in merged and point.startswith("transport."):
        merged["exc"] = ConnectionError
    return Fault(point=point, **merged)


def install_env_faults(spec: Optional[str] = None) -> int:
    """Arm faults from a ``REPRO_FAULTS`` spec string.

    Grammar: ``point[:key=val,...]`` joined by ``;``.  Keys: ``times``,
    ``every``, ``delay`` (sec), ``hang_max`` (sec), ``hang``, ``raise``,
    ``where.<label>=<substring>``.  Returns the number of faults armed.
    """
    spec = os.environ.get("REPRO_FAULTS", "") if spec is None else spec
    n = 0
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        name, _, params = part.partition(":")
        kw: Dict[str, Any] = {}
        where: Dict[str, str] = {}
        for item in params.split(","):
            item = item.strip()
            if not item:
                continue
            key, _, val = item.partition("=")
            if key == "times":
                kw["times"] = int(val)
            elif key == "every":
                kw["every"] = int(val)
            elif key == "delay":
                kw["delay_sec"] = float(val)
            elif key == "hang_max":
                kw["hang_max_sec"] = float(val)
            elif key == "hang":
                kw["hang"] = val.lower() not in ("0", "false")
            elif key == "raise":
                kw["raises"] = val.lower() not in ("0", "false")
            elif key.startswith("where."):
                where[key[len("where."):]] = val
        if where:
            kw["where"] = where
        _arm(_build(name.strip(), kw))
        n += 1
    return n


if os.environ.get("REPRO_FAULTS"):
    install_env_faults()
