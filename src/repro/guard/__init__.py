"""repro.guard: resilience layer — hardened evaluation, shadow evaluation,
drift/regression watch, and deterministic fault injection.

Imports are lazy (PEP 562) so that low-level modules (e.g.
``repro.core.jsonl``) can import ``repro.guard.faults`` without pulling
in the jax-dependent harden/shadow/watch machinery.
"""

_EXPORTS = {
    "FaultInjected": "faults",
    "Fault": "faults",
    "inject": "faults",
    "fault_point": "faults",
    "fault_hit": "faults",
    "install_env_faults": "faults",
    "clear_faults": "faults",
    "active_faults": "faults",
    "CATALOG": "faults",
    "FailureObservation": "harden",
    "HardenPolicy": "harden",
    "HardenedExecutor": "harden",
    "ShadowPolicy": "shadow",
    "ShadowEvaluator": "shadow",
    "WatchPolicy": "watch",
    "GuardAgent": "watch",
    "window_stats": "watch",
    "replay_decisions": "watch",
    "guard_counters": "watch",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.guard' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f"repro.guard.{mod}"), name)
