"""Drift/regression watch: live latency vs stored baseline.

The PR 6 per-signature ``dispatch_execute_seconds`` histograms give every
served signature a live latency distribution; every :class:`TuningRecord`
carries the objective measured when the config was tuned. The watcher
closes the loop: each check folds the registry, subtracts the previous
fold (fixed bucket bounds make delta histograms element-wise), and
compares the *window* p50 against ``drift_factor x baseline``. Sustained
breaches (``hysteresis`` consecutive windows, outside ``cooldown_sec``)
quarantine the record with a machine-readable ``drift:<ratio>x`` reason,
invalidate the executable cache (serving degrades to the default config),
and nudge the background tuner to re-campaign the signature.

The decision core is pure over (previous snapshot, current snapshot,
baselines), so :func:`replay_decisions` can re-run the exact policy over
an obs snapshot JSONL offline — ``repro-guard replay``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.metrics import histogram_quantile

__all__ = [
    "WatchPolicy",
    "GuardAgent",
    "window_stats",
    "replay_decisions",
    "guard_counters",
]

WindowKey = Tuple[str, str, str]  # (kernel, signature_key, backend)


@dataclasses.dataclass(frozen=True)
class WatchPolicy:
    interval_sec: float = 10.0   # watch-thread check period
    drift_factor: float = 3.0    # window p50 must exceed factor x baseline
    hysteresis: int = 2          # consecutive breaching windows before acting
    cooldown_sec: float = 60.0   # per-signature quiet period after an action
    min_samples: int = 8         # executions required per window


@dataclasses.dataclass
class _DriftState:
    breaches: int = 0
    last_action: float = float("-inf")  # monotonic seconds


def window_stats(prev_snap: Optional[dict], cur_snap: dict,
                 name: str = "dispatch_execute_seconds") -> Dict[WindowKey, dict]:
    """Per-signature stats for the *window* between two snapshots.

    Bucket bounds are a fixed constant, so the window histogram is just
    element-wise count subtraction — no per-observation state needed.
    """
    prev_cells: Dict[WindowKey, dict] = {}
    for h in (prev_snap or {}).get("histograms", []):
        if h["name"] == name:
            prev_cells[_cell_key(h)] = h
    out: Dict[WindowKey, dict] = {}
    for h in cur_snap.get("histograms", []):
        if h["name"] != name:
            continue
        key = _cell_key(h)
        prev = prev_cells.get(key)
        counts = list(h["counts"])
        total_sum = float(h["sum"])
        if prev is not None:
            counts = [int(c) - int(p) for c, p in zip(counts, prev["counts"])]
            total_sum -= float(prev["sum"])
        count = sum(counts)
        if count <= 0:
            continue
        out[key] = {
            "count": count,
            "sum": total_sum,
            "p50": histogram_quantile(counts, 0.50),
            "p99": histogram_quantile(counts, 0.99),
        }
    return out


def _cell_key(h: dict) -> WindowKey:
    lab = h["labels"]
    return (lab.get("kernel", ""), lab.get("signature", ""),
            lab.get("backend", ""))


def _decide(windows: Dict[WindowKey, dict],
            baselines: Dict[WindowKey, float],
            states: Dict[WindowKey, _DriftState],
            policy: WatchPolicy, now: float) -> List[dict]:
    """Pure drift-policy core: updates ``states`` in place, returns the
    quarantine decisions for this window. No I/O, no store access."""
    decisions: List[dict] = []
    for key, w in sorted(windows.items()):
        if w["count"] < policy.min_samples:
            continue
        baseline = baselines.get(key)
        if baseline is None or baseline <= 0.0:
            states.pop(key, None)
            continue
        state = states.setdefault(key, _DriftState())
        if w["p50"] <= policy.drift_factor * baseline:
            state.breaches = 0
            continue
        state.breaches += 1
        ratio = w["p50"] / baseline
        if state.breaches < policy.hysteresis:
            continue
        if now - state.last_action < policy.cooldown_sec:
            continue
        state.last_action = now
        state.breaches = 0
        kernel, sig_key, backend = key
        decisions.append({
            "action": "quarantine",
            "kernel": kernel,
            "signature": sig_key,
            "backend": backend,
            "reason": f"drift:{ratio:.1f}x",
            "p50_sec": w["p50"],
            "p99_sec": w["p99"],
            "baseline_sec": baseline,
            "window_count": w["count"],
        })
    return decisions


class GuardAgent:
    """The guard umbrella bound to one :class:`DispatchService` via
    ``service.attach_guard(agent)``: shadow-evaluation sampling hooks plus
    the drift-watch thread. ``check_once()`` runs a single watch cycle
    (what the thread loop and the chaos tests call)."""

    def __init__(self, service, *, watch: WatchPolicy = WatchPolicy(),
                 shadow=None, decisions_path: Optional[str] = None):
        from repro.guard.shadow import ShadowEvaluator, ShadowPolicy

        self.service = service
        self.watch = watch
        self.shadow = (ShadowEvaluator(service, shadow)
                       if isinstance(shadow, ShadowPolicy) else shadow)
        self.decisions_path = decisions_path
        self.decisions: List[dict] = []
        self.stats: Dict[str, int] = {
            "checks": 0, "quarantines": 0, "fallbacks": 0, "retunes": 0,
            "watch_errors": 0,
        }
        self._prev_snap: Optional[dict] = None
        self._states: Dict[WindowKey, _DriftState] = {}
        self._lock = threading.Lock()
        self._stopping = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- shadow hooks (called from the instrumented execute wrapper) -------
    def shadow_mode(self, kernel: str, sig_key: str) -> Optional[str]:
        if self.shadow is None:
            return None
        return self.shadow.shadow_mode(kernel, sig_key)

    def on_shadow(self, kernel: str, sig, config, static_kw, args,
                  measured_sec: float, mode: str) -> None:
        if self.shadow is not None:
            self.shadow.on_shadow(kernel, sig, config, static_kw, args,
                                  measured_sec, mode)

    # -- the watch cycle ---------------------------------------------------
    def _baselines(self) -> Dict[WindowKey, float]:
        svc = self.service
        if svc.store is None:
            return {}
        svc.store.refresh()
        from repro.dispatch.signature import signature_key

        return {(r.kernel, signature_key(r.signature), r.backend):
                float(r.objective) for r in svc.store.records()}

    def check_once(self) -> List[dict]:
        """One watch cycle; returns (and applies) this window's decisions."""
        svc = self.service
        snap = svc.metrics.snapshot()
        with self._lock:
            prev, self._prev_snap = self._prev_snap, snap
            self.stats["checks"] += 1
        svc.metrics.add("guard_checks_total")
        if prev is None:
            return []
        windows = window_stats(prev, snap)
        baselines = self._baselines()  # store I/O stays outside the guard lock
        with self._lock:
            decisions = _decide(windows, baselines, self._states,
                                self.watch, time.monotonic())
        for d in decisions:
            self._apply(d)
        return decisions

    def _apply(self, decision: dict) -> None:
        from repro.dispatch.signature import parse_signature_key

        svc = self.service
        kernel = decision["kernel"]
        sig = parse_signature_key(decision["signature"])
        rec = svc.store.peek(kernel, sig, decision["backend"])
        if rec is not None:
            svc.store.quarantine(rec, reason=decision["reason"])
            decision["config"] = dict(rec.config)
        svc.invalidate(kernel, sig)
        retuned = False
        if hasattr(svc, "request_retune"):
            retuned = bool(svc.request_retune(kernel, decision["signature"]))
        decision["retune_requested"] = retuned
        decision["time"] = time.time()
        with self._lock:
            self.stats["quarantines"] += 1
            self.stats["fallbacks"] += 1  # serving degrades to default now
            if retuned:
                self.stats["retunes"] += 1
            self.decisions.append(dict(decision))
        svc.metrics.add("guard_quarantines_total", kernel=kernel)
        svc.metrics.add("guard_fallbacks_total", kernel=kernel)
        if self.decisions_path:
            from repro.core.jsonl import append_jsonl

            append_jsonl(self.decisions_path, decision)

    # -- thread lifecycle (SyncAgent-style) --------------------------------
    def start(self) -> "GuardAgent":
        if self._thread is not None:
            return self
        self._stopping.clear()
        self._thread = threading.Thread(target=self._run, name="repro-guard",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._stopping.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def nudge(self) -> None:
        self._wake.set()

    def _run(self) -> None:
        while not self._stopping.is_set():
            try:
                self.check_once()
            except Exception:  # noqa: BLE001 — watch must outlive bad cycles
                with self._lock:
                    self.stats["watch_errors"] += 1
                self.service.metrics.add("guard_watch_errors_total")
            self._wake.wait(self.watch.interval_sec)
            self._wake.clear()

    # -- reporting ---------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = {
                **self.stats,
                "decisions": [dict(d) for d in self.decisions[-20:]],
                "watching": {
                    "drift_factor": self.watch.drift_factor,
                    "hysteresis": self.watch.hysteresis,
                    "cooldown_sec": self.watch.cooldown_sec,
                    "min_samples": self.watch.min_samples,
                },
            }
        if self.shadow is not None:
            out["shadow"] = self.shadow.snapshot_stats()
        out["counters"] = guard_counters(self.service.metrics.snapshot())
        return out


def guard_counters(snapshot: dict, prefix: str = "guard_") -> Dict[str, float]:
    """Aggregate ``guard_*`` counters from an obs snapshot (labels folded),
    e.g. hardened-executor failure counts recorded by background campaigns."""
    out: Dict[str, float] = {}
    for c in snapshot.get("counters", []):
        if c["name"].startswith(prefix):
            out[c["name"]] = out.get(c["name"], 0.0) + float(c["value"])
    return out


def replay_decisions(snapshots: List[dict],
                     baselines: Dict[WindowKey, float],
                     policy: WatchPolicy = WatchPolicy()) -> List[dict]:
    """Re-run the drift policy over a recorded obs snapshot sequence
    (``repro.obs.export.read_snapshot_file(..., merge=False)`` lines) with
    no side effects: the offline audit of what the live watcher did (or
    would have done). Snapshot *i* vs *i+1* forms window *i*."""
    states: Dict[WindowKey, _DriftState] = {}
    out: List[dict] = []
    for i in range(1, len(snapshots)):
        prev = snapshots[i - 1].get("snapshot", snapshots[i - 1])
        cur = snapshots[i].get("snapshot", snapshots[i])
        windows = window_stats(prev, cur)
        for d in _decide(windows, baselines, states, policy,
                         now=i * max(policy.interval_sec, 1e-9)):
            d["window_index"] = i
            out.append(d)
    return out
