"""Hardened campaign evaluation: deadlines, crash isolation, and
pathological-slowdown detection.

CATBench-class autotuning evaluations hang, crash, or return absurd
timings; a production tuner must absorb those as *data*.
:class:`HardenedExecutor` wraps any evaluator behind the engine's
``Executor`` protocol and converts each failure mode into a structured
:class:`FailureObservation` whose penalized objective flows through the
campaign's normal ``tell`` path — so the record lands in the
``PerformanceDatabase`` with status FAILED and the surrogate learns to
avoid the region instead of merely skipping it.

Reason codes match the PR 7 quarantine taxonomy (machine-readable
``<kind>[:<detail>]``): ``eval_timeout:<deadline>s``,
``eval_crash:<ExcType>``, ``pathological_slowdown:<ratio>x``.

Worker threads are daemonic and spawned per submission (the campaign
already bounds in-flight work to ``max_inflight``), so a genuinely hung
evaluator is abandoned — it can neither stall the campaign nor block
interpreter exit.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Mapping, Optional

from repro.core.plopper import PENALTY, EvalResult
from repro.guard.faults import fault_point

__all__ = [
    "REASON_CRASH",
    "REASON_DRIFT",
    "REASON_PATHOLOGICAL",
    "REASON_TIMEOUT",
    "FailureObservation",
    "HardenPolicy",
    "HardenedExecutor",
]

REASON_TIMEOUT = "eval_timeout"
REASON_CRASH = "eval_crash"
REASON_PATHOLOGICAL = "pathological_slowdown"
REASON_DRIFT = "drift"  # emitted by the watch layer, listed here for the taxonomy


@dataclasses.dataclass(frozen=True)
class FailureObservation:
    """A failed evaluation, structured: kind + machine-readable reason +
    the penalized objective fed back to the surrogate."""

    kind: str            # "timeout" | "exception" | "pathological"
    reason: str          # e.g. "eval_timeout:5.0s", "eval_crash:ValueError"
    objective: float     # penalized objective (seconds scale when informative)
    wall_sec: float
    config: Dict[str, Any]
    detail: str = ""

    def to_eval_result(self) -> EvalResult:
        return EvalResult(self.objective, False, {
            "failure": self.kind,
            "reason": self.reason,
            "wall_sec": round(self.wall_sec, 6),
            "detail": self.detail,
        })


@dataclasses.dataclass(frozen=True)
class HardenPolicy:
    """Knobs for hardened evaluation.

    ``deadline_sec=None`` disables the timeout (crash isolation still
    applies). The timeout penalty is ``deadline_sec * timeout_penalty_scale``
    — region-informative (a slow region scores worse than a fast one's
    deadline) rather than the flat :data:`PENALTY` used for crashes.
    ``baseline_sec`` (e.g. the warm-start incumbent) arms the
    pathological-slowdown check: an *ok* result slower than
    ``baseline_sec * slowdown_factor`` is reclassified as a failure,
    keeping its measured objective.
    """

    deadline_sec: Optional[float] = None
    timeout_penalty_scale: float = 10.0
    baseline_sec: Optional[float] = None
    slowdown_factor: float = 50.0
    crash_penalty: float = PENALTY


class HardenedExecutor:
    """Engine ``Executor`` adding per-evaluation deadlines + crash isolation.

    With ``parallel=1``, no deadline expiry, and a well-behaved evaluator
    the submit/result ordering is identical to ``InlineExecutor`` —
    fixed-seed campaign trajectories are bit-identical (pinned by test).
    """

    def __init__(self, evaluator: Callable[[Mapping[str, Any]], EvalResult],
                 policy: HardenPolicy = HardenPolicy(), *, parallel: int = 1,
                 metrics=None, labels: Optional[Dict[str, str]] = None):
        if parallel < 1:
            raise ValueError(f"parallel must be >= 1, got {parallel}")
        self.evaluator = evaluator
        self.policy = policy
        self.max_inflight = parallel
        self.labels = dict(labels or {})
        self._metrics = metrics
        self._lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "evals": 0, "timeouts": 0, "crashes": 0, "pathological": 0,
            "late_results": 0,
        }

    # -- metrics helpers -------------------------------------------------
    def _count(self, key: str, metric: Optional[str] = None, **labels) -> None:
        with self._lock:
            self.stats[key] += 1
        if self._metrics is not None and metric is not None:
            self._metrics.add(metric, **{**self.labels, **labels})

    # -- Executor protocol -----------------------------------------------
    def submit(self, config: Mapping[str, Any]) -> cf.Future:
        cfg = dict(config)
        outer: cf.Future = cf.Future()
        self._count("evals", "guard_evals_total")
        if self.max_inflight == 1 and self.policy.deadline_sec is None:
            # serial, no deadline: evaluate inline so ordering (and hence
            # fixed-seed trajectories) matches InlineExecutor exactly
            self._finish(outer, cfg, *self._guarded(cfg))
            return outer
        t0 = time.perf_counter()
        timer = None
        if self.policy.deadline_sec is not None:
            timer = threading.Timer(
                self.policy.deadline_sec, self._on_deadline, args=(outer, cfg))
            timer.daemon = True
            timer.start()
        worker = threading.Thread(
            target=self._worker, args=(outer, cfg, timer, t0),
            name="repro-guard-eval", daemon=True)
        worker.start()
        return outer

    def shutdown(self, wait: bool = True) -> None:
        # per-submission daemon threads: nothing to join; abandoned hung
        # evaluations die with the process
        pass

    # -- internals -------------------------------------------------------
    def _guarded(self, cfg: Dict[str, Any]):
        """Run one evaluation; returns (result, wall_sec). Never raises."""
        t0 = time.perf_counter()
        try:
            fault_point("eval.slow", **self.labels)
            fault_point("eval.hang", **self.labels)
            fault_point("eval.crash", **self.labels)
            res = self.evaluator(cfg)
        except BaseException as e:  # noqa: BLE001 — crash isolation is the point
            wall = time.perf_counter() - t0
            self._count("crashes", "guard_failures_total", kind="exception")
            obs = FailureObservation(
                kind="exception",
                reason=f"{REASON_CRASH}:{type(e).__name__}",
                objective=self.policy.crash_penalty,
                wall_sec=wall, config=cfg, detail=str(e)[:500])
            return obs.to_eval_result(), wall
        wall = time.perf_counter() - t0
        base = self.policy.baseline_sec
        if (res.ok and base is not None
                and res.objective > base * self.policy.slowdown_factor):
            ratio = res.objective / base
            self._count("pathological", "guard_failures_total", kind="pathological")
            obs = FailureObservation(
                kind="pathological",
                reason=f"{REASON_PATHOLOGICAL}:{ratio:.1f}x",
                objective=res.objective,  # measured: already its own penalty
                wall_sec=wall, config=cfg,
                detail=f"objective {res.objective:.3e}s vs baseline {base:.3e}s")
            return obs.to_eval_result(), wall
        return res, wall

    def _worker(self, outer: cf.Future, cfg: Dict[str, Any], timer, t0) -> None:
        res, _ = self._guarded(cfg)
        if timer is not None:
            timer.cancel()
        self._finish(outer, cfg, res, time.perf_counter() - t0)

    def _on_deadline(self, outer: cf.Future, cfg: Dict[str, Any]) -> None:
        deadline = self.policy.deadline_sec or 0.0
        obs = FailureObservation(
            kind="timeout",
            reason=f"{REASON_TIMEOUT}:{deadline:g}s",
            objective=deadline * self.policy.timeout_penalty_scale,
            wall_sec=deadline, config=cfg,
            detail=f"evaluation exceeded {deadline:g}s deadline")
        if self._set(outer, obs.to_eval_result()):
            self._count("timeouts", "guard_failures_total", kind="timeout")

    def _finish(self, outer: cf.Future, cfg: Dict[str, Any],
                res: EvalResult, wall: float) -> None:
        if not self._set(outer, res):
            # deadline already resolved this future; the straggler's
            # result is dropped (counted) so it can't corrupt the tell order
            self._count("late_results", "guard_late_results_total")

    @staticmethod
    def _set(fut: cf.Future, res: EvalResult) -> bool:
        try:
            fut.set_result(res)
            return True
        except cf.InvalidStateError:
            return False
