"""HLO-text cost model: FLOPs / HBM bytes / collective bytes with correct
while-loop (lax.scan) trip-count multiplication.

``compiled.cost_analysis()`` counts a while body ONCE, so a 60-layer scanned
transformer under-reports compute by ~60x — useless for roofline work. This
walker parses the post-optimization HLO text, builds a per-computation symbol
table, and evaluates costs bottom-up:

  * ``dot``           2 * prod(result) * prod(contracting dims)  [from
                      lhs_contracting_dims + operand shape lookup]
  * ``convolution``   2 * prod(result) * window * in_channels (approx)
  * elementwise       prod(result) per arithmetic op (inside fusions too)
  * ``reduce``        prod(operand)
  * ``fusion``        flops of the fused computation; HBM bytes = the fusion
                      instruction's operands+result only (internals stay in
                      registers — matches XLA's own bytes-accessed convention)
  * ``while``         (body + condition) * known_trip_count (backend_config)
  * collectives       payload bytes by kind, trip-multiplied like everything

The model is validated against closed-form 6*N*D in tests.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["Cost", "module_cost", "parse_module"]

from repro.perf.hlo import COLLECTIVE_KINDS, DTYPE_BYTES

_SHAPE_RE = re.compile(
    r"((?:[a-z][a-z0-9]*)|(?:f8e[0-9]m[0-9](?:fn)?))\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "tanh", "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "logistic", "cosine", "sine", "atan2", "cbrt",
    "erf", "remainder",
}
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "rng-bit-generator",
    "custom-call", "bitcast-convert", "opt-barrier", "optimization-barrier",
}
# ops that move data but do no math (count bytes at top level only)
_DATA_MOVE = {
    "copy", "broadcast", "iota", "reshape", "transpose", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "convert",
    "reverse", "gather", "scatter", "select", "compare", "and", "or", "not",
    "xor", "clamp", "is-finite", "reduce", "reduce-window", "select-and-scatter",
    "map", "sort", "rng", "dot", "convolution", "fusion",
} | _ELEMENTWISE | set(COLLECTIVE_KINDS)


def _shape_elems_bytes(type_text: str) -> tuple[float, float]:
    """(element count, byte count) over all array shapes in a type string."""
    elems = 0.0
    nbytes = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * DTYPE_BYTES[dtype]
    return elems, nbytes


def _shape_dims(type_text: str) -> list[int]:
    m = _SHAPE_RE.search(type_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: list
    attrs: str
    raw: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n, self.collective_bytes * n,
                    {k: v * n for k, v in self.coll_by_kind.items()})


# --------------------------------------------------------------------------
# parsing
# --------------------------------------------------------------------------

_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"^([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*([0-9]+)')


def _parse_instr_line(line: str) -> Instr | None:
    """'%name = <type> opcode(operands), attrs' — the type may be a tuple
    containing nested parens and /*index=N*/ comments, so bracket-match it."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple type: find the matching close paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype, remainder = rest[: i + 1], rest[i + 1:].lstrip()
    else:
        rtype, _, remainder = rest.partition(" ")
    mo = _OPCODE_RE.match(remainder.strip())
    if not mo:
        return None
    opcode = mo.group(1)
    argstr = remainder.strip()[mo.end():]
    return Instr(name, rtype.strip(), opcode, _split_operands(argstr), argstr, line)


def _split_operands(argstr: str) -> list:
    """First-level comma split of the operand list (stops at unbalanced ')')."""
    out, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if depth == 0:
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    names = []
    for tok in out:
        m = re.search(r"%([\w\.\-]+)\s*$", tok)
        names.append(m.group(1) if m else tok)
    return names


def parse_module(text: str) -> dict:
    """module text -> {computation name: [Instr, ...]}"""
    comps: dict = {}
    cur_name = None
    cur: list = []
    entry = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur_name is None:
            s = stripped.strip()
            # computation header: "%name (params...) -> type {" (params may
            # nest parens for tuple types and contain /*index=N*/ comments, so
            # match only the name prefix and exclude instruction-like lines)
            head = s.split("(")[0]
            if s.endswith("{") and "->" in s and "=" not in head:
                m = _COMP_HEAD.match(s)
                if m:
                    cur_name = m.group(1)
                    if s.startswith("ENTRY"):
                        entry = cur_name
                    cur = []
            continue
        if stripped.strip() == "}":
            comps[cur_name] = cur
            cur_name = None
            continue
        ins = _parse_instr_line(stripped)
        if ins:
            cur.append(ins)
    comps["__entry__"] = entry
    return comps


# --------------------------------------------------------------------------
# evaluation
# --------------------------------------------------------------------------


def _called_comps(attrs: str, keys=("calls=", "body=", "condition=",
                                    "branch_computations=", "to_apply=")) -> dict:
    out = {}
    for key in keys:
        for m in re.finditer(re.escape(key) + r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?",
                             attrs):
            vals = [v.strip().lstrip("%") for v in m.group(1).split(",")]
            out.setdefault(key.rstrip("="), []).extend(vals)
    return out


def _dot_flops(instr: Instr, symtab: dict) -> float:
    result_elems, _ = _shape_elems_bytes(instr.result_type)
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.raw)
    k = 1.0
    if cdims and instr.operands:
        lhs_type = symtab.get(instr.operands[0], "")
        dims = _shape_dims(lhs_type)
        for d in cdims.group(1).split(","):
            if d and int(d) < len(dims):
                k *= dims[int(d)]
    return 2.0 * result_elems * k


def _conv_flops(instr: Instr, symtab: dict) -> float:
    result_elems, _ = _shape_elems_bytes(instr.result_type)
    # approximate: 2 * out_elems * prod(kernel spatial dims) * in_channels
    rhs_type = symtab.get(instr.operands[1], "") if len(instr.operands) > 1 else ""
    dims = _shape_dims(rhs_type)
    k = 1.0
    for d in dims[:-1]:  # all but output-channel dim (approximation)
        k *= d
    return 2.0 * result_elems * k


def module_cost(text: str) -> Cost:
    comps = parse_module(text)
    entry = comps.pop("__entry__")
    memo: dict = {}

    def comp_cost(name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # cycle guard
        instrs = comps.get(name, [])
        symtab = {i.name: i.result_type for i in instrs}
        total = Cost()
        for ins in instrs:
            total += instr_cost(ins, symtab, top_level)
        memo[key] = total
        return total

    def instr_cost(ins: Instr, symtab: dict, top_level: bool) -> Cost:
        op = ins.opcode
        c = Cost()
        relems, rbytes = _shape_elems_bytes(ins.result_type)

        if op == "while":
            trips = 1.0
            m = _TRIP_RE.search(ins.raw)
            if m:
                trips = float(m.group(1))
            called = _called_comps(ins.raw)
            inner = Cost()
            for b in called.get("body", []):
                inner += comp_cost(b, top_level=True)
            for b in called.get("condition", []):
                inner += comp_cost(b, top_level=True)
            return inner.scaled(trips)

        if op in ("call", "conditional", "async-start"):
            called = _called_comps(ins.raw)
            for key in ("calls", "branch_computations", "to_apply"):
                for b in called.get(key, []):
                    c += comp_cost(b, top_level=True)
            return c

        if op == "fusion":
            called = _called_comps(ins.raw)
            for b in called.get("calls", []):
                sub = comp_cost(b, top_level=False)
                c.flops += sub.flops
                c.collective_bytes += sub.collective_bytes
                for k, v in sub.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
            if top_level:
                c.bytes += rbytes + sum(
                    _shape_elems_bytes(symtab.get(o, ""))[1] for o in ins.operands)
            return c

        base = op
        for suffix in ("-start", "-done", "-update"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in COLLECTIVE_KINDS and not op.endswith("-done"):
            operand_bytes = sum(
                _shape_elems_bytes(symtab.get(o, ""))[1] for o in ins.operands)
            payload = max(rbytes, operand_bytes)
            c.collective_bytes += payload
            c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + payload
            if top_level:
                c.bytes += rbytes + operand_bytes
            return c

        if op in _FREE:
            return c

        if op == "dot":
            c.flops += _dot_flops(ins, symtab)
        elif op == "convolution":
            c.flops += _conv_flops(ins, symtab)
        elif op in _ELEMENTWISE:
            c.flops += relems
        elif op in ("reduce", "reduce-window"):
            in_elems = sum(
                _shape_elems_bytes(symtab.get(o, ""))[0] for o in ins.operands[:1])
            c.flops += in_elems
        # data movement cost at top level (post-fusion ops touch HBM)
        if top_level and op in _DATA_MOVE:
            c.bytes += rbytes + sum(
                _shape_elems_bytes(symtab.get(o, ""))[1] for o in ins.operands)
        return c

    if entry is None:
        return Cost()
    return comp_cost(entry, top_level=True)
