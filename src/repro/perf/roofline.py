"""Three-term roofline model for compiled TPU-target programs.

This is the §Roofline deliverable and backend B2's objective. Terms (seconds):

    compute    = HLO_FLOPs_global    / (chips * PEAK_FLOPS)
    memory     = HLO_bytes_global    / (chips * HBM_BW)
    collective = collective_bytes_pd / LINK_BW          (per-device traffic
                                                          over per-chip links)

``cost_analysis()`` on an SPMD-partitioned module reports the *per-device*
program cost, so global = per_device * chips; the collective term uses the
per-device traffic directly (each chip pushes its own share through its own
links). The model's bound is max(terms) — the dominant term — and the
roofline fraction we report for a program is compute/max(terms).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(constants from the assignment).
"""

from __future__ import annotations

import dataclasses

from repro.perf.hlo import CollectiveStats, parse_collectives

__all__ = ["HW", "Hardware", "RooflineReport", "analyze_compiled", "score_lowered"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    link_bw: float = 50e9            # bytes/s per ICI link


HW = Hardware()


@dataclasses.dataclass
class RooflineReport:
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: CollectiveStats
    peak_memory_per_device: float | None
    hw: Hardware = HW
    model_flops: float | None = None  # 6*N*D-style useful FLOPs (global)

    # -- the three terms (seconds) ------------------------------------------

    @property
    def flops_global(self) -> float:
        return self.flops_per_device * self.chips

    @property
    def compute_sec(self) -> float:
        return self.flops_global / (self.chips * self.hw.peak_flops)

    @property
    def memory_sec(self) -> float:
        return (self.bytes_per_device * self.chips) / (self.chips * self.hw.hbm_bw)

    @property
    def collective_sec(self) -> float:
        return self.collective_bytes_per_device / self.hw.link_bw

    @property
    def bound_sec(self) -> float:
        return max(self.compute_sec, self.memory_sec, self.collective_sec)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_sec,
            "memory": self.memory_sec,
            "collective": self.collective_sec,
        }
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound spent doing MXU math: compute/bound.
        1.0 means perfectly compute-bound (the roofline ceiling)."""
        b = self.bound_sec
        return self.compute_sec / b if b > 0 else 0.0

    @property
    def useful_flops_ratio(self) -> float | None:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful
        (catches remat/redundancy waste). >1 means HLO under-counts (fusion)."""
        if self.model_flops is None or self.flops_global == 0:
            return None
        return self.model_flops / self.flops_global

    def row(self) -> dict:
        return {
            "chips": self.chips,
            "compute_sec": self.compute_sec,
            "memory_sec": self.memory_sec,
            "collective_sec": self.collective_sec,
            "dominant": self.dominant,
            "bound_sec": self.bound_sec,
            "roofline_fraction": self.roofline_fraction,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "peak_memory_per_device": self.peak_memory_per_device,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collective_detail": self.collectives.summary(),
        }


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returned [dict]
        ca = ca[0] if ca else {}
    return dict(ca or {})


def _peak_memory(compiled) -> float | None:
    try:
        ma = compiled.memory_analysis()
    except Exception:  # noqa: BLE001 — backend may not support it
        return None
    for attr in ("temp_size_in_bytes",):
        if hasattr(ma, attr):
            total = (
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
            return float(total)
    return None


def analyze_compiled(compiled, chips: int, model_flops: float | None = None,
                     hw: Hardware = HW) -> RooflineReport:
    """Build a RooflineReport from a ``jax.stages.Compiled``.

    Costs come from our HLO-text walker (repro.perf.hlo_cost) because XLA's
    ``cost_analysis()`` counts while-loop (lax.scan) bodies once — a 60-layer
    scanned model would under-report ~60x. The walker multiplies by
    known_trip_count and tracks collective payloads the same way."""
    try:
        text = compiled.as_text()
    except Exception:  # noqa: BLE001 — some backends can't dump; degrade
        text = ""

    from repro.perf.hlo_cost import module_cost

    cost = module_cost(text)
    flops_pd = cost.flops
    bytes_pd = cost.bytes
    if flops_pd == 0.0:  # fall back to XLA's numbers if parsing found nothing
        ca = _cost_dict(compiled)
        flops_pd = float(ca.get("flops", 0.0))
        bytes_pd = float(ca.get("bytes accessed", 0.0))
    coll = CollectiveStats(
        dict(cost.coll_by_kind),
        {k: -1 for k in cost.coll_by_kind},  # counts folded into trip products
    )
    return RooflineReport(
        chips=chips,
        flops_per_device=flops_pd,
        bytes_per_device=bytes_pd,
        collective_bytes_per_device=cost.collective_bytes,
        collectives=coll,
        peak_memory_per_device=_peak_memory(compiled),
        hw=hw,
        model_flops=model_flops,
    )


def score_lowered(lowered, chips: int | None = None, hw: Hardware = HW) -> tuple[float, dict]:
    """Backend-B2 objective: compile the lowered program and return the
    roofline bound (seconds) — the modeled step time — plus the term detail."""
    compiled = lowered.compile()
    if chips is None:
        # number of devices the program was lowered for
        chips = getattr(lowered, "_num_devices", None) or 1
        try:
            chips = len(lowered.compile().input_shardings[0][0].device_set)  # best effort
        except Exception:  # noqa: BLE001
            pass
    rep = analyze_compiled(compiled, chips=int(chips), hw=hw)
    return rep.bound_sec, rep.row()
