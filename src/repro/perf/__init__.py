"""repro.perf — roofline modeling and HLO collective analysis."""

from repro.perf.hlo import CollectiveStats, parse_collectives, shape_bytes
from repro.perf.roofline import HW, Hardware, RooflineReport, analyze_compiled, score_lowered

__all__ = [
    "CollectiveStats", "parse_collectives", "shape_bytes",
    "HW", "Hardware", "RooflineReport", "analyze_compiled", "score_lowered",
]
from repro.perf.hlo_cost import Cost, module_cost  # noqa: E402,F401
__all__ += ["Cost", "module_cost"]
