"""HLO text analysis: collective-traffic extraction from compiled programs.

``compiled.cost_analysis()`` reports FLOPs and bytes-accessed but not
collective traffic, so we parse the (post-SPMD-partitioning) HLO text and sum
the operand/result sizes of every communication op:

    all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute

Shapes in HLO text look like ``bf16[16,1024,128]{2,1,0}`` or tuples thereof.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["CollectiveStats", "parse_collectives", "shape_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO shape, e.g. bf16[2,3,4]{2,1,0} or f32[] ; layout suffix optional
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\](?:\{[^}]*\})?")
# an HLO instruction line: `%name = <shape-or-tuple> opcode(` — opcode may have
# `-start`/`-done` suffixes (async collectives)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z][a-z0-9\-]*\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][a-z0-9\-]*)\("
)


def shape_bytes(shape_text: str) -> float:
    """Total bytes of all shapes appearing in ``shape_text``."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind collective byte counts for one compiled module (per device)."""

    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_kind.values()))

    def summary(self) -> str:
        parts = [
            f"{k}: {self.count_by_kind[k]} ops / {self.bytes_by_kind[k]/1e6:.2f} MB"
            for k in sorted(self.bytes_by_kind)
        ]
        return "; ".join(parts) if parts else "<no collectives>"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of every collective op in the HLO module text.

    ``-start`` ops carry the payload for async collectives; their ``-done``
    twins are skipped to avoid double counting. Result size is used as the
    traffic proxy (for all-gather it is the post-gather size, for
    reduce-scatter the pre-reduce size is the input — we use max(result,
    operand) per line to stay conservative).
    """
    bytes_by_kind: dict = defaultdict(float)
    count_by_kind: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_shape, opcode = m.groups()
        base = opcode
        if base.endswith("-start"):
            base = base[: -len("-start")]
        elif base.endswith("-done") or base.endswith("-update"):
            continue  # counted at -start
        if base not in COLLECTIVE_KINDS:
            continue
        # operand shapes appear after the opcode's '('; conservative max
        rest = line[m.end():]
        operand_bytes = shape_bytes(rest.split(", channel_id")[0])
        result_bytes = shape_bytes(result_shape)
        bytes_by_kind[base] += max(result_bytes, operand_bytes)
        count_by_kind[base] += 1
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind))
