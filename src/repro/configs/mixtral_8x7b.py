"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    rope_theta=1.0e6,
    tie_embeddings=False,
    notes="SWA 4096 makes long_500k decode eligible (sub-quadratic).",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, moe_d_ff=96, vocab_size=256, n_experts=4, top_k=2,
        sliding_window=16)
