"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128; SSD (state-space duality). [arXiv:2405.21060; unverified]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    tie_embeddings=True,
    notes="Chunked SSD; O(1) decode state -> long_500k eligible.",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16)
