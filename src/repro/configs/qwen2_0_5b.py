"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936; QKV bias. [arXiv:2407.10671; hf]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1.0e6,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256)
