"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE, dynamic resolution (vision frontend stubbed).
[arXiv:2409.12191; hf]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    rope_theta=1.0e6,
    tie_embeddings=False,
    frontend="vision_stub",
    notes="M-RoPE 3-section rotary; patch embeddings arrive precomputed (stub).",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256)
