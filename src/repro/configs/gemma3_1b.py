"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    sliding_window=512,
    local_global_ratio=5,     # 5 local layers per global layer
    qk_norm=True,
    rope_theta=1.0e6,
    tie_embeddings=True,
    notes="local:global layout is the long-context mechanism -> long_500k eligible.",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=8, local_global_ratio=2)
