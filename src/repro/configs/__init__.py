"""repro.configs — one module per assigned architecture + the shape set.

``get_config(arch_id)`` resolves by the assignment's arch id (dashes/dots);
``get_reduced(arch_id)`` returns the smoke-test configuration of the same
family.
"""

from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, ShapeSpec, cell_supported
from repro.models.common import ArchConfig

__all__ = ["ARCHS", "get_config", "get_reduced", "SHAPES", "ShapeSpec",
           "cell_supported"]

# arch id -> module name
ARCHS = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-780m": "mamba2_780m",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma3-1b": "gemma3_1b",
    "minitron-4b": "minitron_4b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ArchConfig:
    return _module(arch).reduced()
