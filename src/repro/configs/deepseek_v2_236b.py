"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MoE 160e top-6, MLA kv_lora=512, 2 shared experts.
[arXiv:2405.04434; hf]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: all heads share the latent KV
    d_ff=1536,               # routed-expert hidden width
    moe_d_ff=1536,
    vocab_size=102400,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    tie_embeddings=False,
    notes="MLA latent cache (512+64/token/layer); dense layer 0 uses d_ff=12288.",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, d_ff=32, moe_d_ff=32,
        vocab_size=256, q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8,
        qk_nope_dim=16, v_head_dim=16, n_experts=8, n_shared_experts=1,
        top_k=2, first_dense_layers=1, n_kv_heads=4)
