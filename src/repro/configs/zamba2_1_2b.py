"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H d_ff=8192 vocab=32000,
ssm_state=64; Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    attn_type="none",         # backbone layers are Mamba2
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    attn_every=6,             # one shared attention block per 6 mamba layers
    tie_embeddings=True,
    notes="Shared attn block params reused at every site (Zamba weight sharing).",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16, attn_every=2)
