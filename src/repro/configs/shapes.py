"""The assigned input-shape set and per-cell applicability rules.

Every (arch x shape) pair is a dry-run cell. ``train_4k`` lowers train_step,
``prefill_32k`` lowers prefill (forward), ``decode_32k``/``long_500k`` lower
serve_step (one token against a seq_len cache). long_500k requires
sub-quadratic attention (see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

from repro.models.common import ArchConfig

__all__ = ["ShapeSpec", "SHAPES", "cell_supported"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(supported, reason-if-not). Encoder-only archs would skip decode, but
    none are assigned; whisper is enc-dec so its decoder decodes."""
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skip(full-attn): long_500k needs sub-quadratic attention"
    return True, ""
