"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (MHA kv=16) d_ff=2816
vocab=151936; QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256)
