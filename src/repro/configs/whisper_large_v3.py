"""whisper-large-v3 [audio] — 32L(+32 enc) d_model=1280 20H d_ff=5120
vocab=51866; enc-dec, conv frontend stubbed (frame embeddings precomputed).
[arXiv:2212.04356; unverified]"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,              # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    is_encdec=True,
    encoder_len=1500,
    frontend="audio_stub",
    tie_embeddings=True,
    notes=("Decoder shapes exercise the backbone beyond the model's native "
           "448-token decoder context (documented stress test). RoPE used in "
           "place of learned/sinusoidal positions — hardware adaptation note."),
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, encoder_len=24)
