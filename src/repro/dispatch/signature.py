"""Shape signatures: the key half of the tuning store's ``(kernel, shape,
backend)`` addressing scheme.

A signature is a tuple of per-argument dimension tuples — ``((1200, 1000),)``
for syr2k's ``A``, ``((64, 64), (8,))`` for an array plus a static scalar
knob. Two signatures are *compatible* when their nested structure matches
(same arity, same ranks); distance between compatible signatures is the RMS
of log-ratios over corresponding dimensions, so 128→256 is "one doubling
away" regardless of whether the dim is 8 or 8192. That log-scale metric is
what lets an unseen shape resolve to the closest tuned configuration instead
of a naive default: tile-size landscapes are scale-free in the problem dims.
"""

from __future__ import annotations

import math
from typing import Any, Iterable, Sequence

__all__ = [
    "ShapeSignature",
    "shape_signature",
    "signature_key",
    "parse_signature_key",
    "compatible",
    "signature_distance",
    "bucket_signature",
]

# one inner tuple of positive ints per runtime argument
ShapeSignature = tuple

def _arg_dims(arg: Any) -> tuple:
    shape = getattr(arg, "shape", None)
    if shape is not None:
        return tuple(int(d) for d in shape)
    if isinstance(arg, bool):
        # bools must stay distinguishable: max(1, int(·)) would collapse
        # True and False onto the same dim (e.g. causal/non-causal attention)
        return (2 if arg else 1,)
    if isinstance(arg, (int, float)):
        return (max(1, int(arg)),)  # static scalar knobs (e.g. tsteps) count as a dim
    if isinstance(arg, (tuple, list)):
        return tuple(max(1, int(d)) for d in arg)
    raise TypeError(f"cannot derive a shape signature from {type(arg).__name__}")


def shape_signature(args: Iterable[Any]) -> ShapeSignature:
    """Signature of a runtime argument list (arrays, ints, or dim tuples)."""
    return tuple(_arg_dims(a) for a in args)


def signature_key(sig: ShapeSignature) -> str:
    """Canonical string form used as the JSON/store key, e.g. ``1200x1000;8``."""
    return ";".join("x".join(str(int(d)) for d in dims) for dims in sig)


def parse_signature_key(key: str) -> ShapeSignature:
    if not key:
        return ()
    return tuple(tuple(int(d) for d in part.split("x")) for part in key.split(";"))


def _flat(sig: ShapeSignature) -> list:
    return [d for dims in sig for d in dims]


def compatible(a: ShapeSignature, b: ShapeSignature) -> bool:
    return tuple(len(dims) for dims in a) == tuple(len(dims) for dims in b)


def signature_distance(a: ShapeSignature, b: ShapeSignature) -> float:
    """RMS log2-ratio over dims; ``inf`` for structurally incompatible sigs.

    0.0 = identical; 1.0 = every dim off by a factor of two on average."""
    if not compatible(a, b):
        return math.inf
    fa, fb = _flat(a), _flat(b)
    if not fa:
        return 0.0
    sq = sum((math.log2(max(x, 1)) - math.log2(max(y, 1))) ** 2 for x, y in zip(fa, fb))
    return math.sqrt(sq / len(fa))


def bucket_signature(sig: ShapeSignature, base: float = 2.0) -> ShapeSignature:
    """Round every dim to the nearest power of ``base`` — collapses near-equal
    shapes onto one store key so serving traffic with jittery batch sizes
    doesn't fragment the store."""

    def snap(d: int) -> int:
        if d <= 1:
            return 1
        return int(round(base ** round(math.log(d, base))))

    return tuple(tuple(snap(d) for d in dims) for dims in sig)
