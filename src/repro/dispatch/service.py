"""The runtime dispatch service: ``dispatch(kernel_name, *args)``.

Resolution pipeline per call:

  1. derive the shape signature from the runtime args (plus static kwargs);
  2. consult the in-process **compiled-executable cache** keyed by
     ``(kernel, config, signature)`` — a signature-keyed fast map (TTL
     ``resolve_ttl_sec``) remembers the last resolution, so a hit returns
     the already-jitted variant with zero store traffic; the TTL bounds how
     long a cross-process store improvement can go unnoticed, and in-process
     improvements are picked up immediately via :meth:`invalidate`;
  3. on a cache miss, resolve a config from the :class:`TuningStore`
     (exact hit → nearest neighbor → registered space default), build the
     variant via the dispatch registry, jit it, and cache it;
  4. when the resolution is a miss, a too-distant neighbor, or a stale
     record — and a :class:`~repro.dispatch.background.BackgroundTuner` is
     attached — enqueue an async BO campaign for this exact signature. Its
     result is published to the store and hot-swapped in by invalidating
     the affected executable-cache entries, so later calls pick it up.

``stats`` counts every path (store_exact / store_near / store_default,
exec_hit / exec_miss, bg_enqueued) so serving dashboards can watch cache
efficiency and tuning pressure.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable

import jax

from repro.analyze.feasibility import check_config
from repro.core.space import config_key
from repro.engine.executors import evaluator_for_spec
from repro.dispatch.lookup import Resolution, resolve
from repro.dispatch.registry import get as get_variant
from repro.dispatch.signature import shape_signature, signature_key
from repro.dispatch.store import TuningStore
from repro.guard.faults import fault_point
from repro.obs.metrics import get_registry, summarize_histograms
from repro.obs.trace import get_tracer

__all__ = ["DispatchService", "dispatch", "call", "get_service", "configure"]


class DispatchService:
    def __init__(
        self,
        store: TuningStore | None = None,
        *,
        backend: str = "host",
        target: str = "host",
        distance_threshold: float = 1.0,
        staleness_sec: float | None = None,
        tuner: Any | None = None,
        jit: bool = True,
        resolve_ttl_sec: float = 30.0,
        fast_sweep_size: int = 256,
        metrics=None,
    ):
        self.store = store
        # repro.obs registry: per-signature execute-latency histograms and
        # request counters. Recording is shard-local (lock-free), so the
        # fast-hit path's one-lock contract holds with metrics enabled.
        self.metrics = metrics if metrics is not None else get_registry()
        self.backend = backend
        self.target = target
        self.distance_threshold = distance_threshold
        self.staleness_sec = staleness_sec
        self.tuner = tuner
        self.jit = jit
        self.resolve_ttl_sec = resolve_ttl_sec
        self.fast_sweep_size = fast_sweep_size
        # signature -> (exec key, monotonic expiry): lets repeat dispatches
        # skip store refresh + nearest-neighbor scan on the hot path
        self._fast: dict[tuple, tuple[tuple, float]] = {}
        # build_failed counts configs that died in the builder/eval_shape;
        # infeasible counts configs the static feasibility pass
        # (repro.analyze) rejected BEFORE any build was attempted — the two
        # were one stat before the analyze subsystem split them
        self.stats = {
            "store_exact": 0, "store_near": 0, "store_default": 0,
            "exec_hit": 0, "exec_miss": 0, "bg_enqueued": 0, "build_failed": 0,
            "infeasible": 0,
            "serve_rebuilt": 0, "sync_applied": 0, "sync_published": 0,
        }
        self._sync = None  # repro.fleet.SyncAgent, via attach_sync()
        self._kv_cache = None  # serve.PagedKVCache, via attach_kv_cache()
        self._guard = None  # repro.guard.GuardAgent, via attach_guard()
        # retune material per (kernel, sig_key): (signature, static items,
        # arg shape/dtype structs) captured on the miss path so the drift
        # watcher can re-campaign a signature without live args in hand
        self._retune: dict[tuple, tuple] = {}
        self._exec: dict[tuple, Callable] = {}
        # jit_cached sources + stable per-name proxies: invalidate() drops the
        # compiled entry, and the proxy (which callers hold) lazily re-jits
        # from the source — the cross-service serve-step hot swap
        self._fn_src: dict[tuple, Callable] = {}
        self._fn_proxy: dict[tuple, Callable] = {}
        self._lock = threading.RLock()

    # -- config resolution -------------------------------------------------------

    def _resolve_nostats(self, kernel: str, signature):
        """Store resolution without touching stats or the lock; returns
        ``(config, resolution, stat_name)`` so the caller can fold the stat
        bump into whatever critical section it is already paying for."""
        res = None
        if self.store is not None:
            self.store.refresh()
            res = resolve(self.store, kernel, signature, self.backend)
        if res is None:
            return get_variant(kernel).default_config(self.target), None, "store_default"
        return dict(res.config), res, "store_exact" if res.exact else "store_near"

    def resolve_config(self, kernel: str, signature) -> tuple[dict, Resolution | None]:
        """Store-resolved config for a signature, falling back to the
        registered space default when the store is empty/absent."""
        config, res, stat = self._resolve_nostats(kernel, signature)
        with self._lock:
            self.stats[stat] += 1
        return config, res

    def _needs_tuning(self, res: Resolution | None) -> bool:
        if res is None:
            return True
        if not res.exact and res.distance > self.distance_threshold:
            return True
        if self.staleness_sec is not None and res.record.age_sec() > self.staleness_sec:
            return True
        return False

    # -- the runtime API ---------------------------------------------------------

    def dispatch(self, kernel: str, *args, **static_kw) -> Callable:
        """Return a jitted variant of ``kernel`` tuned for these args' shapes.
        The returned callable takes the same positional args."""
        spec = get_variant(kernel)
        sig = shape_signature(list(args) + [v for _, v in sorted(static_kw.items())])
        static_id = tuple(sorted(static_kw.items()))
        sig_key = signature_key(sig)
        fast_key = (kernel, sig_key, static_id)
        now = time.monotonic()
        # hot path: ONE lock acquisition — fast-map read, executable lookup,
        # and the hit-stat bump share a single critical section (the metric
        # bump is shard-local and takes no lock)
        with self._lock:
            entry = self._fast.get(fast_key)
            if entry is not None:
                exec_key, expires = entry
                fn = self._exec.get(exec_key)
                if fn is not None and now < expires:
                    self.stats["exec_hit"] += 1
                    self.metrics.add("dispatch_requests_total",
                                     kernel=kernel, path="fast_hit")
                    return fn
                del self._fast[fast_key]  # expired or orphaned: don't leak
        # miss path: resolve outside the lock (store refresh does file I/O),
        # then fold the resolve stat and the executable-cache probe into one
        # critical section
        tracer = get_tracer()
        t0 = time.perf_counter()
        with tracer.span("dispatch.lookup", kernel=kernel, signature=sig_key):
            config, res, resolve_stat = self._resolve_nostats(kernel, sig)
        self.metrics.observe("dispatch_lookup_seconds",
                             time.perf_counter() - t0, kernel=kernel)
        self.metrics.add("dispatch_requests_total", kernel=kernel,
                         path=resolve_stat)
        key = fast_key + (config_key(config),)
        with self._lock:
            self.stats[resolve_stat] += 1
            fn = self._exec.get(key)
            self.stats["exec_hit" if fn is not None else "exec_miss"] += 1
        built = None
        if fn is None and res is not None:
            # statically-infeasible store records never cost a build or an
            # eval_shape: the feasibility pass proves from the config and
            # the signature's problem dims alone that the builder would die
            # (missing params, non-positive tiles, VMEM over budget, ...).
            # Exact hits are quarantined with the machine-readable reason
            # codes; near neighbors just degrade (same asymmetry as the
            # runtime build_failed path below).
            verdict = check_config(kernel, config, signature=sig,
                                   target=self.target)
            if not verdict.ok:
                if self.store is not None and res.exact:
                    with tracer.span("dispatch.quarantine", kernel=kernel,
                                     signature=sig_key,
                                     reason=verdict.reason()):
                        self.store.quarantine(res.record,
                                              reason=verdict.reason())
                res = None
                config = spec.default_config(self.target)
                key = fast_key + (config_key(config),)
                with self._lock:
                    self.stats["infeasible"] += 1
                    fn = self._exec.get(key)  # default may already be compiled
                self.metrics.add("dispatch_requests_total", kernel=kernel,
                                 path="infeasible")
        if fn is None and res is not None:
            # a store-resolved config is untrusted input to the serving path:
            # validate build + abstract trace now, so a poisoned record
            # degrades to the default config instead of raising at the caller
            try:
                with tracer.span("dispatch.build", kernel=kernel,
                                 signature=sig_key):
                    built = spec.builder(config, **static_kw)
                    if args:
                        jax.eval_shape(built, *args)
            except Exception:
                # only an exact hit proves the record is bad for its own
                # signature; a nearest neighbor may merely not transfer to
                # this shape (e.g. an indivisible block), and quarantining it
                # would destroy a config that is valid where it was tuned
                if self.store is not None and res.exact:
                    with tracer.span("dispatch.quarantine", kernel=kernel,
                                     signature=sig_key):
                        self.store.quarantine(res.record,
                                              reason="build_failed")
                built, res = None, None
                config = spec.default_config(self.target)
                key = fast_key + (config_key(config),)
                with self._lock:
                    self.stats["build_failed"] += 1
                    fn = self._exec.get(key)  # default may already be compiled
                self.metrics.add("dispatch_requests_total", kernel=kernel,
                                 path="build_failed")
        if fn is None:
            if built is None:
                with tracer.span("dispatch.build", kernel=kernel,
                                 signature=sig_key):
                    built = spec.builder(config, **static_kw)
            fn = jax.jit(built) if self.jit else built
            # the cached executable is the instrumented wrapper, so repeat
            # dispatches return the identical object and every execution
            # lands in the per-signature latency histogram
            fn = self._instrument_execute(fn, kernel, sig_key, sig=sig,
                                          config=config, static_kw=static_kw)
        retune_material = None
        if self._guard is not None:
            # shape/dtype structs, not live arrays: enough to synthesize
            # arguments for a drift-triggered re-campaign, without pinning
            # serving buffers in this map
            retune_material = (sig, static_id, tuple(
                jax.ShapeDtypeStruct(a.shape, a.dtype)
                if hasattr(a, "shape") else a for a in args))
        # publish: executable insert, fast-map store, and the TTL sweep share
        # the final critical section
        with self._lock:
            fn = self._exec.setdefault(key, fn)
            self._fast[fast_key] = (key, time.monotonic() + self.resolve_ttl_sec)
            if retune_material is not None:
                self._retune[(kernel, sig_key)] = retune_material
            if len(self._fast) > self.fast_sweep_size:
                self._sweep_fast_locked(time.monotonic())
        if self.tuner is not None and self.store is not None and self._needs_tuning(res):
            self._enqueue_tuning(spec, kernel, sig, args, static_kw)
        return fn

    def call(self, kernel: str, *args, **static_kw):
        """Resolve, build, and run in one step."""
        return self.dispatch(kernel, *args, **static_kw)(*args)

    def _instrument_execute(self, fn: Callable, kernel: str, sig_key: str,
                            *, sig=None, config=None,
                            static_kw=None) -> Callable:
        """Wrap an executable so every call records into the per-signature
        execute-latency histogram (and a trace span when tracing is on).
        The wrapper is what the executable cache stores, so the identity
        contract (repeat dispatch returns the same object) is unchanged.

        On asynchronous backends this times dispatch-to-return as the caller
        observes it — the same quantity a serving loop's own latency sees;
        it does not force a ``block_until_ready`` sync, which would
        serialize the pipeline it is measuring. The exception is a
        shadow-sampled call (epsilon fraction, attached guard only): there
        the wrapper synchronizes to obtain a true wall time and tells it
        into the tuning store."""
        metrics, backend = self.metrics, self.backend

        def timed(*a, **kw):
            tracer = get_tracer()
            guard = self._guard
            mode = (guard.shadow_mode(kernel, sig_key)
                    if guard is not None else None)
            t0 = time.perf_counter()
            try:
                fault_point("dispatch.latency", kernel=kernel,
                            signature=sig_key)
                if tracer.enabled:
                    with tracer.span("dispatch.execute", kernel=kernel,
                                     signature=sig_key):
                        out = fn(*a, **kw)
                else:
                    out = fn(*a, **kw)
                if mode is not None and not any(
                        isinstance(x, jax.core.Tracer) for x in a):
                    # skipped under jit tracing: a trace-time "latency" is
                    # meaningless and must not be told into the store
                    jax.block_until_ready(out)
                    guard.on_shadow(kernel, sig, config, static_kw, a,
                                    time.perf_counter() - t0, mode)
                return out
            finally:
                metrics.observe("dispatch_execute_seconds",
                                time.perf_counter() - t0, kernel=kernel,
                                signature=sig_key, backend=backend)

        timed.__wrapped__ = fn
        return timed

    def _enqueue_tuning(self, spec, kernel, sig, args, static_kw) -> None:
        def factory(cfg):
            return spec.builder(cfg, **static_kw), args

        # make_evaluator override (e.g. the roofline cost backend registered
        # by repro.kernels.problems.register_cost_backend) else wall-clock
        evaluator = evaluator_for_spec(spec, factory)
        fut = self.tuner.submit(
            kernel, sig, self.backend, space=spec.space(self.target),
            evaluator=evaluator, on_done=self._on_tuned)
        if fut is not None:
            with self._lock:
                self.stats["bg_enqueued"] += 1

    def _on_tuned(self, kernel: str, signature, backend: str) -> None:
        self.invalidate(kernel, signature)
        if self._sync is not None:
            # a background campaign just published: push the new config
            # fleet-wide now instead of waiting a full anti-entropy interval
            self._sync.nudge()

    # -- fleet replication (repro.fleet) -----------------------------------------

    def attach_sync(self, agent) -> None:
        """Bind a :class:`repro.fleet.SyncAgent`: replication counters land
        in ``stats`` (``sync_applied`` / ``sync_published``), replication lag
        shows up in :meth:`telemetry`, and local background-tuning publishes
        nudge the agent to push promptly."""
        self._sync = agent
        if self.tuner is not None and getattr(self.tuner, "on_publish", None) is None:
            self.tuner.on_publish = lambda rec: agent.nudge()

    def attach_guard(self, agent) -> None:
        """Bind a :class:`repro.guard.GuardAgent`: the instrumented execute
        wrapper starts shadow-sampling an epsilon fraction of eager calls,
        retune material is captured per signature so the drift watcher can
        re-campaign without live args, and :meth:`telemetry` grows a
        ``guard`` section. Attach before the first dispatch — wrappers
        created earlier keep serving, but their signatures only gain shadow
        sampling after an :meth:`invalidate`."""
        self._guard = agent

    def request_retune(self, kernel: str, sig_key: str) -> bool:
        """Force a background re-campaign for a signature seen earlier by
        :meth:`dispatch` (the drift watcher's recovery path). Returns False
        when no tuner/store is attached or the signature was never served
        with a guard attached."""
        if self.tuner is None or self.store is None:
            return False
        with self._lock:
            material = self._retune.get((kernel, sig_key))
        if material is None:
            return False
        sig, static_id, shapes = material
        spec = get_variant(kernel)
        args = tuple(
            jax.numpy.zeros(s.shape, s.dtype)
            if isinstance(s, jax.ShapeDtypeStruct) else s for s in shapes)
        self._enqueue_tuning(spec, kernel, sig, args, dict(static_id))
        return True

    def attach_kv_cache(self, cache) -> None:
        """Bind a :class:`repro.serve.PagedKVCache`: its paged accounting
        (pages allocated vs tokens resident, occupancy) shows up in
        :meth:`telemetry` under ``kv_cache`` next to the dispatch counters
        the same serving loop produces."""
        self._kv_cache = cache

    def telemetry(self) -> dict:
        """One merged serving-telemetry view: the dispatch counters, the
        background tuner's optimizer-overhead aggregates (ask/tell/wait
        seconds), the sync agent's replication lag (ops pending, last-sync
        age) when one is attached, the attached paged KV cache's
        page/token accounting (under ``kv_cache``), and — under
        ``execute_latency`` — per-signature p50/p99 execute latency from
        the obs registry's histograms. All pre-existing flat keys are
        unchanged."""
        with self._lock:
            out = dict(self.stats)
        if self.tuner is not None and getattr(self.tuner, "stats", None):
            out.update(self.tuner.stats)
        if self._sync is not None:
            out.update(self._sync.lag())
        if self._kv_cache is not None:
            out["kv_cache"] = self._kv_cache.stats()
        if self._guard is not None:
            out["guard"] = self._guard.summary()
        out["execute_latency"] = [
            {
                "kernel": row["labels"].get("kernel"),
                "signature": row["labels"].get("signature"),
                "backend": row["labels"].get("backend"),
                "count": row["count"],
                "p50_sec": row["p50"],
                "p99_sec": row["p99"],
                "mean_sec": row["sum"] / row["count"] if row["count"] else None,
            }
            for row in summarize_histograms(
                self.metrics.snapshot(), name="dispatch_execute_seconds")
        ]
        return out

    # -- cache management --------------------------------------------------------

    def _sweep_fast_locked(self, now: float) -> int:
        """Drop expired ``_fast`` entries (caller holds the lock). Without
        this, jittery serving shapes grow the TTL map without bound — expiry
        was otherwise only checked on hit."""
        doomed = [k for k, (_, expires) in self._fast.items() if now >= expires]
        for k in doomed:
            del self._fast[k]
        return len(doomed)

    def invalidate(self, kernel: str | None = None, signature=None) -> int:
        """Drop executable-cache entries (all, per kernel, or per kernel+sig)
        so the next dispatch re-resolves — the hot-swap half of background
        tuning. Returns the number of kernel entries dropped.

        ``jit_cached`` serve steps are invalidated alongside: a jitted serve
        step bakes in whatever kernel executables were dispatched at trace
        time, so a config hot swap must also force those steps to re-trace.
        Their compiled entries are dropped (any entry could close over the
        affected kernel) and lazily rebuilt from source on next call through
        the stable proxy callers hold."""
        sig_key = signature_key(signature) if signature is not None else None

        def matches(k):
            return k[0] != "__fn__" and \
                   (kernel is None or k[0] == kernel) and \
                   (sig_key is None or k[1] == sig_key)

        with self._lock:
            doomed = [k for k in self._exec if matches(k)]
            for k in doomed:
                del self._exec[k]
            for k in [k for k in self._fast if matches(k)]:
                del self._fast[k]
            if doomed or kernel is None:
                for k in list(self._fn_src):
                    self._exec.pop(k, None)
            return len(doomed)

    # -- generic executable cache (serving integration) --------------------------

    def jit_cached(self, name: str, fn: Callable) -> Callable:
        """Cache-and-jit an arbitrary callable under a stable name, sharing
        the service's executable cache and hit/miss counters. Used by the
        serving step so repeated ``make_serve_step`` calls for the same model
        reuse one compiled entry point.

        Returns a stable proxy, not the jitted function itself: when
        :meth:`invalidate` drops the compiled entry (a kernel config hot
        swap), every held reference transparently re-traces against the new
        configs on its next call instead of serving stale executables."""
        key = ("__fn__", name, (), ())
        with self._lock:
            self._fn_src.setdefault(key, fn)
            cached = self._exec.get(key)
            if cached is not None:
                self.stats["exec_hit"] += 1
            else:
                self.stats["exec_miss"] += 1
        if cached is None:
            jitted = jax.jit(fn) if self.jit else fn
            with self._lock:
                self._exec.setdefault(key, jitted)
        with self._lock:
            proxy = self._fn_proxy.get(key)
            if proxy is None:
                proxy = self._fn_proxy[key] = self._make_fn_proxy(key)
        return proxy

    def _make_fn_proxy(self, key: tuple) -> Callable:
        def proxy(*args, **kw):
            with self._lock:
                fn = self._exec.get(key)
            if fn is None:  # invalidated: rebuild from source
                with self._lock:
                    src = self._fn_src[key]
                    self.stats["serve_rebuilt"] += 1
                # jit caches traces by function identity, so re-jitting `src`
                # directly would replay the stale executable; a fresh wrapper
                # object forces a re-trace, baking in freshly-dispatched
                # kernel configs
                def fresh(*a, **k):
                    return src(*a, **k)

                fn = jax.jit(fresh) if self.jit else fresh
                with self._lock:
                    fn = self._exec.setdefault(key, fn)
            return fn(*args, **kw)

        return proxy


# -- module-level default service (the one-liner API) ---------------------------

_default: DispatchService | None = None
_default_lock = threading.Lock()


def get_service() -> DispatchService:
    global _default
    with _default_lock:
        if _default is None:
            _default = DispatchService()
        return _default


def configure(store: TuningStore | str | None = None, **kw) -> DispatchService:
    """(Re)build the process-wide default service, e.g.
    ``configure("results/store", tuner=BackgroundTuner(...))``."""
    global _default
    if isinstance(store, str):
        store = TuningStore(store)
    with _default_lock:
        _default = DispatchService(store, **kw)
        return _default


def dispatch(kernel: str, *args, **static_kw) -> Callable:
    return get_service().dispatch(kernel, *args, **static_kw)


def call(kernel: str, *args, **static_kw):
    return get_service().call(kernel, *args, **static_kw)
