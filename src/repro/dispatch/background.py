"""Background tuning: async BO campaigns feeding the store.

A dispatch-time cache miss (or a too-distant / stale resolution) enqueues a
campaign on a small thread worker pool. Each campaign reuses the exact
offline machinery — :func:`repro.core.search.run_search` — but warm-started
from the store's nearest-neighbor records, so an online campaign typically
needs a fraction of the offline 200-evaluation budget. The winning config is
published back to the :class:`TuningStore` (an atomic best-only append, i.e.
the hot swap) and an ``on_done`` callback lets the dispatch service
invalidate its compiled-executable cache for the affected signature.

In-flight deduplication is by ``(kernel, signature, backend)``: a hot
serving path that misses a thousand times enqueues one campaign, not a
thousand.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
from typing import Any, Callable

from repro.core.search import run_search
from repro.core.space import config_key
from repro.dispatch.signature import ShapeSignature, signature_distance, signature_key
from repro.dispatch.store import TuningRecord, TuningStore

__all__ = ["BackgroundTuner"]


class BackgroundTuner:
    def __init__(
        self,
        store: TuningStore,
        *,
        max_workers: int = 2,
        max_evals: int = 20,
        learner: str = "RF",
        seed: int = 1234,
        n_initial: int = 4,
        warm_neighbors: int = 3,
    ):
        self.store = store
        self.max_evals = max_evals
        self.learner = learner
        self.seed = seed
        self.n_initial = n_initial
        self.warm_neighbors = warm_neighbors
        self._pool = cf.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="repro-bg-tune")
        self._inflight: set[tuple] = set()
        self._futures: list[cf.Future] = []
        self._lock = threading.Lock()
        self.errors: list[tuple[tuple, BaseException]] = []

    # -- submission --------------------------------------------------------------

    def submit(
        self,
        kernel: str,
        signature: ShapeSignature,
        backend: str,
        *,
        space: Any,
        evaluator: Callable,
        max_evals: int | None = None,
        on_done: Callable[[str, ShapeSignature, str], None] | None = None,
    ) -> cf.Future | None:
        """Enqueue one campaign per distinct key; returns None when one is
        already in flight for this ``(kernel, signature, backend)``."""
        key = (kernel, signature_key(signature), backend)
        with self._lock:
            if key in self._inflight:
                return None
            self._inflight.add(key)
        fut = self._pool.submit(
            self._campaign, key, kernel, signature, backend, space, evaluator,
            max_evals or self.max_evals, on_done)
        with self._lock:
            self._futures.append(fut)
        return fut

    def _warm_start(self, kernel: str, signature: ShapeSignature, backend: str):
        """Nearest store records become warm-start material: the single
        closest config is re-evaluated first, and up to ``warm_neighbors``
        further neighbors seed the surrogate as virtual observations. The
        re-evaluated config is excluded from the virtual observations —
        otherwise its real evaluation plus the prior row would double-count
        that config in the surrogate's training data."""
        ranked = sorted(
            self.store.records(kernel=kernel, backend=backend),
            key=lambda r: signature_distance(signature, r.signature))
        ranked = [r for r in ranked
                  if signature_distance(signature, r.signature) != float("inf")]
        if not ranked:
            return None, None
        configs = [dict(ranked[0].config)]
        first = config_key(ranked[0].config)
        records = [(dict(r.config), float(r.objective))
                   for r in ranked[1 : self.warm_neighbors + 1]
                   if config_key(r.config) != first]
        return configs, records or None

    def _campaign(self, key, kernel, signature, backend, space, evaluator,
                  max_evals, on_done) -> TuningRecord | None:
        try:
            warm_cfgs, warm_recs = self._warm_start(kernel, signature, backend)
            result = run_search(
                space, evaluator, max_evals=max_evals, learner=self.learner,
                seed=self.seed, n_initial=self.n_initial,
                warm_start=warm_cfgs, warm_start_records=warm_recs)
            if result.best is None:
                return None
            rec = TuningRecord(
                kernel=kernel, signature=signature, backend=backend,
                config=dict(result.best.config),
                objective=float(result.best.objective),
                n_evals=len(result.db), source="background")
            self.store.put(rec)
            if on_done is not None:
                on_done(kernel, signature, backend)
            return rec
        except BaseException as e:  # noqa: BLE001 — a worker must never die silently
            with self._lock:
                self.errors.append((key, e))
            return None
        finally:
            with self._lock:
                self._inflight.discard(key)

    # -- lifecycle ---------------------------------------------------------------

    def drain(self, timeout: float | None = None) -> list[TuningRecord | None]:
        """Block until every submitted campaign finishes; returns their
        published records (None for no-improvement or failed campaigns —
        failures are collected in ``self.errors``, not raised)."""
        with self._lock:
            futs = list(self._futures)
        return [f.result(timeout=timeout) for f in futs]

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)
