"""Background tuning: async BO campaigns feeding the store.

A dispatch-time cache miss (or a too-distant / stale resolution) enqueues a
campaign on a small thread worker pool. Each campaign is a
:class:`repro.engine.Campaign` — the exact offline machinery — warm-started
from the store's nearest-neighbor records
(:func:`repro.dispatch.lookup.warm_start_material`), so an online campaign
typically needs a fraction of the offline 200-evaluation budget. With
``parallel > 1`` each campaign additionally keeps that many candidate
evaluations in flight (constant-liar batching), saturating idle cores. The
winning config is published back to the :class:`TuningStore` (an atomic
best-only append, i.e. the hot swap) and an ``on_done`` callback lets the
dispatch service invalidate its compiled-executable cache for the affected
signature.

In-flight deduplication is by ``(kernel, signature, backend)``: a hot
serving path that misses a thousand times enqueues one campaign, not a
thousand.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Any, Callable

from repro.dispatch.lookup import warm_start_material
from repro.dispatch.signature import ShapeSignature, signature_key
from repro.dispatch.store import TuningRecord, TuningStore
from repro.engine import Campaign
from repro.obs.metrics import get_registry
from repro.obs.trace import span as obs_span

__all__ = ["BackgroundTuner"]


class BackgroundTuner:
    def __init__(
        self,
        store: TuningStore,
        *,
        max_workers: int = 2,
        max_evals: int = 20,
        learner: str = "RF",
        seed: int = 1234,
        n_initial: int = 4,
        warm_neighbors: int = 3,
        parallel: int = 1,
        on_publish: Callable[[TuningRecord], None] | None = None,
        harden: Any | None = None,
        cascade: bool = False,
        cascade_budgets: tuple[int, int] | None = None,
    ):
        self.store = store
        # multi-fidelity cascade (repro.fidelity): when enabled, campaigns
        # for fidelity-ready kernels screen a wide pool on the analytic cost
        # model (rung 0) and promote only the top-k to the real evaluator
        # (rung 1), so the serving host pays a fraction of the hardware
        # evaluations. Applies only when the backend is not already "cost"
        # (screening the cost model with itself is a no-op) and the problem
        # dims are derivable from the runtime signature; otherwise campaigns
        # silently fall back to the flat single-fidelity path.
        # cascade_budgets = (screen_budget, hw_budget); the default screens
        # 4x the flat budget analytically but spends only half of it on
        # hardware.
        self.cascade = cascade
        self.cascade_budgets = cascade_budgets
        # repro.guard.HardenPolicy (or None): when set, every campaign's
        # evaluator runs behind a HardenedExecutor — per-eval deadlines,
        # crash isolation, pathological-slowdown reclassification — so a
        # hung or crashing config becomes a penalized FailureObservation
        # instead of wedging a tuner worker
        self.harden = harden
        # fired after every campaign's store publish (even a rejected
        # no-improvement one): DispatchService.attach_sync hooks this so the
        # fleet SyncAgent pushes fresh results without waiting an interval
        self.on_publish = on_publish
        self.max_evals = max_evals
        self.learner = learner
        self.seed = seed
        self.n_initial = n_initial
        self.warm_neighbors = warm_neighbors
        self.parallel = parallel
        self._pool = cf.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="repro-bg-tune")
        self._inflight: set[tuple] = set()
        self._futures: list[cf.Future] = []
        self._lock = threading.Lock()
        self.errors: list[tuple[tuple, BaseException]] = []
        # optimizer-overhead telemetry, aggregated across campaigns from
        # Campaign.timings: ask_sec + tell_sec is the CPU the tuner itself
        # bills to the serving host (CATBench's first-class overhead metric);
        # wait_sec is time blocked on evaluations. A serving dashboard that
        # sees ask_sec rival the eval budget knows the surrogate — not the
        # kernels — is eating the cores.
        # screened/promoted mirror the repro.fidelity counters: configs
        # discarded on the cheap rung vs graduated to hardware (both 0 when
        # cascade is off) — DispatchService.telemetry() surfaces them
        self.stats = {"campaigns": 0, "ask_sec": 0.0, "tell_sec": 0.0,
                      "wait_sec": 0.0, "cascade_campaigns": 0,
                      "screened": 0, "promoted": 0}

    # -- submission --------------------------------------------------------------

    def submit(
        self,
        kernel: str,
        signature: ShapeSignature,
        backend: str,
        *,
        space: Any,
        evaluator: Callable,
        max_evals: int | None = None,
        on_done: Callable[[str, ShapeSignature, str], None] | None = None,
    ) -> cf.Future | None:
        """Enqueue one campaign per distinct key; returns None when one is
        already in flight for this ``(kernel, signature, backend)``."""
        key = (kernel, signature_key(signature), backend)
        with self._lock:
            if key in self._inflight:
                return None
            self._inflight.add(key)
        try:
            fut = self._pool.submit(
                self._campaign, key, kernel, signature, backend, space, evaluator,
                max_evals or self.max_evals, on_done)
        except RuntimeError:  # pool shut down: serving degrades, never crashes
            with self._lock:
                self._inflight.discard(key)
            return None
        with self._lock:
            self._futures.append(fut)
        return fut

    def _warm_start(self, kernel: str, signature: ShapeSignature, backend: str):
        """Nearest store records become warm-start material (see
        :func:`repro.dispatch.lookup.warm_start_material`)."""
        return warm_start_material(
            self.store, kernel, signature, backend, neighbors=self.warm_neighbors)

    def _cascade_ladder(self, kernel, signature, backend, evaluator,
                        executor, max_evals):
        """Cost → hardware ladder for this campaign, or None for the flat
        path: cascade off, backend already analytic, kernel not
        fidelity-ready, or dims underivable from the runtime signature."""
        if not self.cascade or backend == "cost":
            return None
        from repro.kernels.problems import (
            dims_from_signature,
            fidelity_ready,
            make_cost_evaluator,
        )

        if not fidelity_ready(kernel):
            return None
        try:
            dims = dims_from_signature(kernel, tuple(signature))
        except Exception:
            return None
        from repro.fidelity import FidelityLadder, Rung

        screen, hw = self.cascade_budgets or (max_evals * 4,
                                              max(2, max_evals // 2))
        promote = max(1, min(screen, hw, max(2, hw // 2)))
        return FidelityLadder([
            Rung(0, "cost", make_cost_evaluator(kernel, dims),
                 budget=int(screen), promote=promote),
            Rung(1, "hw", evaluator, budget=int(hw), executor=executor),
        ])

    def _campaign(self, key, kernel, signature, backend, space, evaluator,
                  max_evals, on_done) -> TuningRecord | None:
        sig_key = signature_key(signature)
        registry = get_registry()
        try:
            t0 = time.perf_counter()
            with obs_span("tuner.campaign", kernel=kernel, signature=sig_key,
                          backend=backend, max_evals=max_evals):
                warm_cfgs, warm_recs = self._warm_start(kernel, signature, backend)
                executor = None
                if self.harden is not None:
                    import dataclasses as _dc

                    from repro.guard.harden import HardenedExecutor

                    policy = self.harden
                    if policy.baseline_sec is None and warm_recs:
                        # warm-start incumbents arm the pathological-
                        # slowdown check with a region-realistic baseline
                        policy = _dc.replace(
                            policy,
                            baseline_sec=min(o for _, o in warm_recs))
                    executor = HardenedExecutor(
                        evaluator, policy, parallel=self.parallel,
                        metrics=registry, labels={"kernel": kernel})
                ladder = self._cascade_ladder(
                    kernel, signature, backend, evaluator, executor, max_evals)
                if ladder is not None:
                    from repro.fidelity import CascadeCampaign

                    cres = CascadeCampaign(
                        space, ladder, learner=self.learner, seed=self.seed,
                        n_initial=self.n_initial, parallel=self.parallel,
                        warm_start=warm_cfgs, warm_start_records=warm_recs,
                        kernel=kernel).run()
                    result = cres.rungs[-1]  # publish from the hardware rung
                    timings, cascade_stats = cres.timings, cres.stats
                else:
                    result = Campaign(
                        space, evaluator, executor=executor,
                        max_evals=max_evals, learner=self.learner,
                        seed=self.seed, n_initial=self.n_initial, parallel=self.parallel,
                        warm_start=warm_cfgs, warm_start_records=warm_recs).run()
                    timings, cascade_stats = result.timings, None
            registry.add("tuner_campaigns_total", kernel=kernel)
            registry.observe("tuner_campaign_seconds",
                             time.perf_counter() - t0, kernel=kernel)
            if timings:
                with self._lock:
                    self.stats["campaigns"] += 1
                    for k in ("ask_sec", "tell_sec", "wait_sec"):
                        self.stats[k] += timings[k]
                    if cascade_stats is not None:
                        self.stats["cascade_campaigns"] += 1
                        self.stats["screened"] += cascade_stats["screened"]
                        self.stats["promoted"] += cascade_stats["promoted"]
            if result.best is None:
                return None
            rec = self._publishable(result, kernel, signature, backend)
            if rec is None:
                return None
            with obs_span("tuner.publish", kernel=kernel, signature=sig_key):
                self.store.put(rec)
            registry.add("tuner_publish_total", kernel=kernel)
            if self.on_publish is not None:
                self.on_publish(rec)
            if on_done is not None:
                on_done(kernel, signature, backend)
            return rec
        except BaseException as e:  # noqa: BLE001 — a worker must never die silently
            with self._lock:
                self.errors.append((key, e))
            return None
        finally:
            with self._lock:
                self._inflight.discard(key)

    def _publishable(self, result, kernel, signature, backend) -> TuningRecord | None:
        """Best evaluated config that the store will actually serve again:
        quarantined configs (e.g. the drift-banned incumbent a re-campaign
        just re-measured as fastest) are skipped in favor of the next-best,
        so a drift recovery publishes a *replacement* rather than silently
        re-proposing the banned config and leaving the key empty."""
        self.store.refresh()  # fold tombstones appended during the campaign
        candidates = sorted(result.db.evaluated(), key=lambda r: r.objective)
        for cand in candidates:
            rec = TuningRecord(
                kernel=kernel, signature=signature, backend=backend,
                config=dict(cand.config), objective=float(cand.objective),
                n_evals=len(result.db), source="background")
            if not self.store.is_quarantined(rec):
                return rec
        return None

    # -- lifecycle ---------------------------------------------------------------

    def drain(self, timeout: float | None = None) -> list[TuningRecord | None]:
        """Block until every submitted campaign finishes; returns their
        published records (None for no-improvement or failed campaigns —
        failures are collected in ``self.errors``, not raised). ``timeout``
        is one deadline shared across all futures — total wait is bounded by
        ``timeout`` seconds, not ``n_futures x timeout``."""
        with self._lock:
            futs = list(self._futures)
        deadline = None if timeout is None else time.monotonic() + timeout
        out = []
        for f in futs:
            remaining = None if deadline is None else deadline - time.monotonic()
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"drain deadline ({timeout}s) exceeded with "
                    f"{len(futs) - len(out)} campaign(s) unfinished")
            try:
                out.append(f.result(timeout=remaining))
            except cf.TimeoutError:  # normalize (distinct class before 3.11)
                raise TimeoutError(
                    f"drain deadline ({timeout}s) exceeded with "
                    f"{len(futs) - len(out)} campaign(s) unfinished") from None
        return out

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)
