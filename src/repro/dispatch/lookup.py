"""Nearest-neighbor config resolution over the tuning store.

An exact ``(kernel, signature, backend)`` hit wins outright. Otherwise the
store's records for the same kernel+backend are ranked by log-scale shape
distance (see :mod:`repro.dispatch.signature`) and the closest compatible
record is returned, annotated with its distance so callers can decide
whether the neighbor is close enough to serve as-is or should also trigger
a background re-tune.
"""

from __future__ import annotations

import dataclasses

from repro.dispatch.signature import ShapeSignature, signature_distance
from repro.dispatch.store import TuningRecord, TuningStore

__all__ = ["Resolution", "resolve", "warm_start_material"]


@dataclasses.dataclass
class Resolution:
    record: TuningRecord
    distance: float      # 0.0 for an exact hit
    exact: bool

    @property
    def config(self) -> dict:
        return self.record.config


def resolve(
    store: TuningStore,
    kernel: str,
    signature: ShapeSignature,
    backend: str,
    max_distance: float | None = None,
) -> Resolution | None:
    """Exact hit, else nearest compatible neighbor within ``max_distance``
    (no bound when ``None``). Returns ``None`` when nothing qualifies."""
    hit = store.get(kernel, signature, backend)
    if hit is not None:
        return Resolution(hit, 0.0, True)
    best, best_d = None, float("inf")
    for rec in store.records(kernel=kernel, backend=backend):
        d = signature_distance(signature, rec.signature)
        if d < best_d:
            best, best_d = rec, d
    if best is None or best_d == float("inf"):
        return None
    if max_distance is not None and best_d > max_distance:
        return None
    return Resolution(best, best_d, False)


def warm_start_material(
    store: TuningStore,
    kernel: str,
    signature: ShapeSignature,
    backend: str,
    neighbors: int = 3,
) -> tuple[list[dict] | None, list[tuple[dict, float]] | None]:
    """Warm-start material for a campaign targeting ``signature``, derived
    from the store's nearest records: ``(configs, records)`` where
    ``configs`` is the single closest config (to re-evaluate first, so the
    campaign's best can never regress below the stored optimum) and
    ``records`` are up to ``neighbors`` further (config, objective) pairs
    that seed the surrogate as virtual observations. The re-evaluated config
    is excluded from the virtual observations — its real evaluation plus the
    prior row would double-count it in the surrogate's training data.
    Returns ``(None, None)`` when the store has no compatible record.

    This is the one warm-start policy shared by the background tuner, the
    autotune CLI, and the pallas-tuning benchmark (previously three
    divergent copies)."""
    from repro.core.space import config_key
    from repro.dispatch.signature import signature_distance as _dist

    # fold in records other writers appended since our last read — with
    # fleet replication (repro.fleet) a neighbor may have been tuned on a
    # different host and synced in moments ago; campaigns should warm-start
    # from the whole fleet's material, not this process's stale view
    store.refresh()
    ranked = sorted(
        store.records(kernel=kernel, backend=backend),
        key=lambda r: _dist(signature, r.signature))
    ranked = [r for r in ranked if _dist(signature, r.signature) != float("inf")]
    if not ranked:
        return None, None
    configs = [dict(ranked[0].config)]
    first = config_key(ranked[0].config)
    records = [(dict(r.config), float(r.objective))
               for r in ranked[1 : neighbors + 1]
               if config_key(r.config) != first]
    return configs, records or None
