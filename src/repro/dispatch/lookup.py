"""Nearest-neighbor config resolution over the tuning store.

An exact ``(kernel, signature, backend)`` hit wins outright. Otherwise the
store's records for the same kernel+backend are ranked by log-scale shape
distance (see :mod:`repro.dispatch.signature`) and the closest compatible
record is returned, annotated with its distance so callers can decide
whether the neighbor is close enough to serve as-is or should also trigger
a background re-tune.
"""

from __future__ import annotations

import dataclasses

from repro.dispatch.signature import ShapeSignature, signature_distance
from repro.dispatch.store import TuningRecord, TuningStore

__all__ = ["Resolution", "resolve"]


@dataclasses.dataclass
class Resolution:
    record: TuningRecord
    distance: float      # 0.0 for an exact hit
    exact: bool

    @property
    def config(self) -> dict:
        return self.record.config


def resolve(
    store: TuningStore,
    kernel: str,
    signature: ShapeSignature,
    backend: str,
    max_distance: float | None = None,
) -> Resolution | None:
    """Exact hit, else nearest compatible neighbor within ``max_distance``
    (no bound when ``None``). Returns ``None`` when nothing qualifies."""
    hit = store.get(kernel, signature, backend)
    if hit is not None:
        return Resolution(hit, 0.0, True)
    best, best_d = None, float("inf")
    for rec in store.records(kernel=kernel, backend=backend):
        d = signature_distance(signature, rec.signature)
        if d < best_d:
            best, best_d = rec, d
    if best is None or best_d == float("inf"):
        return None
    if max_distance is not None and best_d > max_distance:
        return None
    return Resolution(best, best_d, False)
