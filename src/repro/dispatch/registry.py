"""Dispatch registry: kernel name -> how to build a tuned variant.

Each entry ties together the three things the runtime needs: a *builder*
(``builder(config, **static_kw) -> fn(*arrays)``) producing the concrete JAX
program for a configuration, the kernel's :class:`ConfigurationSpace`
factory (``space(target) -> ConfigurationSpace``) for background campaigns,
and the space default as the last-resort config when the store is empty.

The built-in PolyBench kernels register themselves from
``repro.kernels.variants`` on first use (lazy, to keep this module
import-light and cycle-free); user kernels register with :func:`register`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

__all__ = ["VariantSpec", "register", "get", "registered"]


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    name: str
    builder: Callable[..., Callable]            # builder(config, **static_kw) -> fn
    space: Callable[[str], Any]                 # target -> ConfigurationSpace
    eval_repeats: int = 1                       # timing repeats for background tuning
    eval_warmup: int = 1
    # optional override for background campaigns: factory(cfg) -> (fn, args)
    # goes in, evaluator(cfg) -> EvalResult comes out. Defaults to wall-clock
    # timing (TimingEvaluator); inject e.g. a cost-model scorer instead.
    make_evaluator: Callable[[Callable], Callable] | None = None

    def default_config(self, target: str = "host") -> dict:
        return self.space(target).default_configuration()


_REGISTRY: dict[str, VariantSpec] = {}
_builtins_loaded = False


def register(
    name: str,
    builder: Callable[..., Callable],
    space: Callable[[str], Any],
    **kw,
) -> VariantSpec:
    spec = VariantSpec(name=name, builder=builder, space=space, **kw)
    _REGISTRY[name] = spec
    return spec


def _ensure_builtins() -> None:
    global _builtins_loaded
    if _builtins_loaded:
        return
    _builtins_loaded = True
    from repro.kernels import model_kernels, variants

    variants.register_dispatch_variants()      # PolyBench host kernels
    model_kernels.register_model_kernels()     # flash attention + matmul


def get(name: str) -> VariantSpec:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no dispatch variant registered for kernel {name!r}; "
            f"known: {sorted(_REGISTRY)}"
        ) from None


def registered() -> list[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)
