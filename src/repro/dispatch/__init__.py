"""repro.dispatch — tuning cache + runtime kernel dispatch.

The layer that turns offline autotuning campaigns into an online service:
a persistent :class:`TuningStore` of best-known configs keyed by
``(kernel, shape-signature, backend)``, nearest-neighbor resolution for
shapes no campaign ever saw, a :func:`dispatch` runtime API with an
in-process compiled-executable cache, and background BO campaigns
(warm-started from the store) that hot-swap better configs in as they land.

    from repro import dispatch
    svc = dispatch.configure("results/store")
    out = svc.call("syr2k", C, A, B)          # tuned variant, jitted+cached
"""

from repro.dispatch.background import BackgroundTuner
from repro.dispatch.lookup import Resolution, resolve
from repro.dispatch.registry import VariantSpec, get, register, registered
from repro.dispatch.service import (
    DispatchService,
    call,
    configure,
    dispatch,
    get_service,
)
from repro.dispatch.signature import (
    ShapeSignature,
    bucket_signature,
    compatible,
    parse_signature_key,
    shape_signature,
    signature_distance,
    signature_key,
)
from repro.dispatch.store import TuningRecord, TuningStore

__all__ = [
    "BackgroundTuner",
    "DispatchService",
    "Resolution",
    "ShapeSignature",
    "TuningRecord",
    "TuningStore",
    "VariantSpec",
    "bucket_signature",
    "call",
    "compatible",
    "configure",
    "dispatch",
    "get",
    "get_service",
    "parse_signature_key",
    "register",
    "registered",
    "resolve",
    "shape_signature",
    "signature_distance",
    "signature_key",
]
