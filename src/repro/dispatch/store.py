"""TuningStore: the persistent, process-safe store of best-known configs.

Layout on disk (``<path>/``):

  * ``store.jsonl`` — append-only log, one :class:`TuningRecord` per line.
    The in-memory view keeps, per ``(kernel, signature, backend)`` key, the
    record with the lowest objective; the log keeps full history until
    :meth:`compact` rewrites it to bests-only.
  * ``store.lock``  — advisory ``flock`` file serializing writers across
    processes. Readers re-tail the log (:meth:`refresh`) from their last
    byte offset, so concurrent campaigns publishing results are picked up
    without re-parsing the whole file.

This is the reuse layer the extended paper calls the "evaluation database
across datasets": offline :class:`~repro.core.database.PerformanceDatabase`
campaign directories are ingested via :meth:`ingest_database`, and live
(background) campaigns publish through :meth:`put` — a hot-swap, since every
reader's next :meth:`refresh` sees the better config.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from typing import Any, Iterator, Mapping

try:
    import fcntl
except ImportError:  # non-POSIX: single-process best effort
    fcntl = None

from repro.core.jsonl import append_jsonl, repair_torn_tail
from repro.dispatch.signature import (
    ShapeSignature,
    parse_signature_key,
    signature_key,
)

__all__ = ["TuningRecord", "TuningStore"]


@dataclasses.dataclass
class TuningRecord:
    kernel: str
    signature: ShapeSignature
    backend: str
    config: dict
    objective: float
    n_evals: int = 0
    source: str = ""          # e.g. "campaign:results/syr2k_rf", "background"
    created: float = 0.0      # unix seconds; 0 = unknown (legacy)

    def key(self) -> tuple:
        return (self.kernel, signature_key(self.signature), self.backend)

    def age_sec(self, now: float | None = None) -> float:
        if not self.created:
            return float("inf")
        return (now if now is not None else time.time()) - self.created

    def to_json(self) -> dict:
        return {
            "kernel": self.kernel,
            "signature": signature_key(self.signature),
            "backend": self.backend,
            "config": self.config,
            "objective": self.objective,
            "n_evals": self.n_evals,
            "source": self.source,
            "created": self.created,
        }

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "TuningRecord":
        return cls(
            kernel=str(d["kernel"]),
            signature=parse_signature_key(str(d["signature"])),
            backend=str(d["backend"]),
            config=dict(d["config"]),
            objective=float(d["objective"]),
            n_evals=int(d.get("n_evals", 0)),
            source=str(d.get("source", "")),
            created=float(d.get("created", 0.0)),
        )


class TuningStore:
    """Best-config store keyed by ``(kernel, shape-signature, backend)``."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(path, exist_ok=True)
        self._best: dict[tuple, TuningRecord] = {}
        self._offset = 0  # bytes of store.jsonl already folded into _best
        self.refresh()

    # -- paths / locking --------------------------------------------------------

    def _log_path(self) -> str:
        return os.path.join(self.path, "store.jsonl")

    @contextlib.contextmanager
    def _lock(self) -> Iterator[None]:
        lock_path = os.path.join(self.path, "store.lock")
        f = open(lock_path, "a+")
        try:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_EX)
            yield
        finally:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_UN)
            f.close()

    # -- read side --------------------------------------------------------------

    def refresh(self) -> int:
        """Fold any log lines appended since the last read (by this or any
        other process) into the in-memory best view. Returns #records read."""
        path = self._log_path()
        if not os.path.exists(path):
            return 0
        n = 0
        with open(path) as f:
            f.seek(self._offset)
            for line in f:
                if not line.endswith("\n"):
                    break  # torn tail from a writer mid-append; retry next refresh
                self._offset += len(line.encode())
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = TuningRecord.from_json(json.loads(line))
                except (json.JSONDecodeError, KeyError, ValueError):
                    continue
                self._fold(rec)
                n += 1
        return n

    def _fold(self, rec: TuningRecord) -> None:
        cur = self._best.get(rec.key())
        if cur is None or rec.objective <= cur.objective:
            self._best[rec.key()] = rec

    def __len__(self) -> int:
        return len(self._best)

    def get(self, kernel: str, signature: ShapeSignature, backend: str) -> TuningRecord | None:
        return self._best.get((kernel, signature_key(signature), backend))

    def records(self, kernel: str | None = None, backend: str | None = None) -> list[TuningRecord]:
        return [
            r for r in self._best.values()
            if (kernel is None or r.kernel == kernel)
            and (backend is None or r.backend == backend)
        ]

    # -- write side -------------------------------------------------------------

    def put(self, rec: TuningRecord, force: bool = False) -> bool:
        """Publish a record. Only a strict improvement (or ``force``) for an
        existing key is appended; returns whether the record was accepted."""
        if not rec.created:
            rec = dataclasses.replace(rec, created=time.time())
        with self._lock():
            # terminate a crashed writer's torn tail so our append does not
            # merge into the fragment; refresh then skips the isolated line
            repair_torn_tail(self._log_path())
            self.refresh()  # fold concurrent writers before deciding
            cur = self._best.get(rec.key())
            if cur is not None and not force and rec.objective >= cur.objective:
                return False
            self._offset += append_jsonl(self._log_path(), rec.to_json(), fsync=True)
            self._fold(rec)
            return True

    def ingest_database(
        self,
        db_path: str,
        kernel: str,
        signature: ShapeSignature,
        backend: str,
        source: str | None = None,
    ) -> TuningRecord | None:
        """Populate from an existing campaign result dir (results.jsonl/.json).
        Publishes the campaign's best evaluated config; returns it (or None
        when the campaign has no successful evaluation or no improvement)."""
        from repro.core.database import PerformanceDatabase

        db = PerformanceDatabase(db_path)
        best = db.best()
        if best is None:
            return None
        rec = TuningRecord(
            kernel=kernel,
            signature=signature,
            backend=backend,
            config=dict(best.config),
            objective=float(best.objective),
            n_evals=len(db),
            source=source or f"campaign:{db_path}",
        )
        return rec if self.put(rec) else None

    def compact(self) -> int:
        """Rewrite the log keeping only the current best per key. Returns the
        number of surviving records."""
        with self._lock():
            self.refresh()
            tmp = self._log_path() + ".tmp"
            with open(tmp, "w") as f:
                for rec in self._best.values():
                    f.write(json.dumps(rec.to_json()) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._log_path())
            self._offset = os.path.getsize(self._log_path())
            return len(self._best)
