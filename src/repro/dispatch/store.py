"""TuningStore: the persistent, process-safe store of best-known configs.

Layout on disk (``<path>/``):

  * ``store.jsonl`` — append-only log, one :class:`TuningRecord` per line.
    The in-memory view keeps, per ``(kernel, signature, backend)`` key, the
    record with the lowest objective; the log keeps full history until
    :meth:`compact` rewrites it to bests-only.
  * ``store.lock``  — advisory ``flock`` file serializing writers across
    processes. Readers re-tail the log (:meth:`refresh`) from their last
    byte offset, so concurrent campaigns publishing results are picked up
    without re-parsing the whole file.

This is the reuse layer the extended paper calls the "evaluation database
across datasets": offline :class:`~repro.core.database.PerformanceDatabase`
campaign directories are ingested via :meth:`ingest_database`, and live
(background) campaigns publish through :meth:`put` — a hot-swap, since every
reader's next :meth:`refresh` sees the better config.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
from typing import Any, Iterator, Mapping

try:
    import fcntl
except ImportError:  # non-POSIX: single-process best effort
    fcntl = None

from repro.core.jsonl import append_jsonl, iter_jsonl_tail, repair_torn_tail
from repro.core.space import config_key
from repro.dispatch.signature import (
    ShapeSignature,
    bucket_signature,
    parse_signature_key,
    signature_key,
)

__all__ = ["TuningRecord", "TuningStore"]


@dataclasses.dataclass
class TuningRecord:
    kernel: str
    signature: ShapeSignature
    backend: str
    config: dict
    objective: float
    n_evals: int = 0
    source: str = ""          # e.g. "campaign:results/syr2k_rf", "background"
    created: float = 0.0      # unix seconds; 0 = unknown (legacy)

    def key(self) -> tuple:
        return (self.kernel, signature_key(self.signature), self.backend)

    def age_sec(self, now: float | None = None) -> float:
        if not self.created:
            return float("inf")
        # lint: allow=REP101 record `created` stamps are cross-process wall-clock
        return (now if now is not None else time.time()) - self.created

    def to_json(self) -> dict:
        return {
            "kernel": self.kernel,
            "signature": signature_key(self.signature),
            "backend": self.backend,
            "config": self.config,
            "objective": self.objective,
            "n_evals": self.n_evals,
            "source": self.source,
            "created": self.created,
        }

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "TuningRecord":
        return cls(
            kernel=str(d["kernel"]),
            signature=parse_signature_key(str(d["signature"])),
            backend=str(d["backend"]),
            config=dict(d["config"]),
            objective=float(d["objective"]),
            n_evals=int(d.get("n_evals", 0)),
            source=str(d.get("source", "")),
            created=float(d.get("created", 0.0)),
        )


class TuningStore:
    """Best-config store keyed by ``(kernel, shape-signature, backend)``.

    ``bucket=True`` applies write-time signature bucketing: every signature
    is snapped to powers of ``bucket_base`` (see
    :func:`~repro.dispatch.signature.bucket_signature`) on both :meth:`put`
    and :meth:`get`, so jittery serving shapes (batch 33, 34, 35, ...)
    collapse onto one store key instead of fragmenting the store.
    """

    def __init__(self, path: str, *, bucket: bool = False, bucket_base: float = 2.0):
        self.path = path
        self.bucket = bucket
        self.bucket_base = bucket_base
        os.makedirs(path, exist_ok=True)
        self._best: dict[tuple, TuningRecord] = {}
        # (kernel, sig-key, backend, config-key) tuples banned from serving;
        # _quarantined_json keeps the tombstone lines so compact() rewrites them
        self._quarantined: set[tuple] = set()
        self._quarantined_json: dict[tuple, dict] = {}
        self._access: dict[tuple, float] = {}  # in-process LRU clock per key
        self._offset = 0  # bytes of store.jsonl already folded into _best
        # in-process companion to the flock: refresh() is called bare (no
        # flock) from dispatch resolution, warm-start ranking, and the fleet
        # sync thread — two concurrent refreshes of one store object would
        # otherwise both fold the same lines and double-advance _offset past
        # EOF, silently skipping every record that lands there later
        self._tlock = threading.RLock()
        # repro.fleet op emission: ``sink(kind, record)`` fires for every
        # accepted put, quarantine, and compaction eviction, WHILE the store
        # lock is held — op stamp order must match store application order,
        # or a put/evict pair racing across the lock boundary draws inverted
        # Lamport stamps and the merge resurrects (or wrongly kills) the
        # record fleet-wide. Lock order is always store -> fleet, never the
        # reverse: fleet ingestion releases the oplog locks before touching
        # the store. Remote ops fold back in through :meth:`apply_remote`,
        # which never re-emits.
        self._op_sink = None
        self.refresh()

    def set_op_sink(self, sink) -> None:
        """Attach (or detach, with ``None``) the replication op sink — see
        :class:`repro.fleet.Replica`, which forwards ops into the oplog."""
        self._op_sink = sink

    def _canon(self, sig: ShapeSignature) -> ShapeSignature:
        return bucket_signature(sig, self.bucket_base) if self.bucket else sig

    # -- paths / locking --------------------------------------------------------

    def _log_path(self) -> str:
        return os.path.join(self.path, "store.jsonl")

    @contextlib.contextmanager
    def _lock(self) -> Iterator[None]:
        lock_path = os.path.join(self.path, "store.lock")
        with self._tlock:  # threads of this process first, then processes
            f = open(lock_path, "a+")
            try:
                if fcntl is not None:
                    fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                yield
            finally:
                if fcntl is not None:
                    fcntl.flock(f.fileno(), fcntl.LOCK_UN)
                f.close()

    # -- read side --------------------------------------------------------------

    def refresh(self) -> int:
        """Fold any log lines appended since the last read (by this or any
        other process) into the in-memory best view. Returns #records read."""
        with self._tlock:
            return self._refresh_locked()

    def _refresh_locked(self) -> int:
        n = 0
        for d, self._offset in iter_jsonl_tail(self._log_path(), self._offset):
            if d is None:
                continue
            try:
                rec = TuningRecord.from_json(d)
            except (KeyError, ValueError):
                continue
            if d.get("quarantined"):
                self._apply_quarantine(rec, d)
            elif d.get("evicted"):
                self._apply_evict(rec)
            else:
                self._fold(rec)
            n += 1
        return n

    @staticmethod
    def _qkey(rec: TuningRecord) -> tuple:
        return rec.key() + (config_key(rec.config),)

    def _apply_quarantine(self, rec: TuningRecord, line: dict) -> None:
        qk = self._qkey(rec)
        self._quarantined.add(qk)
        self._quarantined_json[qk] = line
        cur = self._best.get(rec.key())
        if cur is not None and config_key(cur.config) == config_key(rec.config):
            del self._best[rec.key()]

    def _apply_evict(self, rec: TuningRecord) -> bool:
        """A replicated eviction tombstone: drop the key's current best iff
        it is the tombstoned config (a better config appended later in the
        log must survive replay — lines are folded in order)."""
        cur = self._best.get(rec.key())
        if cur is not None and config_key(cur.config) == config_key(rec.config):
            del self._best[rec.key()]
            return True
        return False

    def _fold(self, rec: TuningRecord) -> None:
        if self._qkey(rec) in self._quarantined:
            return
        cur = self._best.get(rec.key())
        if cur is None or rec.objective <= cur.objective:
            self._best[rec.key()] = rec

    def __len__(self) -> int:
        return len(self._best)

    def get(self, kernel: str, signature: ShapeSignature, backend: str) -> TuningRecord | None:
        key = (kernel, signature_key(self._canon(signature)), backend)
        rec = self._best.get(key)
        if rec is not None:
            # under the lock: compact() rewrites _access wholesale while
            # holding it, and a touch landing in the superseded dict would
            # silently vanish from the LRU ordering compact evicts by
            with self._tlock:
                self._access[key] = time.time()
        return rec

    def peek(self, kernel: str, signature: ShapeSignature, backend: str) -> TuningRecord | None:
        """Like :meth:`get` but without the LRU touch — replication's
        reconcile walks every key each cycle, and counting those reads as
        use would erase the access ordering :meth:`compact` evicts by."""
        return self._best.get(
            (kernel, signature_key(self._canon(signature)), backend))

    def is_quarantined(self, rec: TuningRecord) -> bool:
        """Peek-style: whether this exact (kernel, signature, backend,
        config) is already banned in this process's view. Reconcile's fast
        path — re-deriving bans every sync cycle must not pay a flocked
        log append attempt per historical quarantine."""
        rec = dataclasses.replace(rec, signature=self._canon(rec.signature))
        return self._qkey(rec) in self._quarantined

    def quarantines(self, kernel: str | None = None) -> list[dict]:
        """The quarantine tombstones in this process's view, each with its
        machine-readable ``reason`` (empty string for tombstones written
        before reasons existed, and for replicated bans — reasons are
        host-local). Keys: kernel, signature, backend, config, reason."""
        out = []
        with self._tlock:
            lines = list(self._quarantined_json.values())
        for line in lines:
            if kernel is not None and line.get("kernel") != kernel:
                continue
            out.append({
                "kernel": line.get("kernel"),
                "signature": line.get("signature"),
                "backend": line.get("backend"),
                "config": line.get("config"),
                "reason": line.get("reason", ""),
            })
        return out

    def records(self, kernel: str | None = None, backend: str | None = None) -> list[TuningRecord]:
        return [
            r for r in self._best.values()
            if (kernel is None or r.kernel == kernel)
            and (backend is None or r.backend == backend)
        ]

    # -- write side -------------------------------------------------------------

    def put(self, rec: TuningRecord, force: bool = False) -> bool:
        """Publish a record. Only a strict improvement (or ``force``) for an
        existing key is appended; returns whether the record was accepted.
        Quarantined (kernel, signature, backend, config) combinations are
        rejected outright — a poisoned config must not be re-served."""
        if not rec.created:
            rec = dataclasses.replace(rec, created=time.time())
        rec = dataclasses.replace(rec, signature=self._canon(rec.signature))
        with self._lock():
            # terminate a crashed writer's torn tail so our append does not
            # merge into the fragment; refresh then skips the isolated line
            repair_torn_tail(self._log_path())
            self.refresh()  # fold concurrent writers before deciding
            if self._qkey(rec) in self._quarantined:
                return False
            cur = self._best.get(rec.key())
            if cur is not None and not force and rec.objective >= cur.objective:
                return False
            self._offset += append_jsonl(self._log_path(), rec.to_json(), fsync=True)
            self._fold(rec)
            if self._op_sink is not None:
                self._op_sink("put", rec)
            return True

    def quarantine(self, rec: TuningRecord, reason: str = "") -> None:
        """Ban this record's exact (kernel, signature, backend, config) from
        being served or re-accepted — the dispatch service calls this when a
        stored config fails to build or trace, or when the static
        feasibility pass (repro.analyze) rejects it. The tombstone is
        appended to the log, so other processes pick it up on their next
        refresh. ``reason`` is a machine-readable code string (e.g.
        ``"build_failed"`` or feasibility codes like
        ``"tile_not_positive:bi"``) persisted on the tombstone line and
        surfaced by :meth:`quarantines` / ``repro-fleet status``; replicated
        quarantine ops do not carry it (the reason stays host-local)."""
        rec = dataclasses.replace(rec, signature=self._canon(rec.signature))
        line = rec.to_json()
        line["quarantined"] = True
        if reason:
            line["reason"] = reason
        with self._lock():
            repair_torn_tail(self._log_path())
            self.refresh()
            self._offset += append_jsonl(self._log_path(), line, fsync=True)
            self._apply_quarantine(rec, line)
            if self._op_sink is not None:
                self._op_sink("quarantine", rec)

    def apply_remote(self, kind: str, rec: TuningRecord) -> bool:
        """Replication merge hook (see :mod:`repro.fleet`): apply one
        replicated operation to this store WITHOUT re-emitting it to the op
        sink — a merged op must never echo back into the log it came from.
        Returns whether the store changed.

        * ``put`` — accepted only as a strict improvement over the current
          best (the fleet merge decides replacements by first evicting the
          dead local record); re-applying the current best is a no-op, so
          replaying an op stream is idempotent.
        * ``quarantine`` — same semantics as :meth:`quarantine`.
        * ``evict`` — drops the key's best iff it is this exact config and
          persists an ``evicted`` tombstone line so the record does not
          resurrect when the log is replayed by a fresh process.
        """
        rec = dataclasses.replace(rec, signature=self._canon(rec.signature))
        with self._lock():
            repair_torn_tail(self._log_path())
            self.refresh()
            if kind == "put":
                if self._qkey(rec) in self._quarantined:
                    return False
                cur = self._best.get(rec.key())
                if cur is not None and rec.objective >= cur.objective:
                    return False
                self._offset += append_jsonl(
                    self._log_path(), rec.to_json(), fsync=True)
                self._fold(rec)
                return True
            if kind == "quarantine":
                if self._qkey(rec) in self._quarantined:
                    return False
                line = rec.to_json()
                line["quarantined"] = True
                self._offset += append_jsonl(self._log_path(), line, fsync=True)
                self._apply_quarantine(rec, line)
                return True
            if kind == "evict":
                cur = self._best.get(rec.key())
                if cur is None or config_key(cur.config) != config_key(rec.config):
                    return False
                line = rec.to_json()
                line["evicted"] = True
                self._offset += append_jsonl(self._log_path(), line, fsync=True)
                del self._best[rec.key()]
                return True
            raise ValueError(f"unknown replicated op kind {kind!r}")

    def ingest_database(
        self,
        db_path: str,
        kernel: str,
        signature: ShapeSignature,
        backend: str,
        source: str | None = None,
    ) -> TuningRecord | None:
        """Populate from an existing campaign result dir (results.jsonl/.json).
        Publishes the campaign's best evaluated config; returns it (or None
        when the campaign has no successful evaluation or no improvement)."""
        from repro.core.database import PerformanceDatabase

        db = PerformanceDatabase(db_path)
        best = db.best()
        if best is None:
            return None
        rec = TuningRecord(
            kernel=kernel,
            signature=signature,
            backend=backend,
            config=dict(best.config),
            objective=float(best.objective),
            n_evals=len(db),
            source=source or f"campaign:{db_path}",
        )
        return rec if self.put(rec) else None

    def compact(
        self,
        *,
        ttl_sec: float | None = None,
        max_per_kernel: int | None = None,
    ) -> int:
        """Rewrite the log keeping only the current best per key, optionally
        evicting along the way. Returns the number of surviving records.

        * ``ttl_sec`` drops records older than the TTL (records with an
          unknown ``created`` time have infinite age and are evicted first);
        * ``max_per_kernel`` is a per-kernel size budget: only the
          ``max_per_kernel`` most-recently-used keys per kernel survive
          (LRU by this process's :meth:`get` hits, falling back to the
          record's ``created`` time for keys never read here).

        Quarantine tombstones survive compaction so a poisoned config stays
        banned across process restarts. Every eviction is reported to the
        replication op sink (as an ``evict`` tombstone op) so a compacted
        record does not resurrect from a peer on the next fleet pull."""
        with self._lock():
            self.refresh()
            now = time.time()
            survivors = dict(self._best)
            if ttl_sec is not None:
                survivors = {k: r for k, r in survivors.items()
                             if r.age_sec(now) <= ttl_sec}
            if max_per_kernel is not None:
                by_kernel: dict[str, list[tuple]] = {}
                for k, r in survivors.items():
                    by_kernel.setdefault(r.kernel, []).append((k, r))
                survivors = {}
                for items in by_kernel.values():
                    items.sort(key=lambda kr: self._access.get(kr[0], kr[1].created),
                               reverse=True)
                    survivors.update(dict(items[:max_per_kernel]))
            tmp = self._log_path() + ".tmp"
            with open(tmp, "w") as f:
                for rec in survivors.values():
                    f.write(json.dumps(rec.to_json()) + "\n")
                for line in self._quarantined_json.values():
                    f.write(json.dumps(line) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._log_path())
            evicted = [r for k, r in self._best.items() if k not in survivors]
            self._best = survivors
            self._access = {k: t for k, t in self._access.items() if k in survivors}
            self._offset = os.path.getsize(self._log_path())
            # evict ops are stamped while the store lock is still held:
            # eviction is the one op whose merge semantics are stamp-ordered
            # against puts ("a put dies iff stamp <= the newest evict
            # stamp"), so a concurrent put accepted after this compaction
            # must also be stamped after it — emitting outside the lock
            # would let that fresh result draw the older stamp and be
            # killed fleet-wide by our tombstone
            if self._op_sink is not None:
                for r in evicted:
                    self._op_sink("evict", r)
            return len(self._best)
