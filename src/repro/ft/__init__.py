"""repro.ft — fault tolerance: gradient compression (error feedback),
elastic mesh planning, straggler monitoring. Evaluation-campaign fault
tolerance (penalty-on-failure, deadline) lives in repro.core.plopper; search
resume lives in repro.core.database."""

from repro.ft.compression import compressed_psum, dequantize, ef_compress_grads, quantize
from repro.ft.elastic import LADDER, MeshPlan, plan_mesh
from repro.ft.straggler import StragglerMonitor

__all__ = ["compressed_psum", "dequantize", "ef_compress_grads", "quantize",
           "LADDER", "MeshPlan", "plan_mesh", "StragglerMonitor"]
