"""Straggler detection for the training loop: EWMA of step wall-times with a
multiplicative deadline; slow steps are flagged and a configurable action
fires (log, checkpoint-now, or re-plan trigger). Pure bookkeeping — unit
testable without hardware."""

from __future__ import annotations

import dataclasses
import time

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0      # step slower than threshold * EWMA == straggler
    alpha: float = 0.1
    warmup_steps: int = 5

    ewma: float = 0.0
    n: int = 0
    flagged: int = 0
    _t0: float = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> tuple[float, bool]:
        """Returns (duration, is_straggler)."""
        dur = time.perf_counter() - self._t0
        return self.observe(dur)

    def observe(self, dur: float) -> tuple[float, bool]:
        self.n += 1
        if self.n <= self.warmup_steps:
            self.ewma = dur if self.ewma == 0 else \
                (1 - self.alpha) * self.ewma + self.alpha * dur
            return dur, False
        slow = dur > self.threshold * self.ewma
        if slow:
            self.flagged += 1
        else:  # stragglers don't poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dur
        return dur, slow
