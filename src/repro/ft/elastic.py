"""Elastic scaling: re-plan the production mesh when devices are lost.

Because every step function takes the mesh as data (shardings are built per
mesh), a shrink/regrow is: pick a new shape from the allowed ladder,
re-lower, restore the last checkpoint, continue. This module holds the
planning logic (pure, unit-testable); dryrun.py demonstrates that both the
full and the shrunk meshes lower+compile."""

from __future__ import annotations

import dataclasses

__all__ = ["MeshPlan", "plan_mesh", "LADDER"]

# allowed (pod, data, model) shapes, preference order (biggest first)
LADDER = [
    (2, 16, 16),
    (1, 16, 16),
    (1, 8, 16),
    (1, 8, 8),
    (1, 4, 8),
    (1, 4, 4),
    (1, 2, 4),
    (1, 1, 4),
    (1, 1, 2),
    (1, 1, 1),
]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    n_devices: int
    dropped: int   # healthy devices left unused by this plan

    @property
    def multi_pod(self) -> bool:
        return self.shape[0] > 1


def plan_mesh(healthy_devices: int, ladder=LADDER) -> MeshPlan:
    """Largest ladder entry that fits the healthy-device count."""
    for shape in ladder:
        n = shape[0] * shape[1] * shape[2]
        if n <= healthy_devices:
            axes = ("pod", "data", "model") if shape[0] > 1 else ("data", "model")
            eff = shape if shape[0] > 1 else shape[1:]
            return MeshPlan(eff, axes, n, healthy_devices - n)
    raise RuntimeError("no devices healthy")
