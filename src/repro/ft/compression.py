"""Gradient compression with error feedback for cross-pod all-reduce.

The wire trick: quantize the gradient to a low-precision payload *before*
the data-parallel all-reduce and keep the quantization residual locally
(error feedback), adding it back before the next step's quantization. With
bf16 payloads the HLO all-reduce moves half the bytes of f32; int8 moves a
quarter. Exposed as a shard_map-based DP reducer so the collective dtype is
explicit in the lowered HLO (visible to the roofline's collective parser).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["quantize", "dequantize", "compressed_psum", "ef_compress_grads"]


def quantize(x: jnp.ndarray, dtype=jnp.int8):
    """Symmetric per-tensor quantization. Returns (payload, scale)."""
    if dtype == jnp.bfloat16:
        return x.astype(jnp.bfloat16), jnp.ones((), jnp.float32)
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    if q.dtype == jnp.bfloat16:
        return q.astype(jnp.float32)
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str, dtype=jnp.int8):
    """All-reduce with a genuinely compressed wire payload (inside shard_map).

    int8 path: agree on a global scale (pmax of local maxima), pre-divide by
    the shard count so the int8 sum cannot overflow, and psum *in int8* —
    1 byte/param on the wire (4x less than f32). The pre-division costs
    log2(N) bits of precision, which the error-feedback residual
    (ef_compress_grads) re-injects on later steps."""
    if dtype == jnp.bfloat16:
        q = x.astype(jnp.bfloat16)
        return jax.lax.psum(q, axis_name).astype(jnp.float32)
    n = jax.lax.psum(1, axis_name)  # axis size (works across jax versions)
    amax = jnp.max(jnp.abs(x)) + 1e-12
    gmax = jax.lax.pmax(amax, axis_name)
    scale = gmax / 127.0
    # pre-scaled so the N-shard sum stays within the int8 range
    q = jnp.clip(jnp.round(x / (scale * n)), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q, axis_name)  # int8 payload on the wire
    return total.astype(jnp.float32) * (scale * n)


def ef_compress_grads(grads, residual, dtype=jnp.int8):
    """Error-feedback step (local half): g' = Q(g + r); r' = g + r - g'.

    The caller all-reduces the quantized payload; this function keeps the
    bookkeeping pure so it can live inside a jitted train step."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize(g32, dtype)
        deq = dequantize(q, scale)
        return deq, g32 - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])
