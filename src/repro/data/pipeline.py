"""Synthetic sharded token pipeline.

Deterministic (seeded counter-based) token generation with document packing,
sliced per data-parallel shard the way a multi-host input pipeline would
slice a global batch: each host materializes only its shard and the global
array is assembled with jax.make_array_from_single_device_arrays semantics
(single-process here, so device_put with the batch NamedSharding).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "make_batch"]


@dataclasses.dataclass
class SyntheticLM:
    """Packed-documents LM stream: documents of random length separated by
    EOS, labels = next token (shifted), deterministic in (seed, step)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 1
    mean_doc_len: int = 256

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        toks = rng.integers(2, self.vocab_size, size=(B, S + 1), dtype=np.int32)
        # stamp EOS at geometric document boundaries (packing)
        p = 1.0 / max(self.mean_doc_len, 2)
        eos_mask = rng.random((B, S + 1)) < p
        toks = np.where(eos_mask, self.eos_id, toks)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch(stream: SyntheticLM, step: int, shardings=None) -> dict:
    """Materialize the batch, placed per the given NamedSharding tree."""
    host = stream.batch_at(step)
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in host.items()}
    return {
        k: jax.device_put(v, shardings[k]) for k, v in host.items()
    }
