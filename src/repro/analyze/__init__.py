"""repro.analyze — static analysis for the tuning stack.

Two passes, no tracing, no builds:

  * :mod:`repro.analyze.feasibility` — declarative per-kernel constraint
    rules that judge a configuration against the problem dims before any
    code runs (the paper's Floyd-Warshall post-mortem, turned into a
    pre-flight check for the search and dispatch paths);
  * :mod:`repro.analyze.lint` — an AST-based concurrency lint that checks
    the documented threading invariants of ``src/repro`` itself (lock
    order, guarded shared-state mutation, monotonic duration clocks,
    daemon/stop handling for threads).

CLI: ``python -m repro.launch.analyze {space,lint}`` (``repro-analyze``).
"""

from repro.analyze.feasibility import (
    Feasibility,
    Finding,
    check_config,
    feasibility_filter,
    kernel_rules,
)
from repro.analyze.lint import LintFinding, lint_paths, lint_source

__all__ = [
    "Feasibility",
    "Finding",
    "check_config",
    "feasibility_filter",
    "kernel_rules",
    "LintFinding",
    "lint_paths",
    "lint_source",
]
