"""Pass 1 — static config feasibility.

Declarative per-kernel constraint rules that judge a configuration against
the problem dimensions *without tracing or building anything*: structural
validity (required params present, tiles positive integers, variant choices
known, fuse factors dividing the step count), resource limits (estimated
VMEM footprint from the BlockSpec geometry via the analytic cost model),
and schedule-quality smells (MXU (8, 128) misalignment, lcm-padding blowup,
grid-size sanity).

Findings carry a stable machine-readable ``code`` (e.g.
``tile_not_positive:bi``, ``vmem_overflow``) and a ``severity``:

  * ``"error"`` — the config is invalid: it would fail to build/trace, or
    the cost model proves it cannot fit (VMEM over budget on a TPU-class
    target). Errors make :attr:`Feasibility.ok` false; the search path
    prunes these before acquisition scoring and ``DispatchService``
    quarantines matching store records without paying an ``eval_shape``.
  * ``"warn"`` — the config builds but is pathological (the paper's
    Floyd-Warshall failure mode): heavy padding waste, misaligned MXU
    tiles, oversized grids. Warnings never prune or quarantine; they feed
    the ``repro-analyze space`` audit.

The severity split is what keeps the pass zero-false-positive: a config is
only ever rejected for a reason that is *provably* fatal for that builder,
which the accepted-implies-builds property test pins for every registered
kernel.

Rules for new kernels go through :func:`register_rules`; kernels with no
registered rules (toy test kernels, third-party registrations) are treated
as feasible — the pass never guesses.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Mapping, Sequence

__all__ = [
    "Feasibility",
    "Finding",
    "KERNEL_RULES",
    "check_config",
    "feasibility_filter",
    "kernel_rules",
    "register_rules",
]

ERROR = "error"
WARN = "warn"

# grid-step budget before a schedule is flagged as pathological: a Pallas /
# XLA loop nest still compiles above this, it just spends its life in
# per-step overhead (warn-only, so the threshold only shapes the audit)
GRID_WARN_STEPS = 1 << 20
# padded-iteration blowup (vs the nominal iteration count) above which a
# host schedule is flagged — syr2k at N=240 with lcm(50, 128)=3200 padding
# sits near 178x, the audit's canonical pathology
PAD_WASTE_RATIO = 1.5


@dataclass(frozen=True)
class Finding:
    """One rule hit. ``code`` is stable across releases (tests and
    quarantine records key on it); ``message`` is for humans."""

    code: str
    severity: str
    message: str
    param: str | None = None

    def to_dict(self) -> dict:
        d = {"code": self.code, "severity": self.severity,
             "message": self.message}
        if self.param is not None:
            d["param"] = self.param
        return d


@dataclass(frozen=True)
class Feasibility:
    """Verdict for one (kernel, config, dims, target) combination."""

    findings: tuple[Finding, ...] = field(default_factory=tuple)

    @property
    def ok(self) -> bool:
        return not any(f.severity == ERROR for f in self.findings)

    @property
    def errors(self) -> tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == ERROR)

    @property
    def warnings(self) -> tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == WARN)

    @property
    def reasons(self) -> tuple[str, ...]:
        """Stable error codes — what lands in quarantine records."""
        return tuple(f.code for f in self.errors)

    def reason(self) -> str:
        """Single machine-readable reason string (codes joined by ``,``)."""
        return ",".join(self.reasons)


FEASIBLE = Feasibility()


@dataclass(frozen=True)
class RuleContext:
    """What a rule may consult besides the config itself."""

    kernel: str
    dims: tuple | None    # problem dims (kernels.problems order), if known
    target: str           # "host" | "tpu" | "cost"


class Rule:
    """Base class: a rule inspects (config, context) and yields findings.

    Rules must be total — any config dict, any dims (including ``None``)
    — and must never trace, build, or import jax at check time."""

    def check(self, cfg: Mapping, ctx: RuleContext) -> Iterator[Finding]:
        raise NotImplementedError

    def describe(self) -> str:
        return self.__class__.__name__


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


class RequiredParams(Rule):
    """Params the builder reads with ``cfg[name]`` — absence is a KeyError
    at build time, so it is an error here."""

    def __init__(self, *names: str):
        self.names = names

    def check(self, cfg, ctx):
        for name in self.names:
            if name not in cfg:
                yield Finding(f"missing_param:{name}", ERROR,
                              f"builder requires {name!r}", name)

    def describe(self):
        return f"required params: {', '.join(self.names)}"


class PositiveIntTiles(Rule):
    """Tile/block params must be positive integers: ``cdiv`` by zero and
    negative reshapes are build-time failures."""

    def __init__(self, *names: str):
        self.names = names

    def check(self, cfg, ctx):
        for name in self.names:
            if name not in cfg:
                continue  # RequiredParams owns absence
            v = cfg[name]
            if not _is_int(v):
                yield Finding(f"tile_not_int:{name}", ERROR,
                              f"{name}={v!r} is not an integer tile", name)
            elif v <= 0:
                yield Finding(f"tile_not_positive:{name}", ERROR,
                              f"{name}={v} must be positive", name)

    def describe(self):
        return f"positive integer tiles: {', '.join(self.names)}"


class ChoiceIn(Rule):
    """Variant-selector params the builder dispatches on (and raises for
    unknown values)."""

    def __init__(self, name: str, choices: Sequence):
        self.name = name
        self.choices = tuple(choices)

    def check(self, cfg, ctx):
        if self.name in cfg and cfg[self.name] not in self.choices:
            yield Finding(
                f"invalid_choice:{self.name}", ERROR,
                f"{self.name}={cfg[self.name]!r} not in {self.choices}",
                self.name)

    def describe(self):
        return f"{self.name} in {self.choices}"


class FuseDivides(Rule):
    """heat-3d: the builder asserts ``(2 * tsteps) % fuse_t == 0`` — a
    non-dividing fuse factor is a hard build failure."""

    def __init__(self, name: str = "fuse_t"):
        self.name = name

    def check(self, cfg, ctx):
        h = cfg.get(self.name, 1)
        if not _is_int(h) or h <= 0:
            yield Finding(f"fuse_not_positive:{self.name}", ERROR,
                          f"{self.name}={h!r} must be a positive integer",
                          self.name)
            return
        if ctx.dims is None or len(ctx.dims) < 2:
            return
        total = 2 * int(ctx.dims[1])
        if total % h != 0:
            yield Finding(
                f"fuse_indivisible:{self.name}", ERROR,
                f"{self.name}={h} does not divide 2*tsteps={total}",
                self.name)

    def describe(self):
        return f"{self.name} divides 2*tsteps"


class PageDividesSeq(Rule):
    """Paged-KV layout contract: the dispatch signature's seq dim is the
    paged cache's *bucket* — by construction a multiple of the config's
    ``page`` (``serve.kvcache`` rounds every view up to page granularity).
    A record whose ``page`` does not divide the signature's S describes a
    layout that cannot have produced this signature: serving it would pair
    a tuned page size with buckets it never sees, so the mismatch is fatal
    for the (cache layout, kernel) pair even though the builder itself —
    which only reads the view it is handed — would trace fine."""

    def __init__(self, name: str = "page", dim_index: int = 2):
        self.name = name
        self.dim_index = dim_index

    def check(self, cfg, ctx):
        v = cfg.get(self.name)
        if not (_is_int(v) and v > 0):
            return  # PositiveIntTiles owns malformed values
        if ctx.dims is None or self.dim_index >= len(ctx.dims):
            return
        s = int(ctx.dims[self.dim_index])
        if s % v != 0:
            yield Finding(
                f"page_indivisible:{self.name}", ERROR,
                f"{self.name}={v} does not divide the seq bucket S={s}",
                self.name)

    def describe(self):
        return f"{self.name} divides the signature's seq bucket"


class VmemBudget(Rule):
    """TPU-class targets only: the analytic cost model derives the VMEM
    footprint from the BlockSpec geometry; over-budget configs are the
    OOM-compile analog and are pruned as errors. Host schedules have no
    VMEM, so the rule is inert there."""

    def check(self, cfg, ctx):
        if ctx.target not in ("tpu", "cost") or ctx.dims is None:
            return
        from repro.kernels.cost import KERNEL_COST_FNS, VMEM_BYTES

        fn = KERNEL_COST_FNS.get(ctx.kernel)
        if fn is None:
            return
        try:
            _, info = fn(cfg, *ctx.dims)
        except Exception:
            # structurally invalid configs are other rules' findings; the
            # cost model choking on them must not mask those codes
            return
        if info.get("infeasible") == "vmem":
            yield Finding(
                "vmem_overflow", ERROR,
                f"estimated VMEM {info.get('vmem_bytes', 0):,} B exceeds "
                f"the {VMEM_BYTES:,} B per-core budget")

    def describe(self):
        return "estimated VMEM footprint within per-core budget (tpu/cost)"


class MxuAlign(Rule):
    """TPU-class targets: tiles off the (8, 128) sublane/lane grid pad in
    the MXU and waste systolic work. Warn-only — the kernels pad and run."""

    def __init__(self, *names: str):
        self.names = names

    def check(self, cfg, ctx):
        if ctx.target not in ("tpu", "cost"):
            return
        for name in self.names:
            v = cfg.get(name)
            if _is_int(v) and v > 0 and v % 8 != 0:
                yield Finding(
                    f"mxu_misaligned:{name}", WARN,
                    f"{name}={v} is not a multiple of the 8-sublane tile",
                    name)

    def describe(self):
        return f"MXU (8,128) alignment: {', '.join(self.names)} (tpu/cost)"


class LcmPadding(Rule):
    """Host syr2k/covariance pad the square dim up to a multiple of
    ``lcm(bi, bj)`` (after the builder's ``min(tile, dim)`` clamp); mixed
    tile families (50 vs 128) blow this up — the audit's canonical
    pathology. Warn-only: the padded kernel is correct, just wasteful."""

    def __init__(self, pi: str, pj: str, dim_index: int):
        self.pi, self.pj, self.dim_index = pi, pj, dim_index

    def check(self, cfg, ctx):
        if ctx.target != "host" or ctx.dims is None:
            return
        bi, bj = cfg.get(self.pi), cfg.get(self.pj)
        if not (_is_int(bi) and bi > 0 and _is_int(bj) and bj > 0):
            return
        n = int(ctx.dims[self.dim_index])
        bi, bj = min(bi, n), min(bj, n)
        lcm = math.lcm(bi, bj)
        padded = -(-n // lcm) * lcm
        ratio = (padded / n) ** 2  # the padded dim is squared in the nest
        if ratio > PAD_WASTE_RATIO:
            yield Finding(
                "padding_waste", WARN,
                f"lcm({self.pi}={bi}, {self.pj}={bj})={lcm} pads "
                f"N={n} to {padded} (~{ratio:.1f}x the nominal work)")

    def describe(self):
        return (f"lcm({self.pi}, {self.pj}) padding blowup vs problem dim "
                f"(host)")


class GridBound(Rule):
    """Grid-size sanity: the number of block steps the schedule implies,
    after the builder's ``min(tile, dim)`` clamp. Oversized grids compile
    but drown in per-step overhead. ``axes`` maps tile params to the dim
    index they divide."""

    def __init__(self, axes: Mapping[str, int], steps: int = 1):
        self.axes = dict(axes)
        self.steps = steps  # outer sequential multiplier (e.g. FW rounds)

    def check(self, cfg, ctx):
        if ctx.dims is None:
            return
        total = self.steps
        for name, di in self.axes.items():
            v = cfg.get(name)
            if not (_is_int(v) and v > 0) or di >= len(ctx.dims):
                return
            n = int(ctx.dims[di])
            total *= -(-n // min(v, n))
        if total > GRID_WARN_STEPS:
            yield Finding(
                "grid_too_large", WARN,
                f"~{total:,} grid steps exceeds the {GRID_WARN_STEPS:,} "
                f"sanity bound")

    def describe(self):
        return f"grid steps over {', '.join(self.axes)} within sanity bound"


# ---------------------------------------------------------------------------
# per-kernel rule tables
# ---------------------------------------------------------------------------
# dims follow kernels.problems.BENCH_DIMS order for each kernel.

KERNEL_RULES: dict[str, tuple[Rule, ...]] = {
    "syr2k": (
        RequiredParams("bi", "bj", "bk"),
        PositiveIntTiles("bi", "bj", "bk"),
        VmemBudget(),
        MxuAlign("bi", "bj", "bk"),
        LcmPadding("bi", "bj", dim_index=0),
        GridBound({"bi": 0, "bj": 0, "bk": 1}),
    ),
    "mm3": (
        RequiredParams("bm", "bn", "bk"),
        PositiveIntTiles("bm", "bn", "bk"),
        VmemBudget(),
        MxuAlign("bm", "bn", "bk"),
        GridBound({"bm": 0, "bn": 4, "bk": 2}),
    ),
    "lu": (
        RequiredParams("bs"),
        PositiveIntTiles("bs", "bm", "bn"),
        VmemBudget(),
        MxuAlign("bs", "bm", "bn"),
        GridBound({"bs": 0}),
    ),
    "heat3d": (
        RequiredParams("bi"),
        PositiveIntTiles("bi"),
        FuseDivides("fuse_t"),
        VmemBudget(),
        GridBound({"bi": 0}, steps=2),
    ),
    "covariance": (
        RequiredParams("bi", "bj", "bk"),
        PositiveIntTiles("bi", "bj", "bk"),
        VmemBudget(),
        MxuAlign("bi", "bj", "bk"),
        LcmPadding("bi", "bj", dim_index=1),
        GridBound({"bi": 1, "bj": 1, "bk": 0}),
    ),
    "floyd_warshall": (
        RequiredParams("bs", "bi", "bj"),
        PositiveIntTiles("bs", "bi", "bj"),
        ChoiceIn("unroll", (1, 2, 4, 8)),
        VmemBudget(),
        MxuAlign("bi", "bj"),
        GridBound({"bs": 0, "bi": 0, "bj": 0}),
    ),
    "flash_attention": (
        ChoiceIn("impl", ("pallas", "xla")),
        PositiveIntTiles("bq", "bk"),
        VmemBudget(),
        MxuAlign("bq", "bk"),
        GridBound({"bq": 1, "bk": 2}),
    ),
    "decode_attention": (
        ChoiceIn("impl", ("pallas", "xla")),
        PositiveIntTiles("bk", "hg", "page"),
        PageDividesSeq("page", dim_index=2),
        VmemBudget(),
        MxuAlign("bk"),
        GridBound({"hg": 0, "bk": 2}),
    ),
    "matmul": (
        PositiveIntTiles("bm", "bn", "bk"),
        VmemBudget(),
        MxuAlign("bm", "bn", "bk"),
        GridBound({"bm": 0, "bk": 1, "bn": 2}),
    ),
}


def kernel_rules(kernel: str) -> tuple[Rule, ...]:
    """The rule tuple for ``kernel`` (empty for unknown kernels)."""
    return KERNEL_RULES.get(kernel, ())


def register_rules(kernel: str, rules: Iterable[Rule],
                   *, replace: bool = False) -> None:
    """Attach feasibility rules to a kernel (tests, third-party kernels).
    Appends to any existing table unless ``replace``."""
    rules = tuple(rules)
    if replace or kernel not in KERNEL_RULES:
        KERNEL_RULES[kernel] = rules
    else:
        KERNEL_RULES[kernel] = KERNEL_RULES[kernel] + rules


def _dims_for(kernel: str, signature, dims) -> tuple | None:
    if dims is not None:
        return tuple(dims)
    if signature is None:
        return None
    from repro.kernels.problems import dims_from_signature

    try:
        return tuple(dims_from_signature(kernel, signature))
    except Exception:
        return None  # runtime signature shapes this table doesn't know


def check_config(
    kernel: str,
    config: Mapping,
    *,
    dims: tuple | None = None,
    signature=None,
    target: str = "host",
) -> Feasibility:
    """Statically judge ``config`` for ``kernel``.

    ``dims`` are the problem dims in :data:`~repro.kernels.problems.BENCH_DIMS`
    order; alternatively pass the store/runtime ``signature`` and the dims
    are recovered via ``dims_from_signature`` (unknown kernels or shapes
    degrade to dimension-independent rules only). Kernels with no
    registered rules are feasible by construction."""
    rules = KERNEL_RULES.get(kernel)
    if not rules:
        return FEASIBLE
    ctx = RuleContext(kernel=kernel, dims=_dims_for(kernel, signature, dims),
                      target=target)
    findings: list[Finding] = []
    for rule in rules:
        findings.extend(rule.check(config, ctx))
    if not findings:
        return FEASIBLE
    return Feasibility(tuple(findings))


def feasibility_filter(
    kernel: str,
    *,
    dims: tuple | None = None,
    signature=None,
    target: str = "host",
) -> Callable[[Mapping], bool] | None:
    """A ``config -> bool`` predicate for the search path (True = keep), or
    ``None`` when the kernel has no rules — callers skip the filtering pass
    entirely in that case. Only errors prune; warnings survive so the
    optimizer can still learn the pathological region is bad."""
    if not KERNEL_RULES.get(kernel):
        return None
    ctx_dims = _dims_for(kernel, signature, dims)

    def accept(cfg: Mapping) -> bool:
        return check_config(kernel, cfg, dims=ctx_dims, target=target).ok

    return accept
