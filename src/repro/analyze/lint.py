"""Pass 2 — the concurrency lint.

An AST-based checker over ``src/repro`` that turns the threading invariants
documented in comments (``dispatch/store.py`` lock-order block,
``dispatch/service.py`` one-lock fast path) into machine-checked rules:

  * **REP101** — ``time.time()`` used in a duration computation
    (``time.time() - t0``). Wall clocks step under NTP; durations must use
    ``time.monotonic()`` / ``time.perf_counter()``. Persisted cross-process
    timestamps legitimately subtract wall-clock values — allowlist those
    sites with a pragma.
  * **REP102** — shared-state mutation outside a lock in a lock-owning
    class. The invariant is self-consistency: an attribute that is ever
    mutated inside a ``with <lock>:`` block must be mutated under the lock
    *everywhere* (``__init__`` and ``*_locked`` caller-holds-lock helpers
    exempt; private helpers whose every call site is already inside a
    locked region inherit that protection).
  * **REP103** — lock-order violation. Lock classes carry ranks
    (``TuningStore`` = 0, ``OpLog`` = 1; the documented order is always
    store → fleet) and acquiring a lower-ranked lock while holding a
    higher-ranked one — directly or through a method call — is flagged.
  * **REP104** — a ``threading.Thread`` started without ``daemon=True``
    and without an enclosing stop/shutdown method: an unowned thread that
    can hang interpreter exit.
  * **REP105** — a broad ``except`` (bare / ``Exception`` /
    ``BaseException``) inside a thread run-loop that neither increments a
    counter nor re-raises. A daemon loop that silently eats its errors
    looks healthy while doing nothing (the SyncAgent anti-entropy swallow
    is the canonical *almost*-instance — it passes because it counts
    per-error-class stats). Handlers in methods a run-loop calls each
    iteration are covered too.

Allowlist pragma (on the flagged line or the line above)::

    x = time.time() - rec.created  # lint: allow=REP101 cross-host wall-clock

Multiple codes: ``# lint: allow=REP101,REP102 <reason>``.

Entry points: :func:`lint_source` (one snippet — test fixtures),
:func:`lint_paths` (files/dirs; builds the cross-module class table first so
REP103 resolves ``self.store.put()`` through ``__init__`` annotations).
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Iterable, Mapping

__all__ = ["LintFinding", "lint_paths", "lint_source", "lint_sources",
           "LOCK_RANKS", "ATTR_TYPES"]

# documented lock order: store locks are acquired before fleet locks, never
# the reverse (dispatch/store.py op-sink contract). Lower rank = acquired
# earlier; REP103 fires on acquiring a lower rank while holding a higher.
LOCK_RANKS: dict[str, int] = {"TuningStore": 0, "OpLog": 1}

# conventional attribute names -> class, used when __init__ gives no
# annotation to resolve `self.<attr>.<method>()` receivers
ATTR_TYPES: dict[str, str] = {
    "store": "TuningStore",
    "oplog": "OpLog",
    "service": "DispatchService",
    "replica": "Replica",
}

# dict/list/set mutators counted as shared-state mutation by REP102
_MUTATORS = frozenset({
    "append", "extend", "insert", "add", "update", "pop", "popitem",
    "clear", "remove", "discard", "setdefault",
})

_THREAD_OWNER_METHODS = frozenset({"stop", "shutdown", "close", "join_all"})

# calls that count as "the error was accounted for" in a run-loop handler
# (REP105): metric/stat increments and bounded error-list appends. Logging
# deliberately does NOT qualify — a log line is not a queryable signal.
_COUNTERISH = frozenset({
    "add", "observe", "inc", "increment", "append", "record", "set_gauge",
})

_PRAGMA_RE = re.compile(r"#\s*lint:\s*allow=([A-Z0-9,]+)")


@dataclass(frozen=True)
class LintFinding:
    code: str
    message: str
    path: str
    line: int

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def to_dict(self) -> dict:
        return {"code": self.code, "message": self.message,
                "path": self.path, "line": self.line}


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------


def _attr_chain(node: ast.AST) -> list[str] | None:
    """``self.store.put`` -> ["self", "store", "put"]; None for anything
    that is not a pure Name/Attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def _annotation_class(node: ast.AST | None) -> str | None:
    """Best-effort class name out of an annotation: handles ``OpLog``,
    ``OpLog | None``, ``Optional[OpLog]``, ``"OpLog"``."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        name = node.value
        for tok in re.split(r"[\[\]|,\s]+", name):
            tok = tok.strip().rsplit(".", 1)[-1]
            if tok and tok not in ("None", "Optional", "Union"):
                return tok
        return None
    if isinstance(node, ast.Name):
        return None if node.id in ("None",) else node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        return _annotation_class(node.left) or _annotation_class(node.right)
    if isinstance(node, ast.Subscript):
        chain = _attr_chain(node.value)
        if chain and chain[-1] in ("Optional", "Union"):
            inner = node.slice
            if isinstance(inner, ast.Tuple):
                for elt in inner.elts:
                    got = _annotation_class(elt)
                    if got:
                        return got
                return None
            return _annotation_class(inner)
    return None


def _is_wallclock_call(node: ast.AST) -> bool:
    """A call to ``time.time`` (or bare ``time()`` from ``from time import
    time``) anywhere in this subtree."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        chain = _attr_chain(sub.func)
        if chain in (["time", "time"], ["time"]):
            return True
    return False


def _is_lockish_item(expr: ast.AST) -> list[str] | None:
    """The attr chain of a with-item that acquires a lock: ``self._lock``,
    ``self._tlock``, ``self._lock()``, ``svc._lock`` — anything whose final
    attribute name contains "lock"."""
    if isinstance(expr, ast.Call):
        expr = expr.func
    chain = _attr_chain(expr)
    if chain and len(chain) >= 2 and "lock" in chain[-1].lower():
        return chain
    return None


def _mutated_self_attr(stmt: ast.stmt) -> tuple[str, int, bool] | None:
    """If ``stmt`` mutates ``self.<attr>`` (item/attr assignment, augmented
    assignment, del, or a mutating method call), return
    (attr, lineno, direct). ``direct`` distinguishes structural mutations
    (assignment/del/augassign — these define an attribute as lock-guarded
    when they appear under a lock) from mutator *method calls*
    (``.append()``/``.update()``/...), which are only ever flagged, never
    used to infer guarding: objects like the obs registry or
    ``threading.Event`` expose thread-safe mutators that legitimately run
    lock-free."""

    def base_attr(node: ast.AST) -> str | None:
        # peel subscripts: self.stats["x"] -> self.stats
        while isinstance(node, ast.Subscript):
            node = node.value
        chain = _attr_chain(node)
        if chain and chain[0] == "self" and len(chain) == 2:
            return chain[1]
        return None

    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            attr = base_attr(tgt)
            if attr is not None:
                return attr, stmt.lineno, True
    elif isinstance(stmt, (ast.AugAssign,)):
        attr = base_attr(stmt.target)
        if attr is not None:
            return attr, stmt.lineno, True
    elif isinstance(stmt, ast.Delete):
        for tgt in stmt.targets:
            attr = base_attr(tgt)
            if attr is not None:
                return attr, stmt.lineno, True
    elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        chain = _attr_chain(stmt.value.func)
        if (chain and chain[0] == "self" and len(chain) == 3
                and chain[2] in _MUTATORS):
            return chain[1], stmt.lineno, False
    return None


# ---------------------------------------------------------------------------
# per-class model
# ---------------------------------------------------------------------------


class ClassModel:
    def __init__(self, node: ast.ClassDef, path: str):
        self.node = node
        self.path = path
        self.name = node.name
        self.rank = LOCK_RANKS.get(node.name)
        self.methods: dict[str, ast.FunctionDef] = {
            n.name: n for n in node.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        self.lock_attrs: set[str] = set()
        self.attr_types: dict[str, str] = {}
        self.has_stop = bool(_THREAD_OWNER_METHODS & self.methods.keys())
        self._scan_init()
        # method name -> set of lock ranks it (transitively, within this
        # class) acquires; filled in by the cross-class pass
        self.acquires: dict[str, set[int]] = {}

    def _scan_init(self) -> None:
        init = self.methods.get("__init__")
        ann: dict[str, str | None] = {}
        if init is not None:
            all_args = list(init.args.posonlyargs) + list(init.args.args) \
                + list(init.args.kwonlyargs)
            for a in all_args:
                ann[a.arg] = _annotation_class(a.annotation)
            for stmt in ast.walk(init):
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    chain = _attr_chain(stmt.targets[0])
                    if not (chain and chain[0] == "self" and len(chain) == 2):
                        continue
                    attr = chain[1]
                    # self._lock = threading.Lock() / RLock() / Condition()
                    if isinstance(stmt.value, ast.Call):
                        vchain = _attr_chain(stmt.value.func)
                        if vchain and vchain[-1] in ("Lock", "RLock",
                                                     "Condition"):
                            self.lock_attrs.add(attr)
                            continue
                    # self.store = store  (param with annotation)
                    vchain = _attr_chain(stmt.value)
                    if vchain and len(vchain) == 1 and ann.get(vchain[0]):
                        self.attr_types[attr] = ann[vchain[0]]
                elif isinstance(stmt, ast.AnnAssign):
                    chain = _attr_chain(stmt.target)
                    if chain and chain[0] == "self" and len(chain) == 2:
                        got = _annotation_class(stmt.annotation)
                        if got:
                            self.attr_types[chain[1]] = got

    def resolve_attr_class(self, attr: str) -> str | None:
        return self.attr_types.get(attr) or ATTR_TYPES.get(attr)


# ---------------------------------------------------------------------------
# the linter
# ---------------------------------------------------------------------------


class Linter:
    def __init__(self) -> None:
        self._modules: list[tuple[str, str, ast.Module]] = []
        self.classes: dict[str, ClassModel] = {}

    def add_source(self, src: str, path: str) -> None:
        tree = ast.parse(src, filename=path)
        self._modules.append((path, src, tree))
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                self.classes[node.name] = ClassModel(node, path)

    # -- cross-class lock summaries (REP103 support) -------------------------

    def _item_rank(self, cm: ClassModel, chain: list[str]) -> int | None:
        """Rank of the lock a with-item chain acquires, if resolvable."""
        if chain[0] == "self":
            if len(chain) == 2:
                return cm.rank
            owner = cm.resolve_attr_class(chain[1])
            if owner is not None:
                target = self.classes.get(owner)
                return target.rank if target else LOCK_RANKS.get(owner)
        return None

    def _compute_acquires(self) -> None:
        """Fixpoint: ranks each method acquires via its own with-items plus
        self-method and typed-attr method calls."""
        for cm in self.classes.values():
            for name in cm.methods:
                cm.acquires[name] = set()
        changed = True
        while changed:
            changed = False
            for cm in self.classes.values():
                for name, fn in cm.methods.items():
                    got = set(cm.acquires[name])
                    for node in ast.walk(fn):
                        if isinstance(node, ast.With):
                            for item in node.items:
                                chain = _is_lockish_item(item.context_expr)
                                if chain:
                                    r = self._item_rank(cm, chain)
                                    if r is not None:
                                        got.add(r)
                        elif isinstance(node, ast.Call):
                            got |= self._call_acquires(cm, node)
                    if got != cm.acquires[name]:
                        cm.acquires[name] = got
                        changed = True

    def _call_acquires(self, cm: ClassModel, call: ast.Call) -> set[int]:
        chain = _attr_chain(call.func)
        if not chain or chain[0] != "self":
            return set()
        if len(chain) == 2:  # self.method()
            return set(cm.acquires.get(chain[1], ()))
        if len(chain) == 3:  # self.attr.method()
            owner = cm.resolve_attr_class(chain[1])
            target = self.classes.get(owner) if owner else None
            if target is not None:
                return set(target.acquires.get(chain[2], ()))
            if owner in LOCK_RANKS:
                # class not in the linted set: assume any method may take
                # its own lock
                return {LOCK_RANKS[owner]}
        return set()

    # -- rule walks ----------------------------------------------------------

    def run(self) -> list[LintFinding]:
        self._compute_acquires()
        findings: list[LintFinding] = []
        for path, src, tree in self._modules:
            raw: list[LintFinding] = []
            raw += self._check_durations(path, tree)
            raw += self._check_threads(path, tree)
            for node in ast.walk(tree):
                if isinstance(node, ast.ClassDef):
                    cm = self.classes[node.name]
                    raw += self._check_guarded_mutations(path, cm)
                    raw += self._check_lock_order(path, cm)
                    raw += self._check_runloop_swallows(path, cm)
            findings += _apply_pragmas(raw, src)
        findings.sort(key=lambda f: (f.path, f.line, f.code))
        return findings

    # REP101 ----------------------------------------------------------------

    def _check_durations(self, path: str, tree: ast.Module) -> list[LintFinding]:
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub) \
                    and (_is_wallclock_call(node.left)
                         or _is_wallclock_call(node.right)):
                out.append(LintFinding(
                    "REP101",
                    "time.time() in a duration computation — use "
                    "time.monotonic()/time.perf_counter() (wall clocks step "
                    "under NTP)",
                    path, node.lineno))
        return out

    # REP102 ----------------------------------------------------------------

    def _guard_contexts(self, cm: ClassModel) -> dict[str, list[tuple[str, int, bool, bool]]]:
        """Per method: [(mutated attr, line, under_lock, direct)]; also
        records self-method call sites as ("()name", line, under_lock,
        False)."""
        out: dict[str, list[tuple[str, int, bool, bool]]] = {}

        def walk(node: ast.AST, depth: int, sink: list) -> None:
            for child in ast.iter_child_nodes(node):
                d = depth
                if isinstance(child, ast.With):
                    if any(_is_lockish_item(i.context_expr)
                           for i in child.items):
                        d = depth + 1
                if isinstance(child, ast.stmt):
                    got = _mutated_self_attr(child)
                    if got is not None:
                        sink.append((got[0], got[1], d > 0, got[2]))
                if isinstance(child, ast.Call):
                    chain = _attr_chain(child.func)
                    if chain and chain[0] == "self" and len(chain) == 2:
                        sink.append(("()" + chain[1], child.lineno, d > 0,
                                     False))
                walk(child, d, sink)

        for name, fn in cm.methods.items():
            sink: list[tuple[str, int, bool, bool]] = []
            walk(fn, 0, sink)
            out[name] = sink
        return out

    def _check_guarded_mutations(self, path: str,
                                 cm: ClassModel) -> list[LintFinding]:
        if not cm.lock_attrs:
            return []
        ctx = self._guard_contexts(cm)
        # private helpers whose every in-class call site is under a lock (or
        # inside another such helper) inherit the caller's lock — fixpoint
        protected: set[str] = {
            n for n in cm.methods
            if n.endswith("_locked") or n == "__init__"
        }
        call_sites: dict[str, list[tuple[str, bool]]] = {n: [] for n in cm.methods}
        for caller, events in ctx.items():
            for attr, _line, locked, _direct in events:
                if attr.startswith("()") and attr[2:] in call_sites:
                    call_sites[attr[2:]].append((caller, locked))
        changed = True
        while changed:
            changed = False
            for name in cm.methods:
                if name in protected or not name.startswith("_") \
                        or name.startswith("__"):
                    continue
                sites = call_sites[name]
                if sites and all(locked or caller in protected
                                 for caller, locked in sites):
                    protected.add(name)
                    changed = True

        # pass 1: which attrs are ever DIRECTLY mutated under a lock?
        # (__init__ is exempt from flagging AND from defining guardedness —
        # construction races with nobody; mutator method calls never define
        # guardedness either, see _mutated_self_attr)
        guarded: set[str] = set()
        for method, events in ctx.items():
            if method == "__init__":
                continue
            for attr, _line, locked, direct in events:
                if not attr.startswith("()") and direct \
                        and (locked or method in protected):
                    guarded.add(attr)
        # pass 2: flag unguarded mutations of those attrs
        out = []
        for method, events in ctx.items():
            if method == "__init__" or method in protected:
                continue
            for attr, line, locked, _direct in events:
                if attr.startswith("()") or locked or attr not in guarded:
                    continue
                out.append(LintFinding(
                    "REP102",
                    f"{cm.name}.{method} mutates self.{attr} outside "
                    f"`with <lock>` but the attribute is lock-guarded "
                    f"elsewhere in the class",
                    path, line))
        return out

    # REP103 ----------------------------------------------------------------

    def _check_lock_order(self, path: str, cm: ClassModel) -> list[LintFinding]:
        out = []

        def walk(node: ast.AST, held: tuple[int, ...]) -> None:
            for child in ast.iter_child_nodes(node):
                h = held
                if isinstance(child, ast.With):
                    for item in child.items:
                        chain = _is_lockish_item(item.context_expr)
                        if not chain:
                            continue
                        r = self._item_rank(cm, chain)
                        if r is None:
                            continue
                        if held and r < max(held):
                            out.append(LintFinding(
                                "REP103",
                                f"acquires rank-{r} lock "
                                f"({'.'.join(chain)}) while holding a "
                                f"rank-{max(held)} lock — documented order "
                                f"is store → fleet",
                                path, child.lineno))
                        h = h + (r,)
                elif isinstance(child, ast.Call) and held:
                    acquired = self._call_acquires(cm, child)
                    bad = {r for r in acquired if r < max(held)}
                    if bad:
                        chain = _attr_chain(child.func) or ["<call>"]
                        out.append(LintFinding(
                            "REP103",
                            f"call {'.'.join(chain)}() acquires a "
                            f"rank-{min(bad)} lock while a rank-"
                            f"{max(held)} lock is held — documented order "
                            f"is store → fleet",
                            path, child.lineno))
                walk(child, h)

        for fn in cm.methods.values():
            walk(fn, ())
        return out

    # REP105 ----------------------------------------------------------------

    def _check_runloop_swallows(self, path: str,
                                cm: ClassModel) -> list[LintFinding]:
        """Broad excepts in thread run-loops that swallow without counting.

        Run-loop roots are methods handed to ``threading.Thread(target=
        self.X)`` (plus ``run``/``_run`` in any Thread-constructing class).
        A broad handler is flagged when it sits lexically inside a loop of
        a root, or anywhere in a method the loop body calls (transitively,
        within the class) — those handlers run every iteration — unless its
        body re-raises or increments a counter/error list."""
        roots: set[str] = set()
        constructs_thread = False
        for fn in cm.methods.values():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = _attr_chain(node.func)
                if chain not in (["threading", "Thread"], ["Thread"]):
                    continue
                constructs_thread = True
                for kw in node.keywords:
                    if kw.arg == "target":
                        tchain = _attr_chain(kw.value)
                        if tchain and tchain[0] == "self" and len(tchain) == 2:
                            roots.add(tchain[1])
        if constructs_thread:
            roots |= {n for n in ("run", "_run") if n in cm.methods}
        roots &= cm.methods.keys()
        if not roots:
            return []

        def self_calls(node: ast.AST) -> set[str]:
            got = set()
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    chain = _attr_chain(sub.func)
                    if chain and chain[0] == "self" and len(chain) == 2:
                        got.add(chain[1])
            return got

        # methods whose handlers effectively run once per loop iteration
        frontier: set[str] = set()
        for r in roots:
            for node in ast.walk(cm.methods[r]):
                if isinstance(node, (ast.While, ast.For)):
                    frontier |= self_calls(node)
        loop_reachable: set[str] = set()
        while frontier:
            name = frontier.pop()
            if name in loop_reachable or name not in cm.methods \
                    or name in roots:
                continue
            loop_reachable.add(name)
            frontier |= self_calls(cm.methods[name])

        def broad(handler: ast.ExceptHandler) -> bool:
            if handler.type is None:
                return True
            elts = (handler.type.elts
                    if isinstance(handler.type, ast.Tuple) else [handler.type])
            for e in elts:
                chain = _attr_chain(e)
                if chain and chain[-1] in ("Exception", "BaseException"):
                    return True
            return False

        def accounted(handler: ast.ExceptHandler) -> bool:
            for node in ast.walk(handler):
                if isinstance(node, (ast.Raise, ast.AugAssign)):
                    return True
                if isinstance(node, ast.Call):
                    chain = _attr_chain(node.func)
                    if chain and chain[-1] in _COUNTERISH:
                        return True
            return False

        suspect_handlers: list[ast.ExceptHandler] = []
        for r in roots:
            for node in ast.walk(cm.methods[r]):
                if isinstance(node, (ast.While, ast.For)):
                    suspect_handlers += [
                        h for h in ast.walk(node)
                        if isinstance(h, ast.ExceptHandler)]
        for name in loop_reachable:
            suspect_handlers += [
                h for h in ast.walk(cm.methods[name])
                if isinstance(h, ast.ExceptHandler)]
        out, seen = [], set()
        for h in suspect_handlers:
            if h.lineno in seen or not broad(h) or accounted(h):
                continue
            seen.add(h.lineno)
            out.append(LintFinding(
                "REP105",
                f"{cm.name}: broad except in a thread run-loop swallows "
                f"errors without incrementing a counter or re-raising — a "
                f"silently failing daemon looks healthy while doing nothing",
                path, h.lineno))
        return out

    # REP104 ----------------------------------------------------------------

    def _check_threads(self, path: str, tree: ast.Module) -> list[LintFinding]:
        out = []
        # class bodies whose methods include a stop/shutdown handler
        owners: list[tuple[ast.ClassDef, bool]] = [
            (n, bool(_THREAD_OWNER_METHODS
                     & {m.name for m in n.body
                        if isinstance(m, ast.FunctionDef)}))
            for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
        ]

        def enclosing_has_stop(lineno: int) -> bool:
            for cls, has in owners:
                if cls.lineno <= lineno <= (cls.end_lineno or cls.lineno):
                    return has
            return False

        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain not in (["threading", "Thread"], ["Thread"]):
                continue
            daemon = any(
                kw.arg == "daemon" and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
                for kw in node.keywords)
            if not daemon and not enclosing_has_stop(node.lineno):
                out.append(LintFinding(
                    "REP104",
                    "threading.Thread without daemon=True and no "
                    "stop/shutdown handler on the owning class — the thread "
                    "can outlive (and hang) interpreter exit",
                    path, node.lineno))
        return out


def _apply_pragmas(findings: Iterable[LintFinding],
                   src: str) -> list[LintFinding]:
    lines = src.splitlines()

    def allowed(f: LintFinding) -> bool:
        for ln in (f.line, f.line - 1):
            if 1 <= ln <= len(lines):
                m = _PRAGMA_RE.search(lines[ln - 1])
                if m and f.code in m.group(1).split(","):
                    return True
        return False

    return [f for f in findings if not allowed(f)]


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def lint_sources(sources: Mapping[str, str]) -> list[LintFinding]:
    """Lint a {path: source} mapping as one program (cross-module class
    resolution included)."""
    linter = Linter()
    for path, src in sources.items():
        linter.add_source(src, path)
    return linter.run()


def lint_source(src: str, path: str = "<src>") -> list[LintFinding]:
    """Lint one source snippet — the test-fixture entry point."""
    return lint_sources({path: src})


def _iter_py_files(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        elif p.endswith(".py"):
            yield p


def lint_paths(paths: Iterable[str]) -> list[LintFinding]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    sources: dict[str, str] = {}
    for fp in _iter_py_files(paths):
        with open(fp, "r", encoding="utf-8") as fh:
            sources[fp] = fh.read()
    return lint_sources(sources)
