"""Fleet transports: how op streams move between hosts.

The contract (:class:`Transport`) is three idempotent methods over an
:class:`~repro.fleet.oplog.OpLog`:

* ``push(oplog) -> int``   — make locally-known ops durable/visible to
  peers; safe to call repeatedly (re-pushing already-visible ops is a
  no-op, the high-water mark is re-derived from the medium itself);
* ``pull(oplog) -> [Op]``  — fetch ops this host may not have yet; final
  deduplication always happens at ``oplog.ingest`` by version vector, so a
  transport may over-deliver but must preserve per-host seq order;
* ``pending(oplog) -> int``— replication lag: locally-known ops not yet
  visible through this transport (the ``repro-fleet status`` metric).

:class:`FileTransport` is the shared-directory / object-store-style
instance: one append-only object per host, ``<root>/<host>.ops.jsonl``,
written ONLY by its owner. Single-writer objects need no cross-host
locking and map 1:1 onto append-or-replace object stores (the listed
follow-on). The localhost HTTP pair lives in :mod:`repro.fleet.http`.
"""

from __future__ import annotations

import os

from repro.core.jsonl import append_jsonl, iter_jsonl_tail, repair_torn_tail
from repro.fleet.oplog import Op, OpLog

__all__ = ["Transport", "FileTransport", "transport_from_spec"]


class Transport:
    """Protocol base; see module docstring for the contract."""

    def push(self, oplog: OpLog) -> int:
        raise NotImplementedError

    def pull(self, oplog: OpLog) -> list[Op]:
        raise NotImplementedError

    def pending(self, oplog: OpLog) -> int:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


class FileTransport(Transport):
    """Shared-directory transport (object-store idiom: single-writer
    append-only objects; readers re-scan and filter by version vector)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._push_cache: dict[str, tuple[int, int]] = {}  # path -> (size, seq)

    def describe(self) -> str:
        return f"file:{self.root}"

    def _own_path(self, oplog: OpLog) -> str:
        return os.path.join(self.root, f"{oplog.host_id}.ops.jsonl")

    def _published_seq(self, path: str) -> int:
        """Durable high-water mark, re-derived from the object itself so a
        restarted host never double-publishes (ops in the own file are in
        seq order: the last complete line carries the max)."""
        try:
            size = os.path.getsize(path)
        except OSError:
            return 0
        cached = self._push_cache.get(path)
        if cached is not None and cached[0] == size:
            return cached[1]
        seq = 0
        for d, _ in iter_jsonl_tail(path, 0):
            try:
                seq = max(seq, int(d["op"]["seq"]))
            except (TypeError, KeyError, ValueError):
                continue
        self._push_cache[path] = (size, seq)
        return seq

    def push(self, oplog: OpLog) -> int:
        path = self._own_path(oplog)
        repair_torn_tail(path)  # single writer: our own crashed append
        ops = oplog.own_ops_after(self._published_seq(path))
        for op in ops:
            append_jsonl(path, op.to_json())
        if ops:
            self._push_cache[path] = (os.path.getsize(path), ops[-1].seq)
        return len(ops)

    def pull(self, oplog: OpLog) -> list[Op]:
        """Ops from every other host's object not covered by the oplog's
        version vector. Deliberately stateless: coverage is judged against
        the durably-advanced vv, never an in-memory cursor, so a pull whose
        ingest later fails (disk full, crash mid-cycle) is simply
        re-delivered next cycle instead of being lost for the process's
        lifetime. Unparseable lines are skipped, not fatal — a newer peer's
        unknown op kinds must not wedge replication of its valid ops."""
        out: list[Op] = []
        vv = oplog.version_vector()
        own = os.path.basename(self._own_path(oplog))
        try:
            names = sorted(os.listdir(self.root))
        except OSError:
            return out
        for name in names:
            if not name.endswith(".ops.jsonl") or name == own:
                continue
            try:
                for d, _ in iter_jsonl_tail(os.path.join(self.root, name), 0):
                    if d is None:
                        continue
                    try:
                        op = Op.from_json(d)
                    except (KeyError, ValueError):
                        continue
                    if op.seq > vv.get(op.host, 0):
                        out.append(op)
            except OSError:
                continue
        return out

    def pending(self, oplog: OpLog) -> int:
        return len(oplog.own_ops_after(self._published_seq(self._own_path(oplog))))


def transport_from_spec(spec: str) -> Transport:
    """``file:<dir>`` or ``http(s)://host:port`` — the CLI/config syntax."""
    if spec.startswith("file:"):
        return FileTransport(spec[len("file:"):])
    if spec.startswith(("http://", "https://")):
        from repro.fleet.http import HttpTransport

        return HttpTransport(spec)
    raise ValueError(
        f"unknown transport spec {spec!r} (expected file:<dir> or http://...)")
