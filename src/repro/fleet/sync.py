"""Replica binding + the anti-entropy SyncAgent.

:class:`Replica` ties one :class:`~repro.dispatch.store.TuningStore` to its
:class:`~repro.fleet.oplog.OpLog`: local store mutations emit ops (stamped
while the store lock is held, so op order matches application order — lock
order is always store → fleet, and ingestion releases the oplog locks
before touching the store), replicated ops fold back into the store through the
deterministic merge, and an attached
:class:`~repro.dispatch.service.DispatchService` gets its compiled
executables invalidated whenever replication changes what the store serves
— a better config tuned anywhere in the fleet hot-swaps in here.

:class:`SyncAgent` is the anti-entropy daemon (a thread, like
:class:`~repro.dispatch.background.BackgroundTuner`): every
``interval_sec`` — or immediately after :meth:`~SyncAgent.nudge`, which the
dispatch service fires when a background campaign publishes — it pulls
remote deltas, merges them, and pushes local ones. Transport failures
(peer down, shared dir unmounted) are counted, never raised: serving
continues on local state and the next cycle retries.
"""

from __future__ import annotations

import os
import random
import threading
import time
from typing import Iterable

from repro.dispatch.signature import parse_signature_key
from repro.dispatch.store import TuningStore
from repro.fleet.oplog import Op, OpLog
from repro.fleet.transport import Transport
from repro.guard.faults import fault_point
from repro.obs.metrics import get_registry, summarize_histograms
from repro.obs.trace import span as obs_span

__all__ = ["Replica", "SyncAgent"]


class Replica:
    def __init__(
        self,
        store: TuningStore,
        *,
        oplog: OpLog | None = None,
        service=None,
    ):
        self.store = store
        self.oplog = oplog or OpLog(os.path.join(store.path, "fleet"))
        self.service = service
        store.set_op_sink(self.oplog.emit)
        # records that predate fleet attachment still need ops, or peers
        # would never learn this host's previously tuned configs
        for rec in store.records():
            self.oplog.ensure_put(rec)
        # ...and an oplog that predates this store view (restart, wiped
        # store dir, crash between durable ingest and store application)
        # folds its merged winners and bans straight back in
        self.reconcile(self.oplog.merge_keys())

    @property
    def host_id(self) -> str:
        return self.oplog.host_id

    # -- merge application -------------------------------------------------------

    def ingest(self, ops: Iterable[Op]) -> int:
        """Fold replicated ops into the oplog, then reconcile the store
        against the merge. Returns the number of store-visible changes.

        Reconciliation deliberately covers *every* merge-state key, not just
        the freshly ingested ones: locally-originated ops (a quarantine, a
        compaction eviction) can flip a key's merge winner to a put that
        only ever existed in the oplog, and the next cycle must fold that
        winner into the store even when the transport delivered nothing."""
        self.oplog.ingest(ops)
        return self.reconcile(self.oplog.merge_keys())

    def reconcile(self, keys: Iterable[tuple]) -> int:
        """Drive the store to the merge state for ``keys``: apply the
        merge's quarantines, evict local bests that are dead in the merge,
        and put merge winners that beat (or replace) the local record.
        Every change invalidates the attached service's executables for
        that signature so the next dispatch serves the fleet's best.

        Quarantines are re-derived from the merge state (not from freshly
        delivered ops): version-vector dedup means a quarantine op is
        delivered exactly once, so a crash between its durable oplog append
        and the store application must not lose the ban — replaying
        reconciliation heals it."""
        changed = 0
        for key in keys:
            kernel, sig_key, backend = key
            sig = parse_signature_key(sig_key)
            for qop in self.oplog.key_quarantines(key):
                if self.store.is_quarantined(qop.record):
                    continue  # cheap in-memory check before the flocked apply
                if self.store.apply_remote("quarantine", qop.record):
                    changed += 1
                    self._invalidate(qop.record)
            win = self.oplog.winner(key)
            cur = self.store.peek(kernel, sig, backend)
            if win is None:
                # every put for this key is tombstoned or quarantined
                if cur is not None and self.store.apply_remote("evict", cur):
                    changed += 1
                    self._invalidate(cur)
                continue
            wrec = win.record
            if cur is not None and wrec.objective >= cur.objective \
                    and (cur.config != wrec.config
                         or wrec.objective > cur.objective):
                # the local record lost the merge without being beaten on
                # objective — its op was evicted/quarantined fleet-wide (the
                # same config may even have been legitimately re-tuned to a
                # slower, newer measurement), or it tied and the stamp order
                # picked the other config; evict it so the winner lands
                if self.store.apply_remote("evict", cur):
                    cur = None
            if (cur is None or wrec.objective < cur.objective) \
                    and self.store.apply_remote("put", wrec):
                changed += 1
                self._invalidate(wrec)
        if changed and self.service is not None:
            with self.service._lock:
                self.service.stats["sync_applied"] += changed
        return changed

    def _invalidate(self, rec) -> None:
        if self.service is not None:
            self.service.invalidate(rec.kernel, rec.signature)

    # -- telemetry ---------------------------------------------------------------

    def status(self, transport: Transport | None = None) -> dict:
        self.store.refresh()
        self.oplog.refresh()
        last = self.oplog.last_sync()
        quarantines = self.store.quarantines()
        out = {
            "host": self.host_id,
            "records": len(self.store),
            "ops": len(self.oplog),
            # quarantine tombstones with their machine-readable reasons
            # (build_failed, or repro.analyze feasibility codes); replicated
            # bans carry an empty reason — reasons are host-local
            "quarantined": [
                {"kernel": q["kernel"], "signature": q["signature"],
                 "backend": q["backend"], "reason": q["reason"]}
                for q in quarantines
            ],
            "clock": self.oplog._clock,
            "version_vector": self.oplog.version_vector(),
            "last_sync_age_sec": (  # lint: allow=REP101 oplog sync stamps are cross-process wall-clock
                round(time.time() - last["time"], 3) if last else None),
            "last_sync": last,
        }
        if transport is not None:
            out["transport"] = transport.describe()
            out["ops_pending"] = transport.pending(self.oplog)
        # sync-duration + replication-lag histograms (count/p50/p99) from
        # this process's obs registry — populated by any SyncAgent cycles run
        # here (the `serve --interval` daemon, or a one-shot `sync`); empty
        # for a process that has not synced
        snap = get_registry().snapshot()
        out["obs"] = summarize_histograms(snap, prefix="fleet_")
        # per-error-class transport failure counts and guard (drift/shadow)
        # counters: `repro-fleet status` shows *why* sync is failing and
        # what the resilience layer has been doing, not just that it ran
        out["counters"] = {}
        for c in snap.get("counters", []):
            name = c["name"]
            if name == "fleet_transport_errors":
                kind = c["labels"].get("kind", "")
                row = out["counters"].setdefault(name, {})
                row[kind] = row.get(kind, 0) + int(c["value"])
            elif name.startswith("guard_"):
                out["counters"][name] = (
                    out["counters"].get(name, 0) + int(c["value"]))
        return out


class SyncAgent:
    """Periodic push/pull of op deltas between this replica and its
    transport; see module docstring."""

    def __init__(
        self,
        replica: Replica,
        transport: Transport,
        *,
        interval_sec: float = 30.0,
        max_errors: int = 20,
        max_backoff_sec: float | None = None,
        backoff_jitter: float = 0.25,
        rng=None,
    ):
        self.replica = replica
        self.transport = transport
        self.interval_sec = interval_sec
        # consecutive transport failures back the loop off exponentially
        # (doubling per failure, capped, with multiplicative jitter so a
        # fleet of replicas behind one dead peer doesn't retry in lockstep)
        # instead of hammering a dead peer every interval
        self.max_backoff_sec = (max_backoff_sec if max_backoff_sec is not None
                                else interval_sec * 32)
        self.backoff_jitter = backoff_jitter
        self._rng = rng if rng is not None else random.Random()
        # per-cycle pull/merge/push durations accumulate here (flat view)
        # and into the obs registry's fleet_{pull,merge,push,cycle}_seconds
        # histograms, labeled by host
        self.stats = {"cycles": 0, "sync_applied": 0, "sync_published": 0,
                      "sync_errors": 0, "ops_pending": 0, "last_sync": 0.0,
                      "pull_sec": 0.0, "merge_sec": 0.0, "push_sec": 0.0,
                      # error-class -> count, e.g. {"ConnectionError": 4}:
                      # *why* sync is failing, not just that it is
                      "transport_errors": {},
                      "consecutive_failures": 0, "backoff_sec": 0.0}
        # monotonic companion to stats["last_sync"] (which stays wall-clock
        # for display): in-process age/lag math must not step under NTP
        self._last_sync_mono = 0.0
        self.errors: list[BaseException] = []
        self._max_errors = max_errors
        self._wake = threading.Event()
        self._stopping = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        if replica.service is not None:
            replica.service.attach_sync(self)

    # -- one anti-entropy cycle --------------------------------------------------

    def sync_once(self) -> dict:
        applied = published = pending = 0
        pull_sec = merge_sec = push_sec = 0.0
        host = self.replica.host_id
        registry = get_registry()
        with self._lock:
            last_mono = self._last_sync_mono
        if last_mono:
            # replication lag proxy: how stale this replica was when the
            # cycle started (time since its previous successful sync)
            registry.observe("fleet_replication_lag_seconds",
                             time.monotonic() - last_mono, host=host)
        t_cycle = time.perf_counter()
        try:
            t0 = time.perf_counter()
            with obs_span("fleet.pull", host=host):
                fault_point("transport.partition", op="pull", host=host)
                fault_point("transport.flake", op="pull", host=host)
                pulled = self.transport.pull(self.replica.oplog)
            pull_sec = time.perf_counter() - t0
            t0 = time.perf_counter()
            with obs_span("fleet.merge", host=host, ops=len(pulled)):
                applied = self.replica.ingest(pulled)
            merge_sec = time.perf_counter() - t0
            t0 = time.perf_counter()
            with obs_span("fleet.push", host=host):
                fault_point("transport.partition", op="push", host=host)
                fault_point("transport.flake", op="push", host=host)
                published = self.transport.push(self.replica.oplog)
            push_sec = time.perf_counter() - t0
            pending = self.transport.pending(self.replica.oplog)
            self.replica.oplog.note_sync(
                applied=applied, published=published, pending=pending)
        except Exception as e:  # noqa: BLE001 — anti-entropy must outlive peers
            self._record_durations(registry, host, pull_sec, merge_sec,
                                   push_sec, time.perf_counter() - t_cycle)
            kind = type(e).__name__
            registry.add("fleet_transport_errors", kind=kind, host=host)
            with self._lock:
                self.stats["sync_errors"] += 1
                errs = self.stats["transport_errors"]
                errs[kind] = errs.get(kind, 0) + 1
                self.stats["consecutive_failures"] += 1
                self.errors.append(e)
                del self.errors[:-self._max_errors]
            return {"applied": applied, "published": published,
                    "pending": pending, "error": repr(e)}
        self._record_durations(registry, host, pull_sec, merge_sec, push_sec,
                               time.perf_counter() - t_cycle)
        registry.set_gauge("fleet_ops_pending", pending, host=host)
        with self._lock:
            self.stats["cycles"] += 1
            self.stats["sync_applied"] += applied
            self.stats["sync_published"] += published
            self.stats["ops_pending"] = pending
            self.stats["consecutive_failures"] = 0
            self.stats["backoff_sec"] = 0.0
            self.stats["last_sync"] = time.time()  # wall-clock, display only
            self._last_sync_mono = time.monotonic()
            self.stats["pull_sec"] += pull_sec
            self.stats["merge_sec"] += merge_sec
            self.stats["push_sec"] += push_sec
        svc = self.replica.service
        if svc is not None and published:
            with svc._lock:
                svc.stats["sync_published"] += published
        # the returned dict keeps its pre-obs shape (callers compare it
        # exactly); per-cycle durations live in self.stats and the registry
        return {"applied": applied, "published": published, "pending": pending}

    @staticmethod
    def _record_durations(registry, host, pull_sec, merge_sec, push_sec,
                          cycle_sec) -> None:
        """Feed one cycle's phase durations into the obs histograms. Runs on
        the error path too — a cycle that dies mid-push still accounts for
        the pull/merge time it spent."""
        registry.observe("fleet_pull_seconds", pull_sec, host=host)
        registry.observe("fleet_merge_seconds", merge_sec, host=host)
        registry.observe("fleet_push_seconds", push_sec, host=host)
        registry.observe("fleet_cycle_seconds", cycle_sec, host=host)

    def lag(self) -> dict:
        """Replication-lag view merged into ``DispatchService.telemetry()``."""
        with self._lock:
            last_mono = self._last_sync_mono
            return {
                "sync_ops_pending": self.stats["ops_pending"],
                "sync_last_age_sec": (
                    round(time.monotonic() - last_mono, 3)
                    if last_mono else float("inf")),
                "sync_errors": self.stats["sync_errors"],
                "sync_transport_errors": dict(self.stats["transport_errors"]),
                "sync_consecutive_failures": self.stats["consecutive_failures"],
                "sync_backoff_sec": self.stats["backoff_sec"],
            }

    def _backoff_delay(self, consecutive_failures: int) -> float:
        """Next wait after ``consecutive_failures`` straight failed cycles:
        exponential (doubling) from ``interval_sec``, capped at
        ``max_backoff_sec``, with up to ``backoff_jitter`` multiplicative
        jitter to de-synchronize a fleet retrying one dead peer."""
        if consecutive_failures <= 0:
            return self.interval_sec
        base = min(self.interval_sec * (2.0 ** min(consecutive_failures - 1, 16)),
                   self.max_backoff_sec)
        return base * (1.0 + self.backoff_jitter * self._rng.random())

    # -- lifecycle ---------------------------------------------------------------

    def nudge(self) -> None:
        """Wake the loop now (e.g. a background campaign just published a
        better config — push it fleet-wide without waiting a full interval)."""
        self._wake.set()

    def start(self) -> "SyncAgent":
        if self._thread is None or not self._thread.is_alive():
            self._stopping.clear()
            self._thread = threading.Thread(
                target=self._run, name="repro-fleet-sync", daemon=True)
            self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stopping.is_set():
            out = self.sync_once()
            if "error" in out:
                with self._lock:
                    failures = self.stats["consecutive_failures"]
                delay = self._backoff_delay(failures)
                with self._lock:
                    self.stats["backoff_sec"] = delay
            else:
                delay = self.interval_sec
            # a nudge() still wakes a backed-off loop immediately: local
            # publishes should not wait out a dead peer's backoff window
            self._wake.wait(delay)
            self._wake.clear()

    def stop(self, wait: bool = True) -> None:
        self._stopping.set()
        self._wake.set()
        if wait and self._thread is not None:
            self._thread.join(timeout=30)
