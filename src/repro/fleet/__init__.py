"""repro.fleet — cross-host TuningStore replication.

The distribution layer over :mod:`repro.dispatch`: every store mutation
becomes a stamped op in an append-only per-host oplog, transports move op
deltas between hosts (shared-directory/object-store files, or a localhost
HTTP push/pull pair), and an anti-entropy :class:`SyncAgent` periodically
merges remote ops back into the live store — deterministically (lowest
objective wins per key, quarantine/tombstone aware, idempotent under
re-application) — and invalidates the dispatch service's compiled
executables so better fleet configs hot-swap into serving.

    from repro import dispatch, fleet
    svc = dispatch.configure("results/store")
    rep = fleet.Replica(svc.store, service=svc)
    agent = fleet.SyncAgent(rep, fleet.FileTransport("/mnt/shared/fleet"),
                            interval_sec=30).start()
    # one host's 200-eval campaign is now every host's warm start

See README "repro.fleet" for the on-disk oplog layout, the transport
contract, and the convergence guarantees.
"""

from repro.fleet.oplog import OP_KINDS, MergeState, Op, OpLog
from repro.fleet.sync import Replica, SyncAgent
from repro.fleet.transport import FileTransport, Transport, transport_from_spec

__all__ = [
    "OP_KINDS",
    "FileTransport",
    "FleetServer",
    "HttpTransport",
    "MergeState",
    "Op",
    "OpLog",
    "Replica",
    "SyncAgent",
    "Transport",
    "transport_from_spec",
]


def __getattr__(name):
    # http.server machinery loads lazily: most fleets use the file transport
    if name in ("FleetServer", "HttpTransport"):
        from repro.fleet import http as _http

        return getattr(_http, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
