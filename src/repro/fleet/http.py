"""Localhost HTTP push/pull transport, built on stdlib ``http.server`` so
tests and single-machine fleets need no extra dependencies.

One side runs a :class:`FleetServer` wrapping its :class:`Replica`; peers
point an :class:`HttpTransport` at it:

* ``GET /vv``            → the server's version vector (JSON);
* ``GET /ops?vv=<json>`` → JSONL of every op the server knows that the
  vector does not cover (own *and* replicated, so ops propagate
  transitively through any reachable peer);
* ``POST /ops``          → JSONL body of ops the client pushes; the server
  ingests them through its replica (merge + store fold + service
  invalidation) and answers ``{"applied": n}``;
* ``GET /status``        → the replica's status dict;
* ``GET /metrics``       → this process's obs registry in Prometheus text
  form (sync-duration/replication-lag histograms and any other metrics the
  serving process records).

``push`` asks the peer for its vector first and ships only the delta, so
re-pushing after a restart is a no-op — the same idempotence contract as
the file transport, with the high-water mark held by the peer.
"""

from __future__ import annotations

import json
import threading
import urllib.parse
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.fleet.oplog import Op, OpLog
from repro.fleet.sync import Replica
from repro.fleet.transport import Transport

__all__ = ["FleetServer", "HttpTransport"]


def _ops_to_jsonl(ops) -> bytes:
    return "".join(json.dumps(op.to_json()) + "\n" for op in ops).encode()


def _ops_from_jsonl(data: bytes) -> list[Op]:
    # per-line tolerance, like the file transport: one foreign op (say, a
    # kind from a newer release) must not wedge every valid op in the batch
    out = []
    for line in data.decode(errors="replace").splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(Op.from_json(json.loads(line)))
        except (json.JSONDecodeError, KeyError, ValueError):
            continue
    return out


class _Handler(BaseHTTPRequestHandler):
    replica: Replica  # bound by FleetServer via subclassing

    def log_message(self, *args):  # quiet: serving paths must not spam stderr
        pass

    def _send(self, code: int, body: bytes, ctype: str = "application/json") -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):  # noqa: N802 — http.server API
        url = urllib.parse.urlparse(self.path)
        oplog = self.replica.oplog
        if url.path == "/vv":
            oplog.refresh()
            self._send(200, json.dumps(oplog.version_vector()).encode())
        elif url.path == "/ops":
            q = urllib.parse.parse_qs(url.query)
            try:
                vv = json.loads(q.get("vv", ["{}"])[0])
            except json.JSONDecodeError:
                self._send(400, b'{"error": "bad vv"}')
                return
            oplog.refresh()
            self._send(200, _ops_to_jsonl(oplog.ops_after(vv)),
                       ctype="application/jsonl")
        elif url.path == "/status":
            self._send(200, json.dumps(self.replica.status()).encode())
        elif url.path == "/metrics":
            from repro.obs.export import prometheus_text

            self._send(200, prometheus_text().encode(),
                       ctype="text/plain; version=0.0.4; charset=utf-8")
        else:
            self._send(404, b'{"error": "not found"}')

    def do_POST(self):  # noqa: N802
        if urllib.parse.urlparse(self.path).path != "/ops":
            self._send(404, b'{"error": "not found"}')
            return
        length = int(self.headers.get("Content-Length", 0))
        ops = _ops_from_jsonl(self.rfile.read(length))
        applied = self.replica.ingest(ops)
        self._send(200, json.dumps({"applied": applied,
                                    "received": len(ops)}).encode())


class FleetServer:
    """Threaded HTTP endpoint for one replica; ``port=0`` picks a free port
    (read it back from ``.port``)."""

    def __init__(self, replica: Replica, host: str = "127.0.0.1", port: int = 0):
        handler = type("BoundFleetHandler", (_Handler,), {"replica": replica})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "FleetServer":
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="repro-fleet-http",
                daemon=True)
            self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._httpd.serve_forever()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)


class HttpTransport(Transport):
    def __init__(self, url: str, timeout: float = 10.0):
        self.url = url.rstrip("/")
        self.timeout = timeout

    def describe(self) -> str:
        return self.url

    def _get(self, path: str) -> bytes:
        with urllib.request.urlopen(self.url + path, timeout=self.timeout) as r:
            return r.read()

    def _remote_vv(self) -> dict:
        return json.loads(self._get("/vv"))

    def push(self, oplog: OpLog) -> int:
        ops = oplog.ops_after(self._remote_vv())
        if not ops:
            return 0
        req = urllib.request.Request(
            self.url + "/ops", data=_ops_to_jsonl(ops),
            headers={"Content-Type": "application/jsonl"}, method="POST")
        with urllib.request.urlopen(req, timeout=self.timeout) as r:
            json.loads(r.read())  # surface malformed replies as errors
        return len(ops)

    def pull(self, oplog: OpLog) -> list[Op]:
        vv = urllib.parse.quote(json.dumps(oplog.version_vector()))
        return _ops_from_jsonl(self._get(f"/ops?vv={vv}"))

    def pending(self, oplog: OpLog) -> int:
        return len(oplog.ops_after(self._remote_vv()))

    def status(self) -> dict:
        """The peer's own status dict (including its ``obs`` histogram
        summaries) — `repro-fleet status --transport http://...` shows the
        serving process's numbers, not just this client's."""
        return json.loads(self._get("/status"))
