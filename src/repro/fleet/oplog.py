"""The replication oplog: per-host operation streams + deterministic merge.

Every local :class:`~repro.dispatch.store.TuningStore` mutation becomes an
**op** — ``put`` / ``quarantine`` / ``evict`` — stamped with the emitting
host's id, a per-host monotonic sequence number, and a Lamport clock:

* the ``(host, seq)`` pair is the replication cursor: a **version vector**
  ``{host: max seq}`` describes exactly which ops a replica already holds,
  so transports ship deltas and re-delivery is a no-op;
* the ``(clock, host, seq)`` triple is a total order (the *stamp*) used by
  the merge to decide causality questions — most importantly whether a
  ``put`` happened before or after an ``evict`` tombstone for its key.

On disk (``<store>/fleet/``):

* ``host``      — this host's stable id, created once;
* ``log.jsonl`` — every op known to this host (own and replicated), in
  local application order, guarded by ``fleet.lock`` (flock) so several
  processes on one host can share the log the way they share the store;
* ``sync.json`` — timestamp + counters of the last anti-entropy cycle
  (telemetry only, written atomically).

Merge semantics (:class:`MergeState`) are a pure function of the op *set*:
applying any interleaving of the same ops — or re-applying a stream twice —
converges to identical winners. Per key, the **lowest objective wins** among
puts that survive quarantine (permanent, per exact config) and eviction
(a put is dead iff its stamp is ≤ the key's newest evict stamp — so a
tombstone kills everything it causally saw, while a genuinely newer tuning
result legitimately resurrects the key). Commutativity under eviction
requires remembering more than the current winner: we keep each key's
*undominated frontier* of puts — ``e`` permanently shadows ``p`` only when
``e`` wins selection (lower objective), survives every eviction ``p``
survives (newer stamp), AND dies with ``p`` under quarantine (same config).
The frontier holds at most one shadowed-out entry per distinct config.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
import uuid
from typing import Iterable, Iterator, Mapping

try:
    import fcntl
except ImportError:  # non-POSIX: single-process best effort
    fcntl = None

from repro.core.jsonl import append_jsonl, iter_jsonl_tail, repair_torn_tail
from repro.core.space import config_key
from repro.dispatch.store import TuningRecord

__all__ = ["Op", "OpLog", "MergeState", "OP_KINDS"]

OP_KINDS = ("put", "quarantine", "evict")


@dataclasses.dataclass(frozen=True)
class Op:
    host: str
    seq: int        # per-host monotonic, 1-based
    clock: int      # Lamport stamp
    kind: str       # one of OP_KINDS
    record: TuningRecord

    @property
    def stamp(self) -> tuple:
        """Total order over ops: Lamport clock, host id, sequence number."""
        return (self.clock, self.host, self.seq)

    def key(self) -> tuple:
        return self.record.key()

    def to_json(self) -> dict:
        d = self.record.to_json()
        d["op"] = {"host": self.host, "seq": self.seq,
                   "clock": self.clock, "kind": self.kind}
        return d

    @classmethod
    def from_json(cls, d: Mapping) -> "Op":
        o = d["op"]
        kind = str(o["kind"])
        if kind not in OP_KINDS:
            # reject at the parse boundary: an unknown kind must never be
            # appended to a log, where it would crash every later replay
            raise ValueError(f"unknown op kind {kind!r}")
        return cls(host=str(o["host"]), seq=int(o["seq"]), clock=int(o["clock"]),
                   kind=kind, record=TuningRecord.from_json(d))


def _dominates(e: Op, p: Op) -> bool:
    # e makes p permanently irrelevant: e survives everything p survives
    # AND beats p in winner selection (lower objective; equal objectives
    # select by lowest stamp, which e's newer stamp cannot win). Surviving
    # "everything" needs the SAME config — an eviction is outlived by any
    # newer stamp, but a quarantine kills by config, so pruning across
    # configs would lose the put that should resurrect when a quarantine
    # later lands on the dominator.
    return (config_key(e.record.config) == config_key(p.record.config)
            and e.record.objective < p.record.objective and e.stamp > p.stamp)


class MergeState:
    """Order-independent fold of put/quarantine/evict ops (see module doc)."""

    def __init__(self):
        self._frontier: dict[tuple, list[Op]] = {}
        self._evict_stamp: dict[tuple, tuple] = {}
        self._quarantined: set[tuple] = set()   # key + config-key
        # the quarantine ops themselves, per key: reconciliation re-derives
        # store-level bans from here, so a crash between durable ingest and
        # store application (or a wiped store dir) cannot lose a ban —
        # version-vector dedup means the op will never be delivered again
        self._qops: dict[tuple, list[Op]] = {}
        # every put content ever folded (key + config-key + objective),
        # including dead ones: bootstrap must not re-emit a store record the
        # fleet already judged — a tombstoned record surviving in the store
        # through the ingest/apply crash window would otherwise come back
        # with a fresh stamp and outlive its own eviction
        self._put_contents: set[tuple] = set()

    @staticmethod
    def _sel(op: Op) -> tuple:
        return (op.record.objective, op.stamp)

    def winner(self, key: tuple) -> Op | None:
        """The merged best put for ``key`` (lowest objective; ties broken by
        lowest stamp), or None when every put is dead."""
        front = self._frontier.get(key)
        return min(front, key=self._sel) if front else None

    def keys(self) -> list[tuple]:
        return list(self._frontier.keys() | self._evict_stamp.keys()
                    | self._qops.keys())

    def is_quarantined(self, key: tuple, config: Mapping) -> bool:
        return key + (config_key(dict(config)),) in self._quarantined

    def quarantine_ops(self, key: tuple) -> list[Op]:
        return list(self._qops.get(key, ()))

    def has_put_content(self, rec: TuningRecord) -> bool:
        """Whether a put op with this exact content was ever folded —
        alive, shadowed, tombstoned, or quarantined."""
        return rec.key() + (config_key(rec.config), rec.objective) \
            in self._put_contents

    def apply(self, op: Op) -> bool:
        """Fold one op; returns whether the key's winner changed. Must only
        see each (host, seq) once — :class:`OpLog` dedups by version vector."""
        key = op.key()
        before = self.winner(op.key())
        if op.kind == "quarantine":
            ck = config_key(op.record.config)
            if key + (ck,) not in self._quarantined:
                self._quarantined.add(key + (ck,))
                self._qops.setdefault(key, []).append(op)
            front = [p for p in self._frontier.get(key, ())
                     if config_key(p.record.config) != ck]
            self._set_frontier(key, front)
        elif op.kind == "evict":
            prev = self._evict_stamp.get(key)
            if prev is None or op.stamp > prev:
                self._evict_stamp[key] = op.stamp
            stamp = self._evict_stamp[key]
            front = [p for p in self._frontier.get(key, ()) if p.stamp > stamp]
            self._set_frontier(key, front)
        elif op.kind == "put":
            self._put_contents.add(
                key + (config_key(op.record.config), op.record.objective))
            if key + (config_key(op.record.config),) in self._quarantined:
                return False
            evicted = self._evict_stamp.get(key)
            if evicted is not None and op.stamp <= evicted:
                return False
            front = self._frontier.get(key, [])
            if any(_dominates(e, op) for e in front):
                return False
            self._set_frontier(
                key, [e for e in front if not _dominates(op, e)] + [op])
        else:
            raise ValueError(f"unknown op kind {op.kind!r}")
        after = self.winner(key)
        if (before is None) != (after is None):
            return True
        return before is not None and before.stamp != after.stamp

    def _set_frontier(self, key: tuple, front: list[Op]) -> None:
        if front:
            self._frontier[key] = front
        else:
            self._frontier.pop(key, None)


class OpLog:
    """Durable op stream of one host: emission of local ops, idempotent
    ingestion of replicated ones, and the live :class:`MergeState`."""

    def __init__(self, path: str, host_id: str | None = None):
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.host_id = host_id or self._load_or_create_host_id()
        self.state = MergeState()
        self._ops: list[Op] = []
        self._vv: dict[str, int] = {}
        self._clock = 0
        self._offset = 0
        self._tlock = threading.RLock()
        self.refresh()

    # -- identity / paths --------------------------------------------------------

    def _load_or_create_host_id(self) -> str:
        hpath = os.path.join(self.path, "host")
        try:
            with open(hpath) as f:
                hid = f.read().strip()
            if hid:
                return hid
        except FileNotFoundError:
            pass
        # claim by fully-written-then-linked temp file: a loser of the race
        # reads a COMPLETE host file (open('x')-then-write would let it read
        # an empty one, and an empty host id collapses seq spaces fleet-wide)
        hid = "h" + uuid.uuid4().hex[:10]
        tmp = f"{hpath}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(hid + "\n")
            f.flush()
            os.fsync(f.fileno())
        try:
            os.link(tmp, hpath)           # atomic: fails if someone else won
        except FileExistsError:
            with open(hpath) as f:
                hid = f.read().strip()
        finally:
            os.unlink(tmp)
        return hid

    def _log_path(self) -> str:
        return os.path.join(self.path, "log.jsonl")

    @contextlib.contextmanager
    def _lock(self) -> Iterator[None]:
        f = open(os.path.join(self.path, "fleet.lock"), "a+")
        try:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_EX)
            yield
        finally:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_UN)
            f.close()

    # -- folding -----------------------------------------------------------------

    def __len__(self) -> int:
        with self._tlock:
            return len(self._ops)

    def _fold(self, op: Op) -> bool:
        self._vv[op.host] = max(self._vv.get(op.host, 0), op.seq)
        self._clock = max(self._clock, op.clock)
        self._ops.append(op)
        return self.state.apply(op)

    def refresh(self) -> list[Op]:
        """Fold ops appended to the log by other processes on this host
        since the last read; returns the newly seen ops."""
        new: list[Op] = []
        with self._tlock:
            for d, self._offset in iter_jsonl_tail(self._log_path(),
                                                   self._offset):
                if d is None:
                    continue
                try:
                    op = Op.from_json(d)
                except (KeyError, ValueError):
                    continue
                if op.seq <= self._vv.get(op.host, 0):
                    continue  # replayed duplicate
                self._fold(op)
                new.append(op)
        return new

    # -- locked MergeState views (safe against concurrent emit/ingest) -----------

    def merge_keys(self) -> list[tuple]:
        with self._tlock:
            return self.state.keys()

    def winner(self, key: tuple) -> Op | None:
        with self._tlock:
            return self.state.winner(key)

    def key_quarantines(self, key: tuple) -> list[Op]:
        with self._tlock:
            return self.state.quarantine_ops(key)

    # -- write side --------------------------------------------------------------

    def emit(self, kind: str, rec: TuningRecord) -> Op:
        """Stamp and append one locally-originated op. Safe across processes
        sharing this log dir: the flock + refresh keep per-host sequence
        numbers monotonic even with several emitters."""
        if kind not in OP_KINDS:
            raise ValueError(f"unknown op kind {kind!r}")
        with self._tlock, self._lock():
            repair_torn_tail(self._log_path())
            self.refresh()
            self._clock += 1
            op = Op(host=self.host_id, seq=self._vv.get(self.host_id, 0) + 1,
                    clock=self._clock, kind=kind, record=rec)
            self._offset += append_jsonl(self._log_path(), op.to_json(), fsync=True)
            self._fold(op)
            return op

    def ensure_put(self, rec: TuningRecord) -> Op | None:
        """Bootstrap hook: emit a put for a store record that predates fleet
        attachment — genuinely new local knowledge — unless the record's
        exact content is already a known put op (alive, shadowed, tombstoned
        or quarantined). Re-emitting known content would both grow the log
        on every re-attach and, worse, resurrect a fleet-evicted record with
        a fresh stamp when a crash left the store lagging the oplog."""
        with self._tlock:
            if self.state.has_put_content(rec):
                return None
            return self.emit("put", rec)

    def ingest(self, ops: Iterable[Op]) -> tuple[list[Op], set]:
        """Fold replicated ops; returns ``(newly applied ops, keys whose
        merge winner changed)``. Ops must arrive in per-host seq order (both
        built-in transports preserve append order); already-known ops are
        skipped by version vector, so re-ingesting any stream is idempotent."""
        applied: list[Op] = []
        changed: set = set()
        with self._tlock, self._lock():
            repair_torn_tail(self._log_path())
            for op in self.refresh():       # other-process emissions count too
                changed.add(op.key())
            for op in ops:
                if op.kind not in OP_KINDS:
                    continue  # never append what replay would choke on
                if op.seq <= self._vv.get(op.host, 0):
                    continue
                self._offset += append_jsonl(
                    self._log_path(), op.to_json(), fsync=True)
                if self._fold(op):
                    changed.add(op.key())
                applied.append(op)
        return applied, changed

    # -- read side (transports / telemetry) --------------------------------------

    def version_vector(self) -> dict[str, int]:
        with self._tlock:
            return dict(self._vv)

    def ops_after(self, vv: Mapping[str, int]) -> list[Op]:
        """Every known op not covered by ``vv`` — own and replicated, so a
        pull through any reachable peer propagates third-party ops too."""
        with self._tlock:
            return [op for op in self._ops if op.seq > vv.get(op.host, 0)]

    def own_ops_after(self, seq: int) -> list[Op]:
        with self._tlock:
            return [op for op in self._ops
                    if op.host == self.host_id and op.seq > seq]

    # -- sync telemetry ----------------------------------------------------------

    def note_sync(self, **counters) -> None:
        tmp = os.path.join(self.path, "sync.json.tmp")
        with open(tmp, "w") as f:
            json.dump({"time": time.time(), **counters}, f)
        os.replace(tmp, os.path.join(self.path, "sync.json"))

    def last_sync(self) -> dict | None:
        try:
            with open(os.path.join(self.path, "sync.json")) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None
