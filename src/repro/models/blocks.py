"""Layer blocks: GQA/MLA transformer layers (dense & MoE), Mamba2 layers,
encoder/decoder layers — init + train-time apply + decode-time apply.

All apply functions are scan-compatible: ``(x, (params_leafwise, per_layer
meta)) -> x`` with the config closed over, so whole stages lower to one
``lax.scan`` (essential for 60-layer dry-run compiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import gqa_attention, gqa_decode
from repro.models.common import (
    ArchConfig,
    dense_init,
    mrope,
    rms_norm,
    rope,
    service_matmul,
)
from repro.models.mla import init_mla, mla_attention, mla_decode
from repro.models.moe import init_mlp, init_moe, mlp, moe_ffn
from repro.models.ssm import init_mamba2, mamba2_decode, mamba2_forward

__all__ = [
    "init_attn_layer", "attn_layer_train", "attn_layer_decode",
    "init_mamba_layer", "mamba_layer_train", "mamba_layer_decode",
    "init_cross_layer", "cross_layer_train", "cross_layer_decode",
    "layer_windows",
]


# ---------------------------------------------------------------------------
# per-layer attention-window schedule (mixtral SWA, gemma3 local:global)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """(L,) window sizes; 0 means full/global attention."""
    L = cfg.n_layers
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        w = np.full(L, cfg.sliding_window or 1024, np.int32)
        w[r::r + 1] = 0  # every (r+1)-th layer is global
        return w
    if cfg.sliding_window:
        return np.full(L, cfg.sliding_window, np.int32)
    return np.zeros(L, np.int32)


# ---------------------------------------------------------------------------
# transformer layer (GQA or MLA attention; dense MLP or MoE)
# ---------------------------------------------------------------------------


def init_attn_layer(key, cfg: ArchConfig, *, moe: bool, d_ff: int | None = None,
                    causal: bool = True) -> dict:
    d = cfg.d_model
    hd = cfg.hd
    dtype = cfg.dtype
    ks = jax.random.split(key, 10)
    p: dict = {"ln1": jnp.zeros((d,), jnp.float32), "ln2": jnp.zeros((d,), jnp.float32)}
    if cfg.attn_type == "mla":
        p["mla"] = init_mla(ks[0], cfg, dtype)
    else:
        p["wq"] = dense_init(ks[0], (d, cfg.n_heads * hd), 0, dtype)
        p["wk"] = dense_init(ks[1], (d, cfg.n_kv_heads * hd), 0, dtype)
        p["wv"] = dense_init(ks[2], (d, cfg.n_kv_heads * hd), 0, dtype)
        p["wo"] = dense_init(ks[3], (cfg.n_heads * hd, d), 0, dtype)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
            p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
            p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((hd,), jnp.float32)
            p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    if moe:
        p["moe"] = init_moe(ks[4], d, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts,
                            cfg.n_shared_experts, dtype)
    else:
        p["mlp"] = init_mlp(ks[4], d, d_ff or cfg.d_ff, dtype)
    return p


def _qkv(p, h, cfg, positions):
    B, S, _ = h.shape
    hd = cfg.hd
    q = h @ p["wq"] + (p.get("bq", 0.0))
    k = h @ p["wk"] + (p.get("bk", 0.0))
    v = h @ p["wv"] + (p.get("bv", 0.0))
    q = q.reshape(B, S, cfg.n_heads, hd).astype(cfg.dtype)
    k = k.reshape(B, S, cfg.n_kv_heads, hd).astype(cfg.dtype)
    v = v.reshape(B, S, cfg.n_kv_heads, hd).astype(cfg.dtype)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is not None:
        if cfg.mrope:
            q = mrope(q, positions, cfg.rope_theta)
            k = mrope(k, positions, cfg.rope_theta)
        else:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_layer_train(p, x, *, cfg: ArchConfig, positions, window=None,
                     moe: bool = False, causal: bool = True, chunk: int = 512,
                     service=None):
    """Returns (x, aux). positions: (B,S) or (B,3,S) for M-RoPE; window: traced
    scalar (0 = full attention). ``service`` routes attention and the output
    projection through :mod:`repro.dispatch` tuned variants."""
    # window is a traced per-layer scalar inside the stage scan, so the flash
    # route is gated statically: only archs with no windowed layers qualify
    svc_attn = service if not (cfg.sliding_window or cfg.local_global_ratio) else None
    h = rms_norm(x, p["ln1"])
    if cfg.attn_type == "mla":
        attn = mla_attention(p["mla"], h, cfg,
                             positions if positions.ndim == 2 else positions[:, 0])
        x = x + attn
    else:
        q, k, v = _qkv(p, h, cfg, positions)
        o = gqa_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                          f32=cfg.attn_f32, service=svc_attn)
        B, S = x.shape[:2]
        x = x + service_matmul(o.reshape(B, S, -1), p["wo"], service)

    h2 = rms_norm(x, p["ln2"])
    if moe:
        y, aux = moe_ffn(p["moe"], h2, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor,
                         group_size=cfg.moe_group)
    else:
        y, aux = mlp(p["mlp"], h2), jnp.zeros((), jnp.float32)
    return x + y, aux


def attn_layer_decode(p, x, cache, pos, *, cfg: ArchConfig, window=None,
                      moe: bool = False, mla_absorb: bool = True,
                      service=None):
    """x: (B,1,d); cache: {'k': (B,S,K,hd), 'v': ...} or MLA latent cache.
    Returns (x, cache, aux). ``pos`` may be a (B,) vector for the GQA
    family (continuous batching: per-sequence decode positions; the cache
    insert becomes a per-row scatter). ``service`` routes the output
    projection through the tuned blocked matmul and — for archs with no
    windowed layers, where the per-layer window scalar is statically zero —
    single-token attention through the tuned ``decode_attention`` kernel."""
    B = x.shape[0]
    h = rms_norm(x, p["ln1"])
    if cfg.attn_type == "mla":
        attn, cache = mla_decode(p["mla"], h, cache, cfg, pos, absorb=mla_absorb)
        x = x + attn
    else:
        # window rides through the layer scan as a traced scalar, so the
        # decode dispatch route is gated statically (cf. attn_layer_train)
        svc_attn = service if not (cfg.sliding_window or cfg.local_global_ratio) \
            else None
        positions = jnp.broadcast_to(jnp.asarray(pos).reshape(-1)[:, None], (B, 1))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (B, 3, 1))
        q, k, v = _qkv(p, h, cfg, positions)
        S_alloc = cache["k"].shape[1]
        ring = bool(cfg.sliding_window) and not cfg.local_global_ratio \
            and S_alloc == cfg.sliding_window
        if jnp.ndim(pos) == 0:
            slot = jnp.mod(pos, S_alloc) if ring else pos
            cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)),
            }
        else:
            # per-sequence positions: row b writes its own slot
            slots = jnp.mod(pos, S_alloc) if ring else pos
            rows = jnp.arange(B)
            cache = {
                "k": cache["k"].at[rows, slots].set(k[:, 0].astype(cache["k"].dtype)),
                "v": cache["v"].at[rows, slots].set(v[:, 0].astype(cache["v"].dtype)),
            }
        o = gqa_decode(q, cache["k"], cache["v"], pos,
                       window=None if svc_attn is not None else window,
                       ring=ring, service=svc_attn)
        x = x + service_matmul(o.reshape(B, 1, -1), p["wo"], service)

    h2 = rms_norm(x, p["ln2"])
    if moe:
        y, aux = moe_ffn(p["moe"], h2, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor,
                         group_size=cfg.moe_group)
    else:
        y, aux = mlp(p["mlp"], h2), jnp.zeros((), jnp.float32)
    return x + y, cache, aux


# ---------------------------------------------------------------------------
# mamba2 layer (pre-norm residual)
# ---------------------------------------------------------------------------


def init_mamba_layer(key, cfg: ArchConfig) -> dict:
    return {
        "ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "mixer": init_mamba2(
            key, cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            n_state=cfg.ssm_state, conv_width=cfg.ssm_conv_width, dtype=cfg.dtype,
        ),
    }


def mamba_layer_train(p, x, *, cfg: ArchConfig, chunk: int = 64):
    return x + mamba2_forward(p["mixer"], rms_norm(x, p["ln"]), cfg, chunk=chunk)


def mamba_layer_decode(p, x, cache, *, cfg: ArchConfig):
    y, cache = mamba2_decode(p["mixer"], rms_norm(x, p["ln"]), cache, cfg)
    return x + y, cache


# ---------------------------------------------------------------------------
# encoder-decoder (whisper): decoder layer with cross-attention
# ---------------------------------------------------------------------------


def init_cross_layer(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    hd = cfg.hd
    dtype = cfg.dtype
    ks = jax.random.split(key, 9)
    p = init_attn_layer(ks[0], cfg, moe=False)
    p["ln_x"] = jnp.zeros((d,), jnp.float32)
    p["xq"] = dense_init(ks[1], (d, cfg.n_heads * hd), 0, dtype)
    p["xk"] = dense_init(ks[2], (d, cfg.n_kv_heads * hd), 0, dtype)
    p["xv"] = dense_init(ks[3], (d, cfg.n_kv_heads * hd), 0, dtype)
    p["xo"] = dense_init(ks[4], (cfg.n_heads * hd, d), 0, dtype)
    return p


def _cross_attend(p, x, enc_k, enc_v, cfg):
    B, S, _ = x.shape
    hd = cfg.hd
    h = rms_norm(x, p["ln_x"])
    q = (h @ p["xq"]).reshape(B, S, cfg.n_heads, hd).astype(cfg.dtype)
    o = gqa_attention(q, enc_k, enc_v, causal=False, window=None)
    return x + o.reshape(B, S, -1) @ p["xo"]


def cross_layer_train(p, x, enc_kv, *, cfg: ArchConfig, positions):
    """Decoder layer: causal self-attn, cross-attn to encoder K/V, MLP."""
    h = rms_norm(x, p["ln1"])
    q, k, v = _qkv(p, h, cfg, positions)
    B, S = x.shape[:2]
    o = gqa_attention(q, k, v, causal=True, window=None)
    x = x + o.reshape(B, S, -1) @ p["wo"]
    x = _cross_attend(p, x, enc_kv["k"], enc_kv["v"], cfg)
    y = mlp(p["mlp"], rms_norm(x, p["ln2"]))
    return x + y


def cross_layer_decode(p, x, cache, enc_kv, pos, *, cfg: ArchConfig):
    B = x.shape[0]
    h = rms_norm(x, p["ln1"])
    positions = jnp.full((B, 1), pos)
    q, k, v = _qkv(p, h, cfg, positions)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos, 0, 0)),
    }
    o = gqa_decode(q, cache["k"], cache["v"], pos)
    x = x + o.reshape(B, 1, -1) @ p["wo"]
    x = _cross_attend(p, x, enc_kv["k"], enc_kv["v"], cfg)
    y = mlp(p["mlp"], rms_norm(x, p["ln2"]))
    return x + y, cache
