"""Shared model building blocks: norms, rotary embeddings (incl. M-RoPE),
initializers, and the architecture config schema."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "rms_norm", "rope", "mrope", "dense_init",
           "service_matmul", "ACT"]


def service_matmul(x: jnp.ndarray, w: jnp.ndarray, service=None) -> jnp.ndarray:
    """``x @ w`` routed through the dispatch service's tuned blocked matmul
    (per ``(rows, K) x (K, N)`` shape signature); a plain matmul without a
    service. Leading dims of ``x`` are flattened for the kernel's 2-D
    contract and restored afterwards."""
    if service is None:
        return x @ w
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    fn = service.dispatch("matmul", x2, w)
    return fn(x2, w).reshape(*lead, w.shape[-1])


# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture. Field defaults cover the plain dense case;
    family-specific blocks read their own fields."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None      # default d_model // n_heads

    # attention
    attn_type: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    mrope: bool = False              # qwen2-vl 3-section rotary
    sliding_window: int | None = None
    local_global_ratio: int | None = None   # gemma3: N local per 1 global
    qk_norm: bool = False
    attn_f32: bool = True            # attention scores/softmax in f32 (knob)

    # MoE
    capacity_factor: float = 1.25
    moe_group: int = 2048            # GShard dispatch group size (tunable)
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int | None = None      # routed-expert hidden width
    first_dense_layers: int = 0      # deepseek: leading dense layer(s)

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2) / hybrid (zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    attn_every: int = 0              # zamba2: shared attn block interval

    # encoder-decoder (whisper)
    is_encdec: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500          # whisper frame count after conv frontend
    frontend: str | None = None      # audio_stub | vision_stub

    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    notes: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.attn_type == "none" and self.attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md §Arch-applicability)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
            or self.local_global_ratio is not None
        )

    def param_count(self) -> int:
        """Analytic parameter count (for 6*N*D roofline bookkeeping)."""
        d, L = self.d_model, self.n_layers
        hd = self.hd
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for layer in range(L):
            if self.attn_type == "gqa":
                attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            elif self.attn_type == "mla":
                qdim = self.qk_rope_dim + self.qk_nope_dim
                attn = (
                    d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qdim
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d
                )
            else:
                attn = 0
            if self.family in ("ssm", "hybrid") and self.attn_type == "none":
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                attn = d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d \
                    + self.ssm_conv_width * (d_in + 2 * self.ssm_state)
            is_moe = self.n_experts > 0 and layer >= self.first_dense_layers
            if is_moe:
                eff = self.moe_d_ff or self.d_ff
                mlp = self.n_experts * 3 * d * eff + self.n_shared_experts * 3 * d * eff \
                    + d * self.n_experts
            elif self.family in ("ssm", "hybrid"):
                mlp = 0  # mamba layers carry no FFN; zamba2's d_ff lives in
                # the shared attention block (counted below)
            else:
                mlp = 3 * d * self.d_ff if self.d_ff else 0
            total += attn + mlp + 2 * d
        if self.attn_every:
            total += 4 * d * d + 3 * d * self.d_ff  # zamba2 shared block
        if self.is_encdec:
            # encoder layers: self-attn + mlp; decoder already counted above
            total += self.n_encoder_layers * (
                4 * d * self.n_heads * hd + 3 * d * self.d_ff + 2 * d
            )
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared, not all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        eff = self.moe_d_ff or self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * eff
        moe_layers = self.n_layers - self.first_dense_layers
        return int(self.param_count() - moe_layers * inactive)


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def _rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """positions: (..., S) -> cos/sin (..., S, dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1.0e4) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)  # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float = 1.0e4,
          sections: tuple = (2, 3, 3)) -> jnp.ndarray:
    """Qwen2-VL multimodal rotary: the head_dim halves are partitioned into
    (t, h, w) sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions3: (B, 3, S). ``sections`` are relative parts
    of hd//2 (Qwen2-VL uses 16/24/24 of 64 -> 2:3:3).
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    widths = [half * s // total for s in sections]
    widths[-1] = half - sum(widths[:-1])

    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    parts, off = [], 0
    for axis, w in enumerate(widths):
        pos = positions3[:, axis, :].astype(jnp.float32)      # (B, S)
        ang = pos[..., None] * inv[off : off + w]             # (B, S, w)
        parts.append(ang)
        off += w
    ang = jnp.concatenate(parts, axis=-1)[:, :, None, :]      # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16) -> jnp.ndarray:
    fan_in = shape[in_axis] if in_axis < len(shape) else shape[0]
    std = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


ACT = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
}
