"""Mixture-of-experts FFN: top-k routing with GShard-style capacity dispatch.

The dispatch/combine tensors are (T, E, C); sharding E over the expert axes
makes the per-device slice small and lets XLA SPMD lower the token exchange
to all-to-all / all-gather — the collective pattern the roofline's
collective term measures. Shared experts (DeepSeek-V2) are an always-on
dense MLP fused alongside the routed path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

__all__ = ["init_moe", "moe_ffn", "init_mlp", "mlp"]


# ---------------------------------------------------------------------------
# dense (gated SwiGLU) MLP — also used for shared experts and dense layers
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, (d, ff), 0, dtype),
        "wu": dense_init(k2, (d, ff), 0, dtype),
        "wd": dense_init(k3, (ff, d), 0, dtype),
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# routed experts
# ---------------------------------------------------------------------------


def init_moe(key, d: int, eff: int, n_experts: int, n_shared: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, n_experts), 0, jnp.float32),
        "wg": dense_init(ks[1], (n_experts, d, eff), 1, dtype),
        "wu": dense_init(ks[2], (n_experts, d, eff), 1, dtype),
        "wd": dense_init(ks[3], (n_experts, eff, d), 1, dtype),
    }
    if n_shared:
        p["shared"] = init_mlp(ks[4], d, n_shared * eff, dtype)
    return p


def moe_ffn(
    p: dict,
    x: jnp.ndarray,             # (B, S, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 2048,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss). Capacity-dropped tokens fall through to the
    residual stream (their routed contribution is zero), GShard semantics.

    Dispatch is *grouped*: tokens are split into groups of ``group_size`` and
    capacity is per group (C = cf * G * k / E), so the dense dispatch einsum
    costs cf*k*G per token instead of cf*k*T — without grouping the GShard
    formulation is quadratic in sequence length (measured: a 230x FLOP blowup
    on the 32k-prefill dry-run cells). ``group_size`` is an autotuner knob."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    T = B * S
    xt = x.reshape(T, d)

    G = min(group_size, T)
    pad = (-T) % G
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ng = xt.shape[0] // G
    xg = xt.reshape(ng, G, d)

    logits = xg.astype(jnp.float32) @ p["router"]          # (ng, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # (ng, G, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)        # (ng, G, k, E)
    gates = (onehot * gate_vals[..., None]).sum(axis=2)            # (ng, G, E)
    mask = onehot.sum(axis=2)                                      # (ng, G, E)

    # GShard load-balancing aux loss (over real tokens only)
    me = probs.reshape(-1, E)[:T].mean(axis=0)
    ce = mask.reshape(-1, E)[:T].mean(axis=0) / max(top_k, 1)
    aux = E * jnp.sum(me * ce)

    # per-group capacity assignment
    C = max(int(capacity_factor * G * top_k / E), 4)
    pos = jnp.cumsum(mask, axis=1) - 1.0                           # (ng, G, E)
    keep = mask * (pos < C)
    pos = jnp.where(keep > 0, pos, 0).astype(jnp.int32)

    disp = keep[..., None] * jax.nn.one_hot(pos, C, dtype=jnp.float32)  # (ng,G,E,C)
    comb = disp * gates[..., None]

    cd = x.dtype
    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(cd), xg)         # (ng, E, C, d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])                  # (ng, E, C, d)
    yt = jnp.einsum("gtec,gecd->gtd", comb.astype(cd), ye).reshape(ng * G, d)
    y = yt[:T].reshape(B, S, d)

    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y, aux
