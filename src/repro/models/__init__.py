"""repro.models — the LM substrate for the assigned architectures."""

from repro.models.common import ArchConfig
from repro.models.model import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)

__all__ = [
    "ArchConfig", "abstract_params", "decode_step", "forward", "init_cache",
    "init_params", "loss_fn",
]
