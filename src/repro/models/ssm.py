"""Mamba2 (SSD — state-space duality) block: chunked parallel form for
training/prefill and O(1)-state recurrence for decode.

The chunked SSD algorithm is a blocked matrix program (intra-chunk
"attention-like" diagonal blocks + inter-chunk state recurrence), i.e. the
same tiled-loop-nest shape the paper's pragmas tune — ``chunk`` is its tile
size and is exposed to the autotuner.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm

__all__ = ["init_mamba2", "mamba2_forward", "mamba2_decode", "init_ssm_cache", "ssd"]


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """(..., L) -> (..., L, L); out[i, j] = sum_{j < t <= i} a[t], -inf above
    the diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd(x, dt, A, B, C, chunk: int = 64, initial_state=None):
    """Chunked state-space-dual scan.

    x: (b, s, H, P); dt: (b, s, H) (already softplus'd); A: (H,) negative;
    B, C: (b, s, N) (single group, broadcast over heads).
    Returns (y: (b, s, H, P), final_state: (b, H, P, N)).
    """
    b, s, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    c = s // chunk

    f32 = jnp.float32
    Xd = (x * dt[..., None]).astype(f32).reshape(b, c, chunk, H, P)
    Ad = (dt * A[None, None, :]).astype(f32).reshape(b, c, chunk, H)
    Ad = Ad.transpose(0, 3, 1, 2)                      # (b, H, c, L)
    Bc = B.astype(f32).reshape(b, c, chunk, N)
    Cc = C.astype(f32).reshape(b, c, chunk, N)

    A_cum = jnp.cumsum(Ad, axis=-1)                    # (b, H, c, L)

    # 1) intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(Ad))                        # (b, H, c, L, L)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, Lmat, Xd)

    # 2) chunk-final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)    # (b, H, c, L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, Xd)

    # 3) inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((b, H, P, N), f32)
    states = jnp.concatenate([initial_state[:, None].transpose(0, 1, 2, 3, 4), states], axis=1)
    chunk_sums = jnp.pad(A_cum[..., -1], ((0, 0), (0, 0), (1, 0)))  # (b, H, c+1)
    decay_chunk = jnp.exp(_segsum(chunk_sums))          # (b, H, c+1, c+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states_prev, final_state = new_states[:, :-1], new_states[:, -1]

    # 4) state -> output contribution
    out_decay = jnp.exp(A_cum)                          # (b, H, c, L)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, states_prev, out_decay)

    y = (Y_diag + Y_off).reshape(b, s, H, P)
    return y.astype(x.dtype), final_state


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def _dims(d_model: int, expand: int, head_dim: int, n_state: int):
    d_in = expand * d_model
    H = d_in // head_dim
    conv_dim = d_in + 2 * n_state
    return d_in, H, conv_dim


def init_mamba2(key, d_model: int, *, expand: int = 2, head_dim: int = 64,
                n_state: int = 128, conv_width: int = 4, dtype=jnp.bfloat16) -> dict:
    d_in, H, conv_dim = _dims(d_model, expand, head_dim, n_state)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * n_state + H
    return {
        "in_proj": dense_init(ks[0], (d_model, proj_out), 0, dtype),
        "conv_w": dense_init(ks[1], (conv_width, conv_dim), 0, jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d_model), 0, dtype),
    }


def _split_proj(zxbcdt, d_in: int, n_state: int, H: int):
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : 2 * d_in + 2 * n_state]
    dt = zxbcdt[..., 2 * d_in + 2 * n_state :]
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along S. xBC: (B, S, Cd); w: (cw, Cd)."""
    cw = w.shape[0]
    out = xBC.astype(jnp.float32) * w[-1]
    padded = jnp.pad(xBC.astype(jnp.float32), ((0, 0), (cw - 1, 0), (0, 0)))
    S = xBC.shape[1]
    for i in range(cw - 1):
        out = out + padded[:, i : i + S, :] * w[i]
    return jax.nn.silu(out + b).astype(xBC.dtype)


def mamba2_forward(p: dict, x: jnp.ndarray, cfg, chunk: int = 64) -> jnp.ndarray:
    """x: (B, S, d_model) -> (B, S, d_model)."""
    d_in, H, conv_dim = _dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state)
    N = cfg.ssm_state
    P = cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, d_in, N, H)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :d_in]
    Bmat = xBC[..., d_in : d_in + N]
    Cmat = xBC[..., d_in + N :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    B_, S_, _ = x.shape
    xh = xs.reshape(B_, S_, H, P)
    y, _ = ssd(xh, dt, A, Bmat, Cmat, chunk=chunk)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)

    y = y.reshape(B_, S_, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"])
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# decode path (single token, recurrent)
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg, B: int, dtype=jnp.float32) -> dict:
    d_in, H, conv_dim = _dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state)
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((B, H, cfg.ssm_head_dim, cfg.ssm_state), dtype),
    }


def mamba2_decode(p: dict, x: jnp.ndarray, cache: dict, cfg) -> tuple[jnp.ndarray, dict]:
    """x: (B, 1, d_model); cache: {conv: (B, cw-1, Cd), ssm: (B, H, P, N)}."""
    d_in, H, conv_dim = _dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    B_ = x.shape[0]

    zxbcdt = (x @ p["in_proj"])[:, 0, :]                       # (B, proj)
    z, xBC, dt = _split_proj(zxbcdt, d_in, N, H)

    window = jnp.concatenate([cache["conv"], xBC[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs = xBC[..., :d_in]
    Bmat = xBC[..., d_in : d_in + N]                            # (B, N)
    Cmat = xBC[..., d_in + N :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                                # (B, H)

    xh = xs.reshape(B_, H, P).astype(jnp.float32)
    contrib = dt[..., None, None] * xh[..., None] * Bmat[:, None, None, :]
    new_ssm = cache["ssm"] * dA[..., None, None] + contrib       # (B, H, P, N)

    y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cmat.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)[:, None, :], p["norm"])
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": new_ssm}
