"""Model assembly: init / forward / loss / decode for every assigned family.

Layers are stacked (leading L axis) and driven by ``lax.scan`` so a 60-layer
model lowers as one scanned block — the property that keeps the 512-device
dry-run compiles tractable. Heterogeneous stacks (DeepSeek's leading dense
layer, Zamba2's shared attention block) become separate stages around the
scan.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks as B
from repro.models.common import ArchConfig, dense_init, rms_norm, service_matmul
from repro.models.mla import init_mla_cache
from repro.models.ssm import init_ssm_cache

__all__ = [
    "init_params", "abstract_params", "forward", "loss_fn", "init_cache",
    "decode_step", "make_batch_positions",
]

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(init_one: Callable, key, n: int):
    return jax.vmap(init_one)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), 1, cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[6], (cfg.d_model, cfg.vocab_size), 0, cfg.dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack_init(
            lambda k: B.init_attn_layer(k, cfg, moe=False), ks[1], cfg.n_layers)
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.first_dense_layers
        if cfg.first_dense_layers:
            # DeepSeek's leading dense layer uses the conventional wide FFN
            p["dense0"] = B.init_attn_layer(ks[2], cfg, moe=False,
                                            d_ff=_dense_ff(cfg))
        p["layers"] = _stack_init(
            lambda k: B.init_attn_layer(k, cfg, moe=True), ks[1], n_moe)
    elif fam == "ssm":
        p["layers"] = _stack_init(
            lambda k: B.init_mamba_layer(k, cfg), ks[1], cfg.n_layers)
    elif fam == "hybrid":
        p["layers"] = _stack_init(
            lambda k: B.init_mamba_layer(k, cfg), ks[1], cfg.n_layers)
        p["shared_attn"] = B.init_attn_layer(ks[3], cfg, moe=False)
    elif fam == "audio":
        p["enc_layers"] = _stack_init(
            lambda k: B.init_attn_layer(k, cfg, moe=False), ks[1],
            cfg.n_encoder_layers)
        p["dec_layers"] = _stack_init(
            lambda k: B.init_cross_layer(k, cfg), ks[4], cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return p


def _dense_ff(cfg: ArchConfig) -> int:
    # DeepSeek-V2's dense layers use the wide FFN (12288), not the expert width
    return 12288 if cfg.name.startswith("deepseek") else cfg.d_ff


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct pytree of the parameters — no allocation (dry-run)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def make_batch_positions(cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    Bsz, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (Bsz, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos[:, None, :], (Bsz, 3, S))
    return pos


def _cst(x, spec):
    """Activation sharding constraint (no-op when spec is None). Pinning the
    residual stream to (batch-axes, None, None) keeps XLA's SPMD propagation
    on the Megatron layout — without it, CPU SPMD happily replicates the
    batch and all-reduces logits (observed: an 80 GB collective)."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat policy {remat!r}")


def _scan_attn_stage(params_stack, x, windows, *, cfg, positions, moe, remat,
                     chunk, act_spec=None, service=None):
    def body(carry, xs):
        x, aux = carry
        p_l, w_l = xs
        x, a = B.attn_layer_train(p_l, x, cfg=cfg, positions=positions,
                                  window=w_l, moe=moe, chunk=chunk,
                                  service=service)
        x = _cst(x, act_spec)
        return (x, aux + a), None

    body = _maybe_remat(body, remat)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params_stack, windows))
    return x, aux


def forward(params: dict, batch: dict, cfg: ArchConfig, *, remat: str = "none",
            attn_chunk: int = 512, ssm_chunk: int = 64, act_spec=None,
            logits_spec=None, service=None):
    """Returns (logits, aux_loss). ``service`` (a
    :class:`repro.dispatch.DispatchService`) routes attention and the big
    matmul call sites through tuned, store-resolved kernel variants."""
    tokens = batch["tokens"]
    Bsz, S = tokens.shape
    x = _cst(params["embed"][tokens].astype(cfg.dtype), act_spec)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    positions = batch.get("positions", None)
    if positions is None:
        positions = make_batch_positions(cfg, tokens)

    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        windows = jnp.asarray(B.layer_windows(cfg))
        x, aux = _scan_attn_stage(params["layers"], x, windows, cfg=cfg,
                                  positions=positions, moe=False, remat=remat,
                                  chunk=attn_chunk, act_spec=act_spec,
                                  service=service)
    elif fam == "moe":
        if "dense0" in params:
            x, a0 = B.attn_layer_train(params["dense0"], x, cfg=cfg,
                                       positions=positions, window=None,
                                       moe=False, chunk=attn_chunk,
                                       service=service)
            aux = aux + a0
        n_moe = cfg.n_layers - cfg.first_dense_layers
        windows = jnp.asarray(B.layer_windows(cfg)[cfg.first_dense_layers:])
        x, a = _scan_attn_stage(params["layers"], x, windows, cfg=cfg,
                                positions=positions, moe=True, remat=remat,
                                chunk=attn_chunk, act_spec=act_spec,
                                service=service)
        aux = aux + a
    elif fam in ("ssm", "hybrid"):
        def mamba_body(x, p_l):
            x = B.mamba_layer_train(p_l, x, cfg=cfg, chunk=ssm_chunk)
            return _cst(x, act_spec), None

        mamba_body = _maybe_remat(mamba_body, remat)
        if fam == "ssm" or not cfg.attn_every:
            x, _ = jax.lax.scan(mamba_body, x, params["layers"])
        else:
            # zamba2: scan segments of mamba layers, shared attn in between
            L = cfg.n_layers
            every = cfg.attn_every
            start = 0
            while start < L:
                seg = min(every, L - start)
                seg_params = jax.tree_util.tree_map(
                    lambda p: p[start : start + seg], params["layers"])
                x, _ = jax.lax.scan(mamba_body, x, seg_params)
                start += seg
                # the shared attention block closes every mamba segment
                x, _ = B.attn_layer_train(
                    params["shared_attn"], x, cfg=cfg, positions=positions,
                    window=None, moe=False, chunk=attn_chunk, service=service)
    elif fam == "audio":
        enc = batch["enc_embed"].astype(cfg.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc.shape[1])[None, :], enc.shape[:2])

        def enc_body(h, p_l):
            h, _ = B.attn_layer_train(p_l, h, cfg=cfg, positions=enc_pos,
                                      window=None, moe=False, causal=False,
                                      chunk=attn_chunk)
            return _cst(h, act_spec), None

        enc_body = _maybe_remat(enc_body, remat)
        enc, _ = jax.lax.scan(enc_body, enc, params["enc_layers"])

        def dec_body(x, p_l):
            hd = cfg.hd
            Be, Se = enc.shape[:2]
            ek = (enc @ p_l["xk"]).reshape(Be, Se, cfg.n_kv_heads, hd).astype(cfg.dtype)
            ev = (enc @ p_l["xv"]).reshape(Be, Se, cfg.n_kv_heads, hd).astype(cfg.dtype)
            x = B.cross_layer_train(p_l, x, {"k": ek, "v": ev}, cfg=cfg,
                                    positions=positions)
            return _cst(x, act_spec), None

        dec_body = _maybe_remat(dec_body, remat)
        x, _ = jax.lax.scan(dec_body, x, params["dec_layers"])
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = service_matmul(x, params["embed"].T.astype(cfg.dtype), service)
    else:
        logits = service_matmul(x, params["unembed"], service)
    logits = _cst(logits, logits_spec)
    return logits.astype(jnp.float32), aux


def loss_fn(params: dict, batch: dict, cfg: ArchConfig, **fw_kw):
    logits, aux = forward(params, batch, cfg, **fw_kw)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + MOE_AUX_COEF * aux
    return total, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step): one new token against a filled cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Stacked per-layer cache pytree (leading L axis per stage).

    Uniform-sliding-window archs (mixtral) get a ring buffer of window size
    instead of max_len — O(window) cache for the 500k decode cell."""
    dtype = dtype or cfg.dtype
    hd = cfg.hd
    alloc = max_len
    if cfg.sliding_window and not cfg.local_global_ratio:
        alloc = min(max_len, cfg.sliding_window)

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, alloc, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((n, batch, alloc, cfg.n_kv_heads, hd), dtype),
        }

    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"layers": kv(cfg.n_layers)}
    if fam == "moe":
        n_moe = cfg.n_layers - cfg.first_dense_layers
        out = {}
        if cfg.attn_type == "mla":
            out["layers"] = jax.vmap(
                lambda _: init_mla_cache(cfg, batch, max_len, dtype))(jnp.arange(n_moe))
            if cfg.first_dense_layers:
                out["dense0"] = init_mla_cache(cfg, batch, max_len, dtype)
        else:
            out["layers"] = kv(n_moe)
            if cfg.first_dense_layers:
                out["dense0"] = jax.tree_util.tree_map(lambda a: a[0], kv(1))
        return out
    if fam == "ssm":
        return {"layers": jax.vmap(
            lambda _: init_ssm_cache(cfg, batch))(jnp.arange(cfg.n_layers))}
    if fam == "hybrid":
        n_sites = int(np.ceil(cfg.n_layers / cfg.attn_every)) if cfg.attn_every else 0
        return {
            "layers": jax.vmap(
                lambda _: init_ssm_cache(cfg, batch))(jnp.arange(cfg.n_layers)),
            "shared_attn": kv(max(n_sites, 1)),
        }
    if fam == "audio":
        return {
            "dec_layers": kv(cfg.n_layers),
            # cross K/V filled once at prefill from the encoder output
            "cross": {
                "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_len,
                                cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_len,
                                cfg.n_kv_heads, hd), dtype),
            },
        }
    raise ValueError(fam)


def decode_step(params: dict, cache: dict, token: jnp.ndarray, pos,
                cfg: ArchConfig, *, mla_absorb: bool = True, service=None):
    """token: (B, 1) int32; pos: scalar, or (B,) per-sequence positions for
    the GQA families (continuous batching). Returns (logits (B, V), new
    cache). ``service`` routes the decode-path matmul call sites (attention
    output projection, unembed) and — where the arch's window schedule is
    statically empty — single-token attention through tuned dispatch
    variants."""
    x = params["embed"][token].astype(cfg.dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "vlm", "moe"):
        windows = jnp.asarray(B.layer_windows(cfg)[cfg.first_dense_layers:])
        moe = fam == "moe"
        if "dense0" in params:
            x, c0, _ = B.attn_layer_decode(params["dense0"], x, cache["dense0"],
                                           pos, cfg=cfg, window=None, moe=False,
                                           mla_absorb=mla_absorb, service=service)
            new_cache["dense0"] = c0

        def body(x, xs):
            p_l, c_l, w_l = xs
            x, c_l, _ = B.attn_layer_decode(p_l, x, c_l, pos, cfg=cfg,
                                            window=w_l, moe=moe,
                                            mla_absorb=mla_absorb,
                                            service=service)
            return x, c_l

        x, cs = jax.lax.scan(body, x, (params["layers"], cache["layers"], windows))
        new_cache["layers"] = cs
    elif fam == "ssm":
        def body(x, xs):
            p_l, c_l = xs
            x, c_l = B.mamba_layer_decode(p_l, x, c_l, cfg=cfg)
            return x, c_l

        x, cs = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = cs
    elif fam == "hybrid":
        L, every = cfg.n_layers, cfg.attn_every
        site = 0
        layer_caches, attn_caches = [], []
        start = 0
        while start < L:
            seg = min(every, L - start)
            seg_p = jax.tree_util.tree_map(lambda p: p[start:start + seg],
                                           params["layers"])
            seg_c = jax.tree_util.tree_map(lambda c: c[start:start + seg],
                                           cache["layers"])

            def body(x, xs):
                p_l, c_l = xs
                x, c_l = B.mamba_layer_decode(p_l, x, c_l, cfg=cfg)
                return x, c_l

            x, cs = jax.lax.scan(body, x, (seg_p, seg_c))
            layer_caches.append(cs)
            start += seg
            ac = jax.tree_util.tree_map(lambda c: c[site], cache["shared_attn"])
            x, ac, _ = B.attn_layer_decode(params["shared_attn"], x, ac, pos,
                                           cfg=cfg, window=None, moe=False,
                                           service=service)
            attn_caches.append(ac)
            site += 1
        new_cache["layers"] = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, 0), *layer_caches)
        new_cache["shared_attn"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, 0), *attn_caches)
    elif fam == "audio":
        def body(x, xs):
            p_l, c_l, xk, xv = xs
            x, c_l = B.cross_layer_decode(p_l, x, c_l, {"k": xk, "v": xv}, pos,
                                          cfg=cfg)
            return x, c_l

        x, cs = jax.lax.scan(body, x, (params["dec_layers"], cache["dec_layers"],
                                       cache["cross"]["k"], cache["cross"]["v"]))
        new_cache["dec_layers"] = cs
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = service_matmul(x, params["embed"].T.astype(cfg.dtype), service)
    else:
        logits = service_matmul(x, params["unembed"], service)
    return logits[:, 0, :].astype(jnp.float32), new_cache
