"""Grouped-query attention: chunked (flash-style) training/prefill path and
single-token decode path, with sliding-window / local-global masking.

The training path scans over query chunks with an online-softmax
accumulator, so peak memory is O(chunk * S) per head instead of O(S^2) —
the property that makes the 32k prefill cells compile with sane
memory_analysis and the TPU analog of flash attention's HBM-traffic shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["gqa_attention", "gqa_decode", "make_positions"]

_NEG = -1.0e30


def _service_attention(q, k, v, *, causal, service):
    """Route full (non-windowed) attention through the dispatch service's
    tuned flash-attention variant. K/V are flattened to the kernel's
    (batch*kv_heads, seq, head_dim) layout — the shape signature the service
    resolves tuned ``(bq, bk)`` block shapes against — and the G query heads
    per kv head run as G calls of the one dispatched executable, so GQA
    never materializes repeated K/V copies on the hot path. Returns None
    when the call can't be expressed as a flash kernel (ragged GQA
    grouping), letting the caller fall back to the chunked path."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    if K == 0 or H % K:
        return None
    G = H // K
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    # head h = k*G + g: group axis out front, kv-head axis aligned with kf
    qg = q.reshape(B, Sq, K, G, hd).transpose(3, 0, 2, 1, 4)  # (G, B, K, Sq, hd)
    qg = qg.reshape(G, B * K, Sq, hd)
    fn = service.dispatch("flash_attention", qg[0], kf, vf, causal=causal)
    og = jnp.stack([fn(qg[g], kf, vf) for g in range(G)])     # (G, B*K, Sq, hd)
    out = og.reshape(G, B, K, Sq, hd).transpose(1, 3, 2, 0, 4)  # (B, Sq, K, G, hd)
    return out.reshape(B, Sq, H, hd)


def _service_decode(q, k_cache, v_cache, cur_pos, *, ring, window, service):
    """Route single-token decode attention through the dispatch service's
    tuned ``decode_attention`` variant. The cache is flattened to the
    kernel's (batch*kv_heads, seq, head_dim) layout — the shape signature
    tuned ``(bk, hg)`` blocks resolve against, with the seq dim being the
    paged cache's bucket — and ``cur_pos`` becomes a per-row (B*K,) vector
    (continuous batching gives every sequence its own position). Returns
    None for ragged GQA grouping, letting the caller fall back to the
    dense einsum path."""
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    if K == 0 or H % K:
        return None
    qg = q.reshape(B, K, H // K, hd).reshape(B * K, H // K, hd)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    cp = jnp.asarray(cur_pos, jnp.int32).reshape(-1)
    if cp.shape[0] == 1:
        cp = jnp.broadcast_to(cp, (B,))
    cp = jnp.repeat(cp, K)                      # row b*K + k shares seq b's pos
    fn = service.dispatch("decode_attention", qg, kf, vf, cp,
                          ring=bool(ring), window=int(window or 0))
    o = fn(qg, kf, vf, cp)                      # (B*K, G, hd)
    return o.reshape(B, K, H // K, hd).reshape(B, 1, H, hd).astype(q.dtype)


def make_positions(B: int, S: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))


def _mask(qpos, kpos, *, causal: bool, window) -> jnp.ndarray:
    """qpos: (Sq,), kpos: (Sk,) -> (Sq, Sk) boolean allow-mask."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        # window may be a traced scalar (per-layer, scanned); <=0 disables
        w = jnp.asarray(window)
        dist_ok = (qpos[:, None] - kpos[None, :]) < w
        m &= jnp.where(w > 0, dist_ok, True)
    return m


def gqa_attention(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Sk, K, hd)
    v: jnp.ndarray,            # (B, Sk, K, hd)
    *,
    causal: bool = True,
    window=None,
    chunk: int = 512,
    scale: float | None = None,
    f32: bool = True,
    service=None,
) -> jnp.ndarray:
    # the dispatch path: callers pass a service only when window masking is
    # statically off (see blocks.attn_layer_train); custom scales and bf16
    # score accumulation stay on the chunked path for exact-variant parity
    if service is not None and scale is None and f32:
        out = _service_attention(q, k, v, causal=causal, service=service)
        if out is not None:
            return out
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else hd ** -0.5

    qg = q.reshape(B, Sq, K, G, hd)
    kpos = jnp.arange(Sk)

    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nq = qg.shape[1] // chunk
    qc = qg.reshape(B, nq, chunk, K, G, hd).transpose(1, 0, 2, 3, 4, 5)

    cdt = jnp.float32 if f32 else q.dtype
    neg = _NEG if f32 else -6.0e4  # bf16-safe mask value

    def one_chunk(ci, qblk):
        # qblk: (B, chunk, K, G, hd)
        qpos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qblk.astype(cdt),
                       k.astype(cdt)) * jnp.asarray(scale, cdt)
        m = _mask(qpos, kpos, causal=causal, window=window)
        s = jnp.where(m[None, None, None, :, :], s, jnp.asarray(neg, cdt))
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(cdt)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(cdt))
        return o.astype(q.dtype)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(nq), qc))          # (nq, B, chunk, K, G, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * chunk, K, G, hd)
    if pad:
        out = out[:, :Sq]
    return out.reshape(B, Sq, H, hd)


def gqa_decode(
    q: jnp.ndarray,            # (B, 1, H, hd)
    k_cache: jnp.ndarray,      # (B, S, K, hd)
    v_cache: jnp.ndarray,      # (B, S, K, hd)
    cur_pos,                   # scalar or (B,): index of each new token
    *,
    window=None,
    ring: bool = False,
    scale: float | None = None,
    service=None,
) -> jnp.ndarray:
    """One-token attention against a filled cache (positions <= cur_pos).

    ``ring=True`` treats the cache as a circular buffer of the last S tokens
    (windowed-KV layout: slot j holds absolute position cur_pos - ((cur_pos -
    j) mod S)), so sliding-window archs cache O(window) instead of O(seq) —
    how the 500k-decode cell fits. ``cur_pos`` may be a (B,) vector
    (continuous batching: per-sequence positions). ``service`` routes the
    call through the tuned ``decode_attention`` dispatch entry when the
    window is statically known (see blocks.attn_layer_decode's gating)."""
    # the dispatch path: a traced per-layer window scalar cannot fold into
    # the static signature, so callers gate on the arch having no windowed
    # layers; custom scales stay on the einsum path for exact-variant parity
    if service is not None and scale is None \
            and (window is None or isinstance(window, int)):
        out = _service_decode(q, k_cache, v_cache, cur_pos, ring=ring,
                              window=window, service=service)
        if out is not None:
            return out
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else hd ** -0.5

    qg = q.reshape(B, K, G, hd)
    # bf16 Q/K stay in their storage dtype: preferred_element_type makes the
    # contraction accumulate in f32 on the MXU without materializing f32
    # copies of the cache in the decode hot loop
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(S)
    # (B|1, 1) per-row positions: scalar cur_pos keeps its original (1, S)
    # broadcast semantics bit-for-bit; a (B,) vector masks each row by its
    # own position
    cpb = jnp.asarray(cur_pos).reshape(-1)[:, None]
    if ring:
        kpos = cpb - jnp.mod(cpb - slots[None, :], S)  # absolute positions
    else:
        kpos = jnp.broadcast_to(slots[None, :], (cpb.shape[0], S))
    valid = (kpos <= cpb) & (kpos >= 0)
    if window is not None:
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0, (cpb - kpos) < w, True)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    # probabilities drop to the cache dtype (flash-style) so the PV
    # contraction also runs without an f32 copy of V; accumulation stays f32
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)
