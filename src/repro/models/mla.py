"""DeepSeek-V2 Multi-head Latent Attention (MLA).

KV state is compressed to a per-token latent (kv_lora_rank=512) plus a
shared rotary key (qk_rope_dim=64); queries go through their own low-rank
bottleneck (q_lora_rank). Decode supports two schedules:

  * ``absorb=False`` — the faithful naive path: cached latents are
    up-projected to per-head K/V every step (paper-equivalent reference);
  * ``absorb=True``  — the matrix-absorption schedule: W_UK is folded into
    the query and W_UV applied after attention, so decode attends directly
    over the 576-wide latent cache. This is a *schedule* change with
    identical math — exactly the class of transformation the autotuning
    framework searches over, and one of our §Perf hillclimb moves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm, rope

__all__ = ["init_mla", "mla_attention", "mla_decode", "init_mla_cache"]

_NEG = -1.0e30


def init_mla(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, cfg.q_lora_rank), 0, dtype),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], (cfg.q_lora_rank, H * qd), 0, dtype),
        "wkv_a": dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), 0, dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), jnp.float32),
        "wkv_b": dense_init(
            ks[3], (cfg.kv_lora_rank, H * (cfg.qk_nope_dim + cfg.v_head_dim)), 0, dtype
        ),
        "wo": dense_init(ks[4], (H * cfg.v_head_dim, d), 0, dtype),
    }


def _project_q(p, x, cfg, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    q = rms_norm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = rope(q[..., cfg.qk_nope_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, x, cfg, positions):
    c = x @ p["wkv_a"]
    c_kv = rms_norm(c[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = c[..., None, cfg.kv_lora_rank :]          # (B, S, 1, rope)
    k_rope = rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _up_kv(p, c_kv, cfg):
    B, S, _ = c_kv.shape
    H = cfg.n_heads
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, cfg.qk_nope_dim + cfg.v_head_dim)
    return kv[..., : cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim :]  # k_nope, v


def mla_attention(p: dict, x: jnp.ndarray, cfg, positions, chunk: int = 512) -> jnp.ndarray:
    """Training/prefill MLA with causal masking (chunked over queries)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5

    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _project_kv_latent(p, x, cfg, positions)
    k_nope, v = _up_kv(p, c_kv, cfg)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, cfg.qk_rope_dim))],
        axis=-1,
    )

    chunk = min(chunk, S)
    pad = (-S) % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    nq = qp.shape[1] // chunk
    qc = qp.reshape(B, nq, chunk, H, -1).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(S)

    def one_chunk(ci, qblk):
        qpos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bshd->bhqs", qblk.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, _NEG)
        pr = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", pr, v.astype(jnp.float32)).astype(x.dtype)

    out = jax.lax.map(lambda a: one_chunk(*a), (jnp.arange(nq), qc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * chunk, H, cfg.v_head_dim)
    if pad:
        out = out[:, :S]
    return out.reshape(B, S, H * cfg.v_head_dim) @ p["wo"]


def init_mla_cache(cfg, B: int, S: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((B, S, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((B, S, cfg.qk_rope_dim), dtype),
    }


def mla_decode(p: dict, x: jnp.ndarray, cache: dict, cfg, pos,
               absorb: bool = True) -> tuple[jnp.ndarray, dict]:
    """One-token MLA decode. x: (B, 1, d); pos: scalar index."""
    B = x.shape[0]
    H = cfg.n_heads
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    positions = jnp.full((B, 1), pos)

    q_nope, q_rope = _project_q(p, x, cfg, positions)      # (B,1,H,*)
    c_new, kr_new = _project_kv_latent(p, x, cfg, positions)

    cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0)),
    }
    c_kv, k_rope = cache["c_kv"], cache["k_rope"]
    S = c_kv.shape[1]
    valid = jnp.arange(S)[None, :] <= pos                  # (1, S)

    wkv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, H, cfg.qk_nope_dim + cfg.v_head_dim)
    w_uk = wkv_b[..., : cfg.qk_nope_dim]                   # (lora, H, nope)
    w_uv = wkv_b[..., cfg.qk_nope_dim :]                   # (lora, H, v)

    if absorb:
        # fold W_UK into q; attend over the latent cache directly
        q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))       # (B,1,H,lora)
        s = jnp.einsum("bqhl,bsl->bhqs", q_eff, c_kv.astype(jnp.float32))
        s += jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
        s = jnp.where(valid[:, None, None, :], s * scale, _NEG)
        pr = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhqs,bsl->bqhl", pr, c_kv.astype(jnp.float32))
        o = jnp.einsum("bqhl,lhv->bqhv", lat, w_uv.astype(jnp.float32))
    else:
        # naive: up-project the whole cache each step
        k_nope, v = _up_kv(p, c_kv, cfg)                   # (B,S,H,*)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, cfg.qk_rope_dim))], axis=-1)
        s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        s = jnp.where(valid[:, None, None, :], s, _NEG)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshv->bqhv", pr, v.astype(jnp.float32))

    out = o.reshape(B, 1, H * cfg.v_head_dim).astype(x.dtype) @ p["wo"]
    return out, cache
