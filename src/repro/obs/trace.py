"""Span tracing: append-only Chrome-trace-event JSONL.

Each line of the trace file is one Chrome trace event object (complete
``"ph": "X"`` spans with microsecond ``ts``/``dur``, ``"i"`` instants, and
``"M"`` metadata), so the file is simultaneously valid JSONL — crash-safe,
torn-tail tolerant via :mod:`repro.core.jsonl`, greppable line by line — and
trivially convertible to a Perfetto/``chrome://tracing``-loadable
``{"traceEvents": [...]}`` JSON via :func:`export_chrome_trace` (or
``repro-obs summarize --perfetto out.json``).

Tracing is off by default: :func:`get_tracer` returns :data:`NULL_TRACER`
(whose ``span()`` hands back a shared no-op context manager, so instrumented
hot paths pay one attribute check) unless :func:`configure_tracer` was called
or the ``REPRO_TRACE=path`` environment variable names a trace file. One
timeline covers every instrumented layer — campaign ask/evaluate/tell,
database checkpoints, dispatch lookup/build/execute/quarantine, background
tuner campaigns/publishes, fleet pull/merge/push — because they all write
through the same process tracer with per-thread ``tid``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Iterator

from repro.core.jsonl import repair_torn_tail

__all__ = [
    "Tracer",
    "NULL_TRACER",
    "get_tracer",
    "configure_tracer",
    "span",
    "instant",
    "iter_trace",
    "validate_trace",
    "export_chrome_trace",
]

TRACE_ENV = "REPRO_TRACE"


class _Span:
    __slots__ = ("_tracer", "_name", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = self._tracer._now_us()
        ev = {
            "name": self._name,
            "cat": "repro",
            "ph": "X",
            "ts": self._t0,
            "dur": max(0, t1 - self._t0),
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if self._attrs:
            ev["args"] = self._attrs
        if exc_type is not None:
            ev.setdefault("args", {})["error"] = exc_type.__name__
        self._tracer.emit(ev)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every operation is a no-op."""

    enabled = False
    path = None

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **attrs) -> None:
        pass

    def emit(self, event: dict) -> None:
        pass

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Appends one trace event per line to ``path``. Thread-safe (one lock
    around the file write); timestamps are wall-clock-anchored microseconds
    advanced by ``perf_counter`` so same-host traces align across processes."""

    enabled = True

    def __init__(self, path: str, process_name: str | None = None):
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        repair_torn_tail(path)
        self.path = path
        self._f = open(path, "a")
        self._lock = threading.Lock()
        self._wall_us0 = time.time_ns() // 1000
        self._perf0 = time.perf_counter()
        if process_name:
            self.emit({"name": "process_name", "ph": "M", "ts": self._wall_us0,
                       "pid": os.getpid(), "tid": 0,
                       "args": {"name": process_name}})

    def _now_us(self) -> int:
        return self._wall_us0 + int((time.perf_counter() - self._perf0) * 1e6)

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        ev = {"name": name, "cat": "repro", "ph": "i", "s": "t",
              "ts": self._now_us(), "pid": os.getpid(),
              "tid": threading.get_ident()}
        if attrs:
            ev["args"] = attrs
        self.emit(ev)

    def emit(self, event: dict) -> None:
        line = json.dumps(event, default=str) + "\n"
        with self._lock:
            f = self._f
            if f is None or f.closed:
                return  # closed tracer: drop, never raise on a serving path
            f.write(line)
            f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None and not self._f.closed:
                self._f.close()


# -- process-wide default tracer -------------------------------------------------

_tracer: Tracer | NullTracer | None = None
_tracer_lock = threading.Lock()


def get_tracer() -> "Tracer | NullTracer":
    """The process tracer: configured one, else ``REPRO_TRACE`` env
    activation, else the shared no-op."""
    global _tracer
    t = _tracer
    if t is not None:
        return t
    with _tracer_lock:
        if _tracer is None:
            path = os.environ.get(TRACE_ENV)
            _tracer = Tracer(path) if path else NULL_TRACER
        return _tracer


def configure_tracer(path: "str | Tracer | None",
                     process_name: str | None = None) -> "Tracer | NullTracer":
    """Set the process tracer (a path, a ready Tracer, or None to disable).
    Returns the active tracer."""
    global _tracer
    with _tracer_lock:
        if _tracer is not None and _tracer.enabled:
            _tracer.close()
        if path is None:
            _tracer = NULL_TRACER
        elif isinstance(path, (Tracer, NullTracer)):
            _tracer = path
        else:
            _tracer = Tracer(path, process_name=process_name)
        return _tracer


def span(name: str, **attrs):
    """``with obs.span("campaign.ask", learner="RF"): ...`` through the
    process tracer (no-op unless tracing is enabled)."""
    return get_tracer().span(name, **attrs)


def instant(name: str, **attrs) -> None:
    get_tracer().instant(name, **attrs)


# -- validation / export ---------------------------------------------------------

_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def iter_trace(path: str) -> Iterator[dict]:
    """Parsed events, one per valid line; blank/torn/garbage lines skipped."""
    if not os.path.exists(path):
        return
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(ev, dict):
                yield ev


def validate_trace(path: str) -> dict:
    """Structural check of a trace file: every parseable line must be a
    Chrome trace event (required keys present, ``X`` spans carry ``dur``).
    Returns ``{"ok", "events", "invalid", "skipped", "names"}`` — ``ok`` is
    False when the file is missing/empty or any *parsed* event is malformed.
    Unparseable lines (a torn tail from a killed writer) are counted in
    ``skipped`` and do not fail validation: the JSONL contract is that a
    torn fragment stays an isolated bad line, never corrupts its neighbors."""
    events = 0
    invalid = 0
    skipped = 0
    names: set[str] = set()
    if not os.path.exists(path):
        return {"ok": False, "events": 0, "invalid": 0, "skipped": 0, "names": []}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                skipped += 1
                continue
            if not isinstance(ev, dict) or not all(k in ev for k in _REQUIRED) \
                    or (ev["ph"] == "X" and "dur" not in ev):
                invalid += 1
                continue
            events += 1
            names.add(str(ev["name"]))
    return {
        "ok": events > 0 and invalid == 0,
        "events": events,
        "invalid": invalid,
        "skipped": skipped,
        "names": sorted(names),
    }


def export_chrome_trace(src: str, out: str) -> int:
    """Wrap trace JSONL into a ``{"traceEvents": [...]}`` JSON file that
    Perfetto / ``chrome://tracing`` loads directly. Returns event count."""
    events = [ev for ev in iter_trace(src)
              if all(k in ev for k in _REQUIRED)]
    parent = os.path.dirname(out)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)
