"""repro.obs — unified observability: metrics, tracing, exposition.

Three layers, each usable alone:

* :mod:`repro.obs.metrics` — a process-wide :class:`MetricsRegistry` of
  counters, gauges, and log-bucketed latency histograms with *fixed* bucket
  boundaries, so histograms merge deterministically across threads, processes,
  and hosts. Recording is lock-free (per-thread shards); folding happens only
  at snapshot time.
* :mod:`repro.obs.trace` — span-based tracing writing append-only
  Chrome-trace-event JSONL (one event per line). Off by default; enable with
  ``configure_tracer(path)`` or the ``REPRO_TRACE=path`` environment
  variable. ``repro-obs summarize --perfetto out.json`` wraps the JSONL into
  a Perfetto-loadable ``{"traceEvents": [...]}`` file.
* :mod:`repro.obs.export` — JSONL snapshot writer, Prometheus text
  exposition, and the stdlib-``http.server`` :class:`ObsServer` serving
  ``/metrics`` + ``/snapshot``.

The serving/tuning stack (``repro.dispatch``, ``repro.engine``,
``repro.fleet``) records into the default registry and traces through the
default tracer; see README "Observability" for the metric names and label
schema.
"""

from repro.obs.metrics import (
    BUCKET_BOUNDS,
    Histogram,
    MetricsRegistry,
    get_registry,
    histogram_quantile,
    merge_snapshots,
    set_registry,
    summarize_histograms,
)
from repro.obs.trace import (
    NULL_TRACER,
    Tracer,
    configure_tracer,
    export_chrome_trace,
    get_tracer,
    span,
    validate_trace,
)
from repro.obs.export import (
    ObsServer,
    prometheus_text,
    read_snapshot_file,
    write_snapshot,
)

__all__ = [
    "BUCKET_BOUNDS",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "histogram_quantile",
    "merge_snapshots",
    "summarize_histograms",
    "Tracer",
    "NULL_TRACER",
    "configure_tracer",
    "get_tracer",
    "span",
    "validate_trace",
    "export_chrome_trace",
    "ObsServer",
    "prometheus_text",
    "write_snapshot",
    "read_snapshot_file",
]
