"""Exposition: JSONL snapshots, Prometheus text format, and an HTTP endpoint.

* :func:`write_snapshot` appends one self-describing line (host/pid/time +
  the full registry snapshot) to a JSONL file — the cross-process handoff
  format: a benchmark or serving process writes, ``repro-obs`` reads and
  merges (bucket boundaries are fixed, so merging is exact).
* :func:`prometheus_text` renders a snapshot in the Prometheus text
  exposition format (``_bucket{le=...}`` cumulative histograms, ``_sum`` /
  ``_count``), with every metric name prefixed ``repro_``.
* :class:`ObsServer` mounts ``GET /metrics`` (Prometheus text) and
  ``GET /snapshot`` (JSON) on the same stdlib ``http.server`` pattern as the
  fleet's :class:`~repro.fleet.http.FleetServer` — which also gained a
  ``/metrics`` route, so a fleet-serving host is scrapeable without a second
  port.
"""

from __future__ import annotations

import json
import os
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Mapping

from repro.core.jsonl import append_jsonl, repair_torn_tail
from repro.obs.metrics import (
    BUCKET_BOUNDS,
    MetricsRegistry,
    get_registry,
    merge_snapshots,
)

__all__ = [
    "write_snapshot",
    "read_snapshot_file",
    "prometheus_text",
    "ObsServer",
]


def write_snapshot(path: str, registry: MetricsRegistry | None = None,
                   **meta) -> dict:
    """Append one snapshot line ``{"time", "host", "pid", **meta,
    "snapshot": {...}}`` to ``path``; returns the line written."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    repair_torn_tail(path)
    line = {
        "time": time.time(),
        "host": socket.gethostname(),
        "pid": os.getpid(),
        **meta,
        "snapshot": (registry or get_registry()).snapshot(),
    }
    append_jsonl(path, line)
    return line


def read_snapshot_file(path: str, merge: bool = True) -> dict | list[dict]:
    """Load a snapshot JSONL file. ``merge=True`` (default) folds every line
    into one merged snapshot; ``merge=False`` returns the raw lines."""
    lines: list[dict] = []
    if os.path.exists(path):
        with open(path) as f:
            for raw in f:
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    obj = json.loads(raw)
                except json.JSONDecodeError:
                    continue  # torn tail
                if isinstance(obj, dict) and "snapshot" in obj:
                    lines.append(obj)
    if not merge:
        return lines
    return merge_snapshots(*(line["snapshot"] for line in lines))


# -- Prometheus text exposition ---------------------------------------------------


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_str(labels: Mapping[str, str], extra: str | None = None) -> str:
    parts = [f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v: float) -> str:
    return repr(float(v)) if v != int(v) else str(int(v))


def prometheus_text(snapshot: Mapping[str, Any] | None = None,
                    registry: MetricsRegistry | None = None,
                    prefix: str = "repro_") -> str:
    """Render a snapshot (or a live registry's snapshot) as Prometheus text
    exposition. Histograms emit cumulative ``_bucket{le=...}`` series plus
    ``_sum``/``_count``, matching the fixed log2 bucket schema."""
    if snapshot is None:
        snapshot = (registry or get_registry()).snapshot()
    out: list[str] = []
    by_name: dict[str, list[dict]] = {}
    for kind in ("counters", "gauges", "histograms"):
        for row in snapshot.get(kind, []):
            by_name.setdefault((kind, row["name"]), []).append(row)
    for (kind, name), rows in sorted(by_name.items()):
        full = prefix + name
        ptype = {"counters": "counter", "gauges": "gauge",
                 "histograms": "histogram"}[kind]
        out.append(f"# TYPE {full} {ptype}")
        for row in rows:
            labels = row["labels"]
            if kind in ("counters", "gauges"):
                out.append(f"{full}{_labels_str(labels)} {_fmt(row['value'])}")
                continue
            cum = 0
            for i, c in enumerate(row["counts"]):
                cum += int(c)
                le = _fmt(BUCKET_BOUNDS[i]) if i < len(BUCKET_BOUNDS) else "+Inf"
                le_label = 'le="' + le + '"'
                out.append(f"{full}_bucket{_labels_str(labels, le_label)} {cum}")
            out.append(f"{full}_sum{_labels_str(labels)} {repr(float(row['sum']))}")
            out.append(f"{full}_count{_labels_str(labels)} {int(row['count'])}")
    return "\n".join(out) + "\n"


# -- HTTP endpoint ----------------------------------------------------------------


class _ObsHandler(BaseHTTPRequestHandler):
    source: Callable[[], dict]  # bound by ObsServer via subclassing

    def log_message(self, *args):  # quiet: scraping must not spam stderr
        pass

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):  # noqa: N802 — http.server API
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            snap = type(self).source()
            self._send(200, prometheus_text(snap).encode(),
                       "text/plain; version=0.0.4")
        elif path == "/snapshot":
            self._send(200, json.dumps(type(self).source()).encode(),
                       "application/json")
        else:
            self._send(404, b'{"error": "not found"}', "application/json")


class ObsServer:
    """Threaded ``/metrics`` + ``/snapshot`` endpoint. Serves the default
    registry unless given an explicit ``registry`` or a ``source`` callable
    (e.g. a lambda re-reading a snapshot file, for ``repro-obs serve``).
    ``port=0`` picks a free port — read it back from ``.port``."""

    def __init__(self, registry: MetricsRegistry | None = None,
                 source: Callable[[], dict] | None = None,
                 host: str = "127.0.0.1", port: int = 0):
        if source is None:
            source = lambda: (registry or get_registry()).snapshot()  # noqa: E731
        handler = type("BoundObsHandler", (_ObsHandler,),
                       {"source": staticmethod(source)})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ObsServer":
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="repro-obs-http",
                daemon=True)
            self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._httpd.serve_forever()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10)
