"""Metrics core: counters, gauges, and log-bucketed latency histograms.

Design constraints, in priority order:

1. **Recording must be lock-free.** The dispatch fast-hit path is pinned (by
   test) to exactly one lock acquisition; metric recording therefore goes to
   a per-thread shard — a plain dict owned by one thread — and shards are
   folded under the registry lock only at :meth:`MetricsRegistry.snapshot`
   time. The only locked operation on a recording path is the one-time shard
   registration when a thread records its first metric.
2. **Histograms must merge deterministically.** Bucket boundaries are a
   fixed module-level constant (log2-spaced, ~1µs to ~256s), so merging two
   histograms — across threads, processes, or hosts — is element-wise count
   addition: associative, commutative, and schema-free. This mirrors the
   fleet oplog's order-independent merge contract.
3. **Snapshots are plain JSON.** ``snapshot()`` returns a dict that
   round-trips through ``json`` unchanged, so the same structure is the
   in-process view, the JSONL snapshot line, and the cross-host merge input.

Recording concurrently with ``snapshot()`` is safe (CPython dict/int ops are
atomic under the GIL) but a mid-record fold may observe a histogram whose
``count`` includes an observation whose ``sum`` does not yet — totals are
exact once the recording threads quiesce, which is what the concurrency test
pins.
"""

from __future__ import annotations

import bisect
import itertools
import threading
from typing import Any, Iterable, Mapping

__all__ = [
    "BUCKET_BOUNDS",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "histogram_quantile",
    "merge_snapshots",
    "summarize_histograms",
]

SCHEMA = "repro.obs/1"

# Fixed for all time: log2-spaced upper bounds in seconds, ~0.95µs .. 256s,
# plus an implicit +Inf bucket. Changing these breaks cross-version snapshot
# merging — add a new schema instead.
BUCKET_BOUNDS: tuple[float, ...] = tuple(2.0 ** e for e in range(-20, 9))

LabelKey = tuple  # ((k, v), ...) sorted


def _label_key(labels: Mapping[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Histogram:
    """One histogram cell: per-bucket counts over :data:`BUCKET_BOUNDS`
    (+Inf last), plus exact ``sum`` and ``count``."""

    __slots__ = ("counts", "sum", "count")

    def __init__(self):
        self.counts = [0] * (len(BUCKET_BOUNDS) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(BUCKET_BOUNDS, value)] += 1
        self.sum += value
        self.count += 1

    def merge(self, other: "Histogram | Mapping[str, Any]") -> "Histogram":
        counts = other["counts"] if isinstance(other, Mapping) else other.counts
        osum = other["sum"] if isinstance(other, Mapping) else other.sum
        ocount = other["count"] if isinstance(other, Mapping) else other.count
        for i, c in enumerate(counts):
            self.counts[i] += int(c)
        self.sum += float(osum)
        self.count += int(ocount)
        return self

    def quantile(self, q: float) -> float:
        return histogram_quantile(self.counts, q)

    def to_json(self) -> dict:
        return {"counts": list(self.counts), "sum": self.sum, "count": self.count}


def histogram_quantile(counts: Iterable[int], q: float) -> float:
    """Prometheus-style quantile estimate from cumulative bucket walk with
    linear interpolation inside the winning bucket. The +Inf bucket clamps
    to the largest finite boundary. NaN for an empty histogram."""
    counts = list(counts)
    total = sum(counts)
    if total <= 0:
        return float("nan")
    rank = q * total
    cum = 0.0
    for i, c in enumerate(counts):
        if c <= 0:
            continue
        if cum + c >= rank:
            lo = BUCKET_BOUNDS[i - 1] if i > 0 else 0.0
            if i >= len(BUCKET_BOUNDS):  # +Inf bucket
                return BUCKET_BOUNDS[-1]
            hi = BUCKET_BOUNDS[i]
            return lo + (hi - lo) * max(0.0, min(1.0, (rank - cum) / c))
        cum += c
    return BUCKET_BOUNDS[-1]


class _Shard:
    """One thread's private metric cells. Never locked: only its owner
    writes, and snapshot-time readers tolerate a torn in-flight update."""

    __slots__ = ("counters", "gauges", "hists")

    def __init__(self):
        self.counters: dict[tuple, float] = {}
        self.gauges: dict[tuple, tuple[int, float]] = {}  # key -> (seq, value)
        self.hists: dict[tuple, Histogram] = {}


class MetricsRegistry:
    """Process-wide metric store; see module docstring for the sharding and
    merge contracts. All three record methods take ``**labels`` keyword
    label pairs; values are stringified (shape-signature keys, learner
    names, kernel names all pass through unchanged)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._local = threading.local()
        self._shards: list[_Shard] = []
        # shared monotonic stamp: last-write-wins gauge folding across shards
        self._gauge_seq = itertools.count(1)

    # -- recording (lock-free after first use per thread) ------------------------

    def _shard(self) -> _Shard:
        shard = getattr(self._local, "shard", None)
        if shard is None:
            shard = _Shard()
            with self._lock:  # once per (thread, registry) lifetime
                self._shards.append(shard)
            self._local.shard = shard
        return shard

    def add(self, name: str, value: float = 1.0, **labels) -> None:
        """Increment a counter."""
        key = (name, _label_key(labels))
        counters = self._shard().counters
        counters[key] = counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        """Set a gauge (last write wins across threads, by global seq)."""
        self._shard().gauges[(name, _label_key(labels))] = (
            next(self._gauge_seq), float(value))

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one observation into a log-bucketed histogram."""
        key = (name, _label_key(labels))
        hists = self._shard().hists
        h = hists.get(key)
        if h is None:
            h = hists[key] = Histogram()
        h.observe(value)

    # -- folding -----------------------------------------------------------------

    def snapshot(self) -> dict:
        """Fold every shard into one JSON-safe snapshot (sorted, so equal
        states serialize identically)."""
        counters: dict[tuple, float] = {}
        gauges: dict[tuple, tuple[int, float]] = {}
        hists: dict[tuple, Histogram] = {}
        with self._lock:
            shards = list(self._shards)
        for shard in shards:
            for key, v in list(shard.counters.items()):
                counters[key] = counters.get(key, 0.0) + v
            for key, (seq, v) in list(shard.gauges.items()):
                if key not in gauges or seq > gauges[key][0]:
                    gauges[key] = (seq, v)
            for key, h in list(shard.hists.items()):
                tgt = hists.get(key)
                if tgt is None:
                    tgt = hists[key] = Histogram()
                tgt.merge(h)
        return {
            "schema": SCHEMA,
            "buckets": list(BUCKET_BOUNDS),
            "counters": [
                {"name": n, "labels": dict(lk), "value": counters[(n, lk)]}
                for n, lk in sorted(counters)],
            "gauges": [
                {"name": n, "labels": dict(lk), "value": gauges[(n, lk)][1]}
                for n, lk in sorted(gauges)],
            "histograms": [
                {"name": n, "labels": dict(lk), **hists[(n, lk)].to_json()}
                for n, lk in sorted(hists)],
        }


def merge_snapshots(*snaps: Mapping[str, Any]) -> dict:
    """Deterministic snapshot merge: counters and histograms sum, gauges are
    last-write-wins in argument order. Associative and commutative for
    counters/histograms (the property test pins this); raises on mismatched
    bucket schemas rather than silently mixing them."""
    counters: dict[tuple, float] = {}
    gauges: dict[tuple, float] = {}
    hists: dict[tuple, Histogram] = {}
    for snap in snaps:
        if list(snap.get("buckets", BUCKET_BOUNDS)) != list(BUCKET_BOUNDS):
            raise ValueError("snapshot bucket schema mismatch")
        for c in snap.get("counters", []):
            key = (c["name"], _label_key(c["labels"]))
            counters[key] = counters.get(key, 0.0) + float(c["value"])
        for g in snap.get("gauges", []):
            gauges[(g["name"], _label_key(g["labels"]))] = float(g["value"])
        for hrow in snap.get("histograms", []):
            key = (hrow["name"], _label_key(hrow["labels"]))
            tgt = hists.get(key)
            if tgt is None:
                tgt = hists[key] = Histogram()
            tgt.merge(hrow)
    return {
        "schema": SCHEMA,
        "buckets": list(BUCKET_BOUNDS),
        "counters": [{"name": n, "labels": dict(lk), "value": counters[(n, lk)]}
                     for n, lk in sorted(counters)],
        "gauges": [{"name": n, "labels": dict(lk), "value": gauges[(n, lk)]}
                   for n, lk in sorted(gauges)],
        "histograms": [{"name": n, "labels": dict(lk), **hists[(n, lk)].to_json()}
                       for n, lk in sorted(hists)],
    }


def summarize_histograms(
    snapshot: Mapping[str, Any],
    name: str | None = None,
    prefix: str | None = None,
) -> list[dict]:
    """Per-cell ``{name, labels, count, sum, p50, p99}`` rows for the
    histograms in a snapshot, filtered by exact ``name`` or ``prefix``."""
    out = []
    for h in snapshot.get("histograms", []):
        if name is not None and h["name"] != name:
            continue
        if prefix is not None and not h["name"].startswith(prefix):
            continue
        counts = h["counts"]
        out.append({
            "name": h["name"],
            "labels": dict(h["labels"]),
            "count": int(h["count"]),
            "sum": float(h["sum"]),
            "p50": histogram_quantile(counts, 0.50),
            "p99": histogram_quantile(counts, 0.99),
        })
    return out


# -- process-wide default registry ----------------------------------------------

_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _registry


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process default (tests use this for isolation)."""
    global _registry
    _registry = registry
    return registry
