"""Training step: loss + grad + AdamW, with microbatch gradient accumulation
(lax.scan) and selectable remat policy — the two step-level knobs the
autotuner searches in the §Perf hillclimb."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.model import loss_fn
from repro.train.optim import adamw_init, adamw_update

__all__ = ["make_train_step", "init_train_state"]


def init_train_state(params, moment_dtype=jnp.float32):
    return adamw_init(params, moment_dtype)


def make_train_step(cfg: ArchConfig, *, lr: float = 3e-4, accum: int = 1,
                    remat: str = "none", attn_chunk: int = 512,
                    ssm_chunk: int = 64, weight_decay: float = 0.1,
                    act_spec=None, logits_spec=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``accum`` > 1 splits the batch into microbatches and accumulates
    grads in f32 via lax.scan (compute stays per-microbatch, memory drops)."""

    loss = functools.partial(loss_fn, cfg=cfg, remat=remat,
                             attn_chunk=attn_chunk, ssm_chunk=ssm_chunk,
                             act_spec=act_spec, logits_spec=logits_spec)

    def split_micro(batch):
        def r(x):
            b = x.shape[0]
            assert b % accum == 0, (b, accum)
            return x.reshape(accum, b // accum, *x.shape[1:])
        return jax.tree_util.tree_map(r, batch)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
        else:
            micro = split_micro(batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (tot, met), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + met["loss"]), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            metrics = {"loss": lsum / accum, "aux": jnp.zeros(())}
            total = metrics["loss"]

        params, opt_state = adamw_update(grads, opt_state, params, lr=lr,
                                         weight_decay=weight_decay)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        metrics = dict(metrics, total=total, grad_norm=gnorm,
                       step=opt_state["step"])
        return params, opt_state, metrics

    return train_step
