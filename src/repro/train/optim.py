"""AdamW optimizer (pure pytree transform) + LR schedules.

Optimizer moments live in f32 and inherit the parameter sharding, so with
FSDP-sharded params this is ZeRO-style sharded optimizer state for free.
A ``moment_dtype`` knob trades moment precision for HBM (a distributed-
optimization trick the §Perf loop can flip)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr", "linear_warmup_lr"]


@dataclasses.dataclass
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32


def adamw_init(params, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _decay_mask(path) -> bool:
    """Decay only matrices (no norms / biases / 1-D vectors)."""
    return True


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + eps)
        if p.ndim >= 2:  # weight decay on matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def linear_warmup_lr(step, *, peak: float, warmup: int):
    return peak * jnp.minimum(1.0, (step + 1) / warmup)


def cosine_lr(step, *, peak: float, warmup: int, total: int, floor: float = 0.1):
    warm = (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return peak * jnp.where(step < warmup, warm, cos)
