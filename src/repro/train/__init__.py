"""repro.train — optimizer + training step."""

from repro.train.optim import adamw_init, adamw_update, cosine_lr, linear_warmup_lr
from repro.train.step import init_train_state, make_train_step

__all__ = ["adamw_init", "adamw_update", "cosine_lr", "linear_warmup_lr",
           "init_train_state", "make_train_step"]
