"""repro.parallel — sharding rules and collective utilities."""

from repro.parallel.sharding import (
    ShardingProfile,
    batch_specs,
    cache_specs,
    make_profile,
    mesh_axis_size,
    named,
    param_specs,
)

__all__ = [
    "ShardingProfile", "batch_specs", "cache_specs", "make_profile",
    "mesh_axis_size", "named", "param_specs",
]
