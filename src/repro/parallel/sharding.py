"""Sharding rules: logical layout -> NamedSharding for params, batches,
optimizer state, and caches, per mesh and per shape-kind profile.

Layout policy (1000+-node posture):

  * ``model`` axis — tensor parallelism: attention heads / FFN columns /
    expert hidden dims / vocab (Megatron 2-collective pattern);
  * ``data`` (+ ``pod``) axes — batch parallelism AND parameter storage
    sharding (FSDP/ZeRO-3): weight matrices shard their contraction dim over
    the fsdp axes, XLA SPMD inserts the all-gathers at use and reduce-
    scatters on the gradients. Optimizer state inherits the param sharding
    (ZeRO);
  * experts shard over the largest divisible combination of (pod, data),
    falling back to FSDP on d_model when E doesn't divide (mixtral's 8
    experts on a 16-wide data axis);
  * decode profiles shard batch over (pod, data); the batch=1 long-context
    profile parks everything on model/fsdp axes instead (documented in
    EXPERIMENTS.md — 500k single-stream decode is a deliberately lopsided
    stress cell).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig

__all__ = ["ShardingProfile", "make_profile", "param_specs", "batch_specs",
           "cache_specs", "named", "mesh_axis_size"]


def _ax(a):
    """Canonical PartitionSpec entry: a singleton axis tuple means the same
    sharding as the bare axis name — unwrap it so specs compare cleanly."""
    if isinstance(a, tuple) and len(a) == 1:
        return a[0]
    return a


def mesh_axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclasses.dataclass(frozen=True)
class ShardingProfile:
    """Resolved layout choice for one (mesh, shape-kind, batch) cell."""

    batch_axes: tuple          # shards the global batch dimension
    fsdp_axes: tuple           # shards parameter storage (ZeRO-3)
    tp_axis: str = "model"

    def batch_spec(self, extra_dims: int = 1) -> P:
        return P(_ax(self.batch_axes) if self.batch_axes else None,
                 *([None] * extra_dims))


def make_profile(mesh: Mesh, kind: str, global_batch: int) -> ShardingProfile:
    axes = list(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    dp = mesh_axis_size(mesh, dp_axes)

    if kind in ("train", "prefill"):
        batch_axes = dp_axes if global_batch % dp == 0 else _divisible_prefix(
            mesh, dp_axes, global_batch)
    else:  # decode / long
        batch_axes = _divisible_prefix(mesh, dp_axes, global_batch)
    return ShardingProfile(batch_axes=batch_axes, fsdp_axes=dp_axes)


def _divisible_prefix(mesh: Mesh, axes: tuple, n: int) -> tuple:
    """Largest leading subset of ``axes`` whose product divides n."""
    out: list = []
    prod = 1
    for a in axes:
        if n % (prod * mesh.shape[a]) == 0:
            out.append(a)
            prod *= mesh.shape[a]
        else:
            break
    return tuple(out)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_IN_TP = {"wo", "wd", "xo", "out_proj"}           # contraction dim is TP'd
_OUT_TP = {"wq", "wk", "wv", "wu", "wg", "xq", "xk", "xv", "in_proj",
           "wq_a", "wq_b", "wkv_a", "wkv_b"}       # output dim is TP'd


def _divides(n: int, mesh: Mesh, axes) -> bool:
    return n % mesh_axis_size(mesh, axes) == 0 if n else False


def _expert_axes(E: int, mesh: Mesh, profile: ShardingProfile):
    for cand in (profile.fsdp_axes, ("data",), ("pod",)):
        cand = tuple(a for a in cand if a in mesh.axis_names)
        if cand and _divides(E, mesh, cand):
            return cand
    return None


def _leaf_spec(path: tuple, shape: tuple, mesh: Mesh, profile: ShardingProfile,
               cfg: ArchConfig) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    stacked = "layers" in names[:-1] or "enc_layers" in names[:-1] \
        or "dec_layers" in names[:-1]
    tp = profile.tp_axis
    fsdp = profile.fsdp_axes or None
    rank = len(shape)
    body = rank - 1 if stacked else rank  # dims excluding the leading L

    def with_stack(spec_dims: list) -> P:
        spec_dims = [_ax(d) for d in spec_dims]
        return P(None, *spec_dims) if stacked else P(*spec_dims)

    # embeddings: (V, d) — vocab over tp, d over fsdp
    if leaf in ("embed",):
        return P(tp if _divides(shape[0], mesh, tp) else None,
                 _ax(fsdp) if _divides(shape[1], mesh, fsdp) else None)
    if leaf == "unembed":
        return P(_ax(fsdp) if _divides(shape[0], mesh, fsdp) else None,
                 tp if _divides(shape[1], mesh, tp) else None)

    # MoE expert tensors: (L?, E, d_in, d_out)
    if "moe" in names and leaf in ("wg", "wu", "wd") and body == 3:
        E, d_in, d_out = shape[-3:]
        ep = _expert_axes(E, mesh, profile)
        used_fsdp = ep == (profile.fsdp_axes or ())
        din_ax = None
        if not used_fsdp and _divides(d_in, mesh, fsdp):
            remaining = tuple(a for a in (profile.fsdp_axes or ()) if not ep or a not in ep)
            if remaining and _divides(d_in, mesh, remaining):
                din_ax = remaining
        if leaf == "wd":  # (E, eff, d): eff is the TP dim
            dims = [ep, tp if _divides(d_in, mesh, tp) else None, None]
        else:             # (E, d, eff)
            dims = [ep, din_ax, tp if _divides(d_out, mesh, tp) else None]
        return with_stack(dims)
    if leaf == "router":
        return with_stack([fsdp if _divides(shape[-2], mesh, fsdp) else None, None])

    # 2D projection matrices
    if body == 2 and leaf in _OUT_TP:
        d_in, d_out = shape[-2:]
        return with_stack([
            fsdp if _divides(d_in, mesh, fsdp) else None,
            tp if _divides(d_out, mesh, tp) else None,
        ])
    if body == 2 and leaf in _IN_TP:
        d_in, d_out = shape[-2:]
        return with_stack([
            tp if _divides(d_in, mesh, tp) else None,
            fsdp if _divides(d_out, mesh, fsdp) else None,
        ])
    if body == 2 and leaf == "conv_w":
        return with_stack([None, tp if _divides(shape[-1], mesh, tp) else None])

    # everything else (norm scales, biases, A_log, ...): replicate
    return with_stack([None] * body)


def param_specs(abstract_params: Any, mesh: Mesh, profile: ShardingProfile,
                cfg: ArchConfig):
    """PartitionSpec pytree mirroring the (abstract) parameter tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf.shape, mesh, profile, cfg),
        abstract_params,
    )


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch_abstract: Any, mesh: Mesh, profile: ShardingProfile):
    b = _ax(profile.batch_axes) if profile.batch_axes else None

    def spec(path, leaf):
        return P(b, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_abstract)


def cache_specs(cache_abstract: Any, mesh: Mesh, profile: ShardingProfile,
                cfg: ArchConfig):
    """KV caches: (L, B, S, K, hd) — batch over batch_axes, then K over tp if
    divisible else hd; MLA latents: (L, B, S, lora) — lora over tp; SSM state:
    (L, B, H, P, N) — H over tp."""
    b = _ax(profile.batch_axes) if profile.batch_axes else None
    tp = profile.tp_axis

    def spec(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        leafname = names[-1]
        shp = leaf.shape
        stacked = len(shp) >= 1 and any(
            n in ("layers", "dec_layers", "enc_layers", "cross", "shared_attn")
            for n in names)
        if leafname in ("k", "v"):
            L, B, S, K, hd = shp if stacked else ((1,) + shp)[-5:]
            head_ax = tp if K % mesh.shape[tp] == 0 else None
            hd_ax = tp if head_ax is None and hd % mesh.shape[tp] == 0 else None
            dims = [b, None, head_ax, hd_ax]
            return P(None, *dims) if stacked else P(*dims)
        if leafname in ("c_kv", "k_rope"):
            dims = [b, None, tp if shp[-1] % mesh.shape[tp] == 0 else None]
            return P(*([None] * (len(shp) - 3)), *dims)
        if leafname == "conv":
            dims = [b, None, tp if shp[-1] % mesh.shape[tp] == 0 else None]
            return P(*([None] * (len(shp) - 3)), *dims)
        if leafname == "ssm":
            dims = [b, tp if shp[-3] % mesh.shape[tp] == 0 else None, None, None]
            return P(*([None] * (len(shp) - 4)), *dims)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(spec, cache_abstract)
