"""Analytic TPU cost model for the Pallas kernels — backend B2's objective
when tuning kernel schedules without TPU hardware.

For a given kernel configuration the model derives, from the BlockSpec
geometry the kernel factory would use:

  * HBM traffic: sum over grid steps of the blocks each step moves HBM<->VMEM
    (exactly what pallas_call's index maps imply — revisited blocks with an
    unchanged index map within the innermost loop stay VMEM-resident);
  * VMEM footprint: all live blocks + scratch; configurations exceeding the
    per-core budget are infeasible (returned as +inf, which the search's
    failure handling penalizes — the OOM-compile analog);
  * MXU efficiency: matmul tiles are derated by how far each dim is from the
    128x128 systolic alignment (ceil waste), plus a VPU-only path for the
    min-plus kernel (no MXU for `min`);
  * modeled seconds = max(flop_time / mxu_eff, hbm_time) — the two-term
    kernel roofline.

Validated against brute-force tile sweeps in tests (monotonic in waste,
infeasible over budget, best-known tiles score near-optimal).
"""

from __future__ import annotations

import math
from typing import Mapping

from repro.kernels.util import cdiv
from repro.perf.roofline import HW

__all__ = ["kernel_cost", "KERNEL_COST_FNS", "VMEM_BYTES"]

VMEM_BYTES = 16 * 1024 * 1024  # v5e per-core VMEM budget (model constant)
_MXU = 128
_F32 = 4
_BF16 = 2


def _align_eff(*dims: int) -> float:
    """Fraction of MXU work that is useful when each dim pads to 128/8."""
    eff = 1.0
    for i, d in enumerate(dims):
        tile = _MXU if i >= len(dims) - 2 else 8
        eff *= d / (cdiv(d, tile) * tile)
    return max(eff, 1e-3)


def _mm_cost(M, N, K, bm, bn, bk, *, dtype_bytes=_F32, extra_vmem=0.0,
             flops_factor=2.0, mxu=True):
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    mi, nj, kk = cdiv(M, bm), cdiv(N, bn), cdiv(K, bk)
    # per (i, j): A and B tiles stream over k; C written once
    hbm = mi * nj * kk * (bm * bk + bk * bn) * dtype_bytes \
        + mi * nj * bm * bn * dtype_bytes
    vmem = (bm * bk + bk * bn + bm * bn) * dtype_bytes + bm * bn * _F32 + extra_vmem
    flops = flops_factor * M * N * K
    eff = _align_eff(bm, bn, bk) if mxu else 1.0
    peak = HW.peak_flops if mxu else HW.peak_flops / 40.0  # VPU ~ MXU/40
    t = max(flops / (peak * eff), hbm / HW.hbm_bw)
    return t, hbm, vmem, flops


def _finish(t, hbm, vmem, flops):
    if vmem > VMEM_BYTES:
        return float("inf"), {"infeasible": "vmem", "vmem_bytes": vmem}
    return t, {"hbm_bytes": hbm, "vmem_bytes": vmem, "flops": flops,
               "modeled_sec": t}


def syr2k_cost(cfg: Mapping, N: int, M: int):
    bi, bj, bk = int(cfg["bi"]), int(cfg["bj"]), int(cfg["bk"])
    # two rank-k products per C tile; packing adds scratch but no HBM
    t, hbm, vmem, flops = _mm_cost(N, N, M, bi, bj, bk, flops_factor=4.0)
    hbm *= 2  # A_i/B_j and B_i/A_j streams
    if cfg.get("pack_a"):
        vmem += bi * min(bk, M) * _F32
    if cfg.get("pack_b"):
        vmem += bi * min(bk, M) * _F32
    t = max(flops / (HW.peak_flops * _align_eff(bi, bj, bk)), hbm / HW.hbm_bw)
    return _finish(t, hbm, vmem, flops)


def mm3_cost(cfg: Mapping, P: int, Q: int, R: int, S: int, T: int):
    bm, bn, bk = int(cfg["bm"]), int(cfg["bn"]), int(cfg["bk"])
    tot_t, tot_hbm, max_vmem, tot_flops = 0.0, 0.0, 0.0, 0.0
    for (m, n, k) in ((P, R, Q), (R, T, S), (P, T, R)):
        t, hbm, vmem, flops = _mm_cost(m, n, k, bm, bn, bk)
        tot_t += t
        tot_hbm += hbm
        tot_flops += flops
        max_vmem = max(max_vmem, vmem)
    return _finish(tot_t, tot_hbm, max_vmem, tot_flops)


def lu_cost(cfg: Mapping, N: int):
    bs = int(cfg["bs"])
    bm, bn = int(cfg.get("bm", 128)), int(cfg.get("bn", 128))
    nb = cdiv(N, bs)
    t = hbm = flops = 0.0
    vmem = 0.0
    for step in range(nb):
        rem = N  # full-size masked panels (static shapes)
        tt, hh, vv, ff = _mm_cost(rem, rem, bs, bm, bn, bs)
        t += tt
        hbm += hh
        flops += ff
        vmem = max(vmem, vv)
        # panel solves: O(bs^2 * N) VPU work
        flops += 2 * bs * bs * N
        t += 2 * bs * bs * N / (HW.peak_flops / 40.0)
    return _finish(t, hbm, vmem, flops)


def heat3d_cost(cfg: Mapping, N: int, tsteps: int):
    bi, fuse = int(cfg["bi"]), int(cfg.get("fuse_t", 1))
    ni = cdiv(N, bi)
    passes = 2 * tsteps // fuse
    # each pass moves (bi + 2*fuse) input slabs + bi output slabs per block
    slab = N * N * _F32
    hbm = passes * ni * ((bi + 2 * fuse) + bi) * slab
    vmem = (3 * bi + 2 * fuse) * slab  # prev/cur/next + working rows
    flops = 2 * tsteps * N * N * N * 12  # ~12 flops/point/application
    t = max(flops / (HW.peak_flops / 40.0), hbm / HW.hbm_bw)  # VPU stencil
    return _finish(t, hbm, vmem, flops)


def covariance_cost(cfg: Mapping, N: int, M: int):
    bi, bj, bk = int(cfg["bi"]), int(cfg["bj"]), int(cfg["bk"])
    t, hbm, vmem, flops = _mm_cost(M, M, N, bi, bj, bk)
    if cfg.get("fuse_center", True):
        vmem += (bi + bj) * _F32  # mean tiles
    else:
        hbm += 2 * N * M * _F32   # separate centering pass
    t = max(flops / (HW.peak_flops * _align_eff(bi, bj, bk)), hbm / HW.hbm_bw)
    return _finish(t, hbm, vmem, flops)


def floyd_warshall_cost(cfg: Mapping, N: int):
    bs, bi, bj = int(cfg["bs"]), int(cfg["bi"]), int(cfg["bj"])
    unroll = int(cfg.get("unroll", 1))
    nb = cdiv(N, bs)
    # per round: diag closure + row/col panels + full phase-3 sweep
    t3, hbm3, vmem3, flops3 = _mm_cost(N, N, bs, bi, bj, bs, mxu=False)
    hbm = nb * (hbm3 + 2 * N * bs * _F32 + bs * bs * _F32)
    flops = nb * (flops3 + 2 * N * bs * bs + bs * bs * bs)
    vmem = vmem3 + 2 * bs * max(bi, bj) * _F32
    # unrolling the k-sweep amortizes loop overhead on the VPU (up to 8)
    vpu = HW.peak_flops / 40.0 * min(1.0, 0.6 + 0.1 * unroll)
    t = max(flops / vpu, hbm / HW.hbm_bw)
    return _finish(t, hbm, vmem, flops)


def flash_attention_cost(cfg: Mapping, BH: int, Sq: int, Sk: int, hd: int):
    """The serving attention kernel. Pallas impl: q/o cross HBM once per
    q-block row, k/v stream once per q-block sweep (the kernel's BlockSpec
    index maps); XLA impl: the materializing path additionally round-trips
    the (Sq, Sk) score tensor."""
    bq, bk = min(int(cfg.get("bq", 128)), Sq), min(int(cfg.get("bk", 128)), Sk)
    impl = str(cfg.get("impl", "pallas"))
    nq, nk = cdiv(Sq, bq), cdiv(Sk, bk)
    flops = 4.0 * BH * Sq * Sk * hd  # qk^T + pv matmuls
    if impl == "xla":
        # score materialization: ~4 HBM passes over (Sq, Sk) f32 scores
        hbm = BH * (2 * Sq + 2 * Sk) * hd * _BF16 + 4 * BH * Sq * Sk * _F32
        vmem = (bq * Sk + bq * hd + Sk * hd) * _F32
        eff = _align_eff(bq, Sk, hd)
    else:
        hbm = BH * nq * (2 * bq * hd + nk * 2 * bk * hd) * _BF16
        vmem = (bq * hd + 2 * bk * hd + bq * hd) * _BF16 \
            + (bq * hd + 2 * bq) * _F32  # acc + m/l scratch
        eff = _align_eff(bq, bk, hd)
    t = max(flops / (HW.peak_flops * eff), hbm / HW.hbm_bw)
    return _finish(t, hbm, vmem, flops)


def decode_attention_cost(cfg: Mapping, BH: int, G: int, S: int, hd: int):
    """The decode hot path: one token's attention against an S-token cache.
    Memory-bound by construction — the whole KV stream crosses HBM once per
    token. ``page`` pads S up to the seq bucket the paged cache would serve
    (the layout axis's modeled cost: bigger pages mean more padded keys per
    token); ``hg`` rows share a grid cell, amortizing the q/o block DMA."""
    page = max(1, int(cfg.get("page", 128)))
    S_eff = cdiv(S, page) * page
    bk = max(1, min(int(cfg.get("bk", 128)), S_eff))
    hg = max(1, min(int(cfg.get("hg", 1)), BH))
    impl = str(cfg.get("impl", "pallas"))
    flops = 4.0 * BH * G * S_eff * hd  # qk^T + pv contractions
    if impl == "xla":
        # chunked fallback: scores stay register/cache resident per chunk,
        # but the scan re-reads q per chunk and runs f32 end to end
        nk = cdiv(S_eff, bk)
        hbm = BH * (nk * G + G) * hd * _F32 + 2 * BH * S_eff * hd * _BF16
        vmem = (G * bk + 2 * bk * hd + 2 * G * hd) * _F32
        eff = _align_eff(G, bk, hd)
    else:
        # q/o cross once per row-group; k/v stream once (the BlockSpec maps)
        hbm = 2 * BH * G * hd * _BF16 + 2 * BH * S_eff * hd * _BF16
        vmem = (hg * G * hd + 2 * bk * hd) * _BF16 \
            + (hg * G * hd + 2 * hg * G) * _F32  # acc + m/l scratch
        eff = _align_eff(hg * G, bk, hd)
    t = max(flops / (HW.peak_flops * eff), hbm / HW.hbm_bw)
    return _finish(t, hbm, vmem, flops)


def matmul_cost(cfg: Mapping, M: int, K: int, N: int):
    bm = int(cfg.get("bm", 128))
    bn = int(cfg.get("bn", 128))
    bk = int(cfg.get("bk", 128))
    t, hbm, vmem, flops = _mm_cost(M, N, K, bm, bn, bk)
    if not cfg.get("pack", False):
        hbm += cdiv(K, bk) * M * N * _F32  # o tile read-modify-written per k step
        t = max(flops / (HW.peak_flops * _align_eff(bm, bn, bk)), hbm / HW.hbm_bw)
    return _finish(t, hbm, vmem, flops)


KERNEL_COST_FNS = {
    "syr2k": syr2k_cost,
    "mm3": mm3_cost,
    "lu": lu_cost,
    "heat3d": heat3d_cost,
    "covariance": covariance_cost,
    "floyd_warshall": floyd_warshall_cost,
    "flash_attention": flash_attention_cost,
    "decode_attention": decode_attention_cost,
    "matmul": matmul_cost,
}


def kernel_cost(name: str, cfg: Mapping, *shape_args):
    """Returns (modeled_seconds, info); +inf when the config cannot fit."""
    return KERNEL_COST_FNS[name](cfg, *shape_args)
