"""Backend-B1 code molds: blocked XLA variants timed on this host.

The paper's plopper substitutes pragma strings into a C mold and times the
clang-compiled binary on an i7. Here the mold is a *blocked JAX program*
whose loop structure genuinely changes with the configuration — tile sizes
set reshape/scan extents, ``interchange`` swaps which operand is stationary,
``pack`` materializes re-laid-out operand copies through
``jax.lax.optimization_barrier`` (the copy cannot be elided, exactly like
Polly's pack-into-malloc'd-buffer) — and the measured objective is the wall
clock of the jitted executable on this machine, the same role the paper's i7
plays. Correctness of every variant is pinned to ref.py by tests.

Naming: ``<kernel>_host(config) -> (fn, args)`` factories, consumable by
``repro.core.plopper.TimingEvaluator``.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.heat3d import _masked_update
from repro.kernels.util import cdiv, pad_to, unpad

__all__ = [
    "blocked_matmul_host", "syr2k_host", "mm3_host", "lu_host", "heat3d_host",
    "covariance_host", "floyd_warshall_host", "HOST_VARIANTS", "naive_fns",
    "DISPATCH_BUILDERS", "register_dispatch_variants",
]

_bar = jax.lax.optimization_barrier


def _as_int(v) -> int:
    return int(v)


# ---------------------------------------------------------------------------
# blocked matmul (shared by 3mm / trailing updates)
# ---------------------------------------------------------------------------


def blocked_matmul_host(a, b, *, bm, bn, bk, interchange=False, pack=False):
    M, K = a.shape
    K2, N = b.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    ap = pad_to(a, (bm, bk))
    bp = pad_to(b, (bk, bn))
    mi, kk = ap.shape[0] // bm, ap.shape[1] // bk
    nj = bp.shape[1] // bn

    A4 = ap.reshape(mi, bm, kk, bk).transpose(2, 0, 1, 3)  # (kk, mi, bm, bk)
    B4 = bp.reshape(kk, bk, nj, bn).transpose(0, 2, 1, 3)  # (kk, nj, bk, bn)
    if pack:  # force the re-laid-out copies to materialize
        A4, B4 = _bar((A4, B4))

    if interchange:
        # n-stationary: loop over output column blocks, full-k product each
        def jstep(_, Bj):  # Bj: (kk, bk, bn)
            return None, jnp.einsum("kmpc,kcn->mpn", A4, Bj)

        _, cols = jax.lax.scan(jstep, None, B4.transpose(1, 0, 2, 3))  # (nj, mi, bm, bn)
        out = cols.transpose(1, 2, 0, 3).reshape(ap.shape[0], bp.shape[1])
    else:
        # k-stationary accumulation: classic tiled-GEMM reduction loop
        def kstep(acc, ab):
            Ak, Bk = ab  # (mi, bm, bk), (nj, bk, bn)
            return acc + jnp.einsum("mpc,ncq->mpnq", Ak, Bk), None

        acc0 = jnp.zeros((mi, bm, nj, bn), dtype=jnp.promote_types(a.dtype, jnp.float32))
        acc, _ = jax.lax.scan(kstep, acc0, (A4, B4))
        out = acc.reshape(ap.shape[0], bp.shape[1]).astype(a.dtype)
    return unpad(out, (M, N))


# ---------------------------------------------------------------------------
# syr2k
# ---------------------------------------------------------------------------


def syr2k_variant(C, A, B, alpha, beta, *, bi, bj, bk, interchange=False,
                  pack_a=False, pack_b=False):
    N, M = A.shape
    bi, bj, bk = min(bi, N), min(bj, N), min(bk, M)
    l = math.lcm(bi, bj)
    Np = cdiv(N, l) * l
    Ap = pad_to(A, (Np, bk))
    Bp = pad_to(B, (Np, bk))
    Cp = pad_to(C, (Np, Np))
    ni, nj, kk = Np // bi, Np // bj, Ap.shape[1] // bk

    Ai = Ap.reshape(ni, bi, kk, bk).transpose(2, 0, 1, 3)  # (kk, ni, bi, bk)
    Aj = Ap.reshape(nj, bj, kk, bk).transpose(2, 0, 1, 3)
    Bi = Bp.reshape(ni, bi, kk, bk).transpose(2, 0, 1, 3)
    Bj = Bp.reshape(nj, bj, kk, bk).transpose(2, 0, 1, 3)
    if pack_a:
        Ai, Aj = _bar((Ai, Aj))
    if pack_b:
        Bi, Bj = _bar((Bi, Bj))

    lhs, rhs = ("jqc,ipc->ipjq", "jqc,ipc->ipjq") if interchange else ("ipc,jqc->ipjq",) * 2

    def kstep(acc, ops):
        ai, aj, bi_, bj_ = ops
        if interchange:
            acc = acc + alpha * jnp.einsum(lhs, bj_, ai)
            acc = acc + alpha * jnp.einsum(rhs, aj, bi_)
        else:
            acc = acc + alpha * jnp.einsum(lhs, ai, bj_)
            acc = acc + alpha * jnp.einsum(rhs, bi_, aj)
        return acc, None

    acc0 = jnp.zeros((ni, bi, nj, bj), dtype=jnp.float32)
    acc, _ = jax.lax.scan(kstep, acc0, (Ai, Aj, Bi, Bj))
    out = beta * Cp + acc.reshape(Np, Np).astype(C.dtype)
    return unpad(out, (N, N))


# ---------------------------------------------------------------------------
# covariance
# ---------------------------------------------------------------------------


def covariance_variant(data, *, bi, bj, bk, fuse_center=True, interchange=False):
    Nn, M = data.shape
    bi, bj, bk = min(bi, M), min(bj, M), min(bk, Nn)
    mean = data.mean(axis=0, keepdims=True)
    if not fuse_center:
        data = data - mean
    l = math.lcm(bi, bj)
    Mp = cdiv(M, l) * l
    dp = pad_to(data, (bk, Mp))
    if fuse_center and dp.shape[0] != Nn:
        filler = jnp.broadcast_to(pad_to(mean, (1, Mp)), (dp.shape[0] - Nn, Mp))
        dp = dp.at[Nn:, :].set(filler)
    mp = pad_to(mean, (1, Mp))
    kk = dp.shape[0] // bk
    ni, nj = Mp // bi, Mp // bj

    Di = dp.reshape(kk, bk, ni, bi).transpose(0, 2, 1, 3)  # (kk, ni, bk, bi)
    Dj = dp.reshape(kk, bk, nj, bj).transpose(0, 2, 1, 3)
    Mi = mp.reshape(1, ni, bi)
    Mj = mp.reshape(1, nj, bj)

    def kstep(acc, ops):
        di, dj = ops
        if fuse_center:
            di = di - Mi[0][:, None, :]
            dj = dj - Mj[0][:, None, :]
        ein = "jcq,icp->jqip" if interchange else "icp,jcq->ipjq"
        if interchange:
            acc = acc + jnp.einsum(ein, dj, di).transpose(2, 3, 0, 1)
        else:
            acc = acc + jnp.einsum(ein, di, dj)
        return acc, None

    acc0 = jnp.zeros((ni, bi, nj, bj), dtype=jnp.float32)
    acc, _ = jax.lax.scan(kstep, acc0, (Di, Dj))
    out = (acc.reshape(Mp, Mp) / (Nn - 1.0)).astype(data.dtype)
    return unpad(out, (M, M))


# ---------------------------------------------------------------------------
# heat-3d (blocked over i with halos, shared masked-update helper)
# ---------------------------------------------------------------------------


def heat3d_variant(A, tsteps, *, bi, fuse_t=1):
    n0, n1, n2 = A.shape
    bi = min(bi, n0)
    h = fuse_t
    total = 2 * tsteps
    assert total % h == 0
    Ap = pad_to(A, (bi, 1, 1))
    ni = Ap.shape[0] // bi
    Npad = Ap.shape[0]

    def one_pass(X):
        Xh = jnp.pad(X, ((h, h), (0, 0), (0, 0)))

        def block(i):
            ext = jax.lax.dynamic_slice(Xh, (i * bi, 0, 0), (bi + 2 * h, n1, n2))
            g_rows = i * bi - h + jnp.arange(bi + 2 * h)
            e = ext
            for _ in range(h):
                e = _masked_update(e, g_rows, n0)
            return e[h : h + bi]

        blocks = jax.lax.map(block, jnp.arange(ni))
        return blocks.reshape(Npad, n1, n2)

    out = jax.lax.fori_loop(0, total // h, lambda _, x: one_pass(x), Ap)
    return out[:n0]


# ---------------------------------------------------------------------------
# lu / floyd-warshall: the blocked wrappers already support an XLA inner path
# ---------------------------------------------------------------------------


def lu_variant(A, *, bs, bm=128, bn=128, pack=True):
    from repro.kernels.lu import lu

    return lu(A, bs=bs, bm=bm, bn=bn, pack=pack, matmul_impl="xla")


def _minplus_xla(D, A, B, chunk: int):
    """min(D, A (x) B) with the k-reduction chunked (``chunk`` = unroll)."""
    n, m = D.shape
    bsz = A.shape[1]
    chunk = min(chunk, bsz)
    pad = (-bsz) % chunk
    if pad:
        A = jnp.pad(A, ((0, 0), (0, pad)), constant_values=1e18)
        B = jnp.pad(B, ((0, pad), (0, 0)), constant_values=1e18)
    kc = A.shape[1] // chunk
    Ac = A.reshape(n, kc, chunk).transpose(1, 0, 2)  # (kc, n, chunk)
    Bc = B.reshape(kc, chunk, m)

    def step(acc, ab):
        a, b = ab  # (n, chunk), (chunk, m)
        return jnp.minimum(acc, (a[:, :, None] + b[None, :, :]).min(axis=1)), None

    out, _ = jax.lax.scan(step, D, (Ac, Bc))
    return out


def floyd_warshall_variant(path, *, bs, bi=128, bj=128, unroll=1):
    N = path.shape[0]
    bs = min(bs, N)
    BIG = 1.0e18
    Dp = pad_to(path, (bs, bs), value=BIG)
    Np = Dp.shape[0]
    nb = Np // bs

    def closure(Dk):
        def s(k, M):
            return jnp.minimum(M, M[:, k][:, None] + M[k, :][None, :])
        return jax.lax.fori_loop(0, bs, s, Dk)

    def block_round(kb, D):
        off = kb * bs
        diag = closure(jax.lax.dynamic_slice(D, (off, off), (bs, bs)))
        D = jax.lax.dynamic_update_slice(D, diag, (off, off))
        row = jax.lax.dynamic_slice(D, (off, 0), (bs, Np))
        row = _minplus_xla(row, diag, row, unroll)
        D = jax.lax.dynamic_update_slice(D, row, (off, 0))
        col = jax.lax.dynamic_slice(D, (0, off), (Np, bs))
        col = _minplus_xla(col, col, diag, unroll)
        D = jax.lax.dynamic_update_slice(D, col, (0, off))
        return _minplus_xla(D, col, row, unroll)

    out = jax.lax.fori_loop(0, nb, block_round, Dp)
    return out[:N, :N]


# ---------------------------------------------------------------------------
# builders: config (+ static knobs) -> fn(*arrays). One shared definition
# feeds both the TimingEvaluator factories below and the dispatch registry.
# ---------------------------------------------------------------------------


def _ints(cfg: Mapping[str, Any], *names) -> dict:
    return {n: _as_int(cfg[n]) for n in names if n in cfg}


def syr2k_builder(cfg: Mapping[str, Any]):
    kw = _ints(cfg, "bi", "bj", "bk")
    kw.update(interchange=bool(cfg.get("interchange", False)),
              pack_a=bool(cfg.get("pack_a", False)),
              pack_b=bool(cfg.get("pack_b", False)))
    return functools.partial(syr2k_variant, alpha=1.5, beta=1.2, **kw)


def mm3_builder(cfg: Mapping[str, Any]):
    kw = _ints(cfg, "bm", "bn", "bk")

    def fn(a, b, c, d):
        E = blocked_matmul_host(a, b, pack=bool(cfg.get("pack1", True)),
                                interchange=bool(cfg.get("inter1", False)), **kw)
        F = blocked_matmul_host(c, d, pack=bool(cfg.get("pack2", True)),
                                interchange=bool(cfg.get("inter2", False)), **kw)
        return blocked_matmul_host(E, F, pack=bool(cfg.get("pack3", True)),
                                   interchange=bool(cfg.get("inter3", False)), **kw)

    return fn


def lu_builder(cfg: Mapping[str, Any]):
    kw = _ints(cfg, "bs", "bm", "bn")
    return functools.partial(lu_variant, pack=bool(cfg.get("pack", True)), **kw)


def heat3d_builder(cfg: Mapping[str, Any], tsteps: int = 8):
    return functools.partial(heat3d_variant, tsteps=tsteps,
                             bi=_as_int(cfg["bi"]), fuse_t=_as_int(cfg.get("fuse_t", 1)))


def covariance_builder(cfg: Mapping[str, Any]):
    kw = _ints(cfg, "bi", "bj", "bk")
    return functools.partial(covariance_variant,
                             fuse_center=bool(cfg.get("fuse_center", True)),
                             interchange=bool(cfg.get("interchange", False)), **kw)


def floyd_warshall_builder(cfg: Mapping[str, Any]):
    return functools.partial(floyd_warshall_variant,
                             **_ints(cfg, "bs", "bi", "bj", "unroll"))


DISPATCH_BUILDERS = {
    "syr2k": syr2k_builder,
    "mm3": mm3_builder,
    "lu": lu_builder,
    "heat3d": heat3d_builder,
    "covariance": covariance_builder,
    "floyd_warshall": floyd_warshall_builder,
}


def register_dispatch_variants() -> None:
    """Register every host kernel into the repro.dispatch registry (called
    lazily by the registry itself, idempotent by construction)."""
    from repro.dispatch.registry import register
    from repro.kernels.spaces import kernel_space

    for name, builder in DISPATCH_BUILDERS.items():
        register(name, builder,
                 space=functools.partial(kernel_space, name))


# ---------------------------------------------------------------------------
# factories: kernel name -> (factory(config) -> (fn, args)) for TimingEvaluator
# ---------------------------------------------------------------------------


def _host_factory(builder, problem, **static_kw):
    def factory(cfg):
        return builder(cfg, **static_kw), problem

    return factory


def syr2k_host(problem):
    return _host_factory(syr2k_builder, problem)


def mm3_host(problem):
    return _host_factory(mm3_builder, problem)


def lu_host(problem):
    return _host_factory(lu_builder, problem)


def heat3d_host(problem, tsteps):
    return _host_factory(heat3d_builder, problem, tsteps=tsteps)


def covariance_host(problem):
    return _host_factory(covariance_builder, problem)


def floyd_warshall_host(problem):
    return _host_factory(floyd_warshall_builder, problem)


HOST_VARIANTS = {
    "syr2k": syr2k_host,
    "mm3": mm3_host,
    "lu": lu_host,
    "heat3d": heat3d_host,
    "covariance": covariance_host,
    "floyd_warshall": floyd_warshall_host,
}


def naive_fns():
    """The untransformed loop nests (the 'gcc -O3 on the original code' row):
    row-at-a-time fori loops — compiled, but neither tiled nor library-lowered."""

    def naive_matvec_rows(a, b):
        M = a.shape[0]

        def row(i, acc):
            return acc.at[i, :].set(a[i, :] @ b)

        return jax.lax.fori_loop(0, M, row, jnp.zeros((M, b.shape[1]), a.dtype))

    def syr2k(C, A, B):
        N = A.shape[0]

        def row(i, acc):
            v = 1.5 * (A[i, :] @ B.T) + 1.5 * (B[i, :] @ A.T) + 1.2 * C[i, :]
            return acc.at[i, :].set(v)

        return jax.lax.fori_loop(0, N, row, jnp.zeros_like(C))

    def mm3(A, B, C, D):
        E = naive_matvec_rows(A, B)
        F = naive_matvec_rows(C, D)
        return naive_matvec_rows(E, F)

    def covariance(data):
        Nn, M = data.shape
        c = data - data.mean(axis=0, keepdims=True)

        def row(i, acc):
            return acc.at[i, :].set(c[:, i] @ c / (Nn - 1.0))

        return jax.lax.fori_loop(0, M, row, jnp.zeros((M, M), data.dtype))

    return {
        "syr2k": syr2k,
        "mm3": mm3,
        "lu": R.lu_ref,
        "heat3d": R.heat3d_ref,
        "covariance": covariance,
        "floyd_warshall": R.floyd_warshall_ref,
    }
