"""Decode flash attention Pallas kernel — the serving latency hot path.

Prefill rides :mod:`repro.kernels.flash_attention`; decode until now rode the
dense einsum in ``models.attention.gqa_decode``, which materializes the full
(B, K, G, S) score tensor every token. This kernel streams the KV cache
through VMEM in ``bk``-sized blocks with the online-softmax recurrence, so
per-token HBM traffic is exactly q + k + v + o and the score block never
leaves VMEM.

The mask reproduces ``gqa_decode``'s ring/window semantics exactly (the
property tests pin bit-closeness): slot ``j`` of a ring cache of length S
holds absolute position ``cur_pos - ((cur_pos - j) mod S)``; positions
beyond ``cur_pos``, negative (not yet written), or older than the sliding
window are masked. ``cur_pos`` is *per row* — a (BH,) int32 vector — because
continuous batching gives every sequence in the batch its own decode
position; it rides into the kernel as a scalar-prefetch operand
(``PrefetchScalarGridSpec``), available in SMEM before the grid body runs.

Schedule knobs (the paper's pragma vocabulary, decode edition):

  * ``bk`` — KV block length (VMEM tile of the cache stream);
  * ``hg`` — head grouping: how many (batch*kv-head) rows share one grid
    cell, amortizing grid overhead when G*hd is far below the MXU tile;
  * ``impl`` — Pallas kernel vs the chunked-XLA fallback (host backend).

The paged KV cache's ``page_size`` is a fourth axis of the same tuned space,
realized by the cache layout (``serve.kvcache``) rather than this kernel:
it decides the seq-bucket granularity the dispatch signature sees.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import cdiv, default_interpret, pad_to, tpu_compiler_params

__all__ = ["decode_attention", "chunked_decode_xla", "decode_ref"]

_NEG = -1.0e30


def _decode_mask(slots, cp, *, s_real: int, ring: bool, window: int):
    """The allow-mask shared by every impl (and the dense reference).

    ``slots``: int32 cache-slot indices, any shape broadcastable with ``cp``;
    ``cp``: per-row current positions. Returns (kpos, valid)."""
    if ring:
        kpos = cp - jnp.mod(cp - slots, s_real)
    else:
        kpos = jnp.broadcast_to(slots, jnp.broadcast_shapes(slots.shape, cp.shape))
    valid = (slots < s_real) & (kpos >= 0) & (kpos <= cp)
    if window > 0:
        valid &= (cp - kpos) < window
    return kpos, valid


def _decode_kernel(cp_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, nk: int, bk: int, hg: int, scale: float, s_real: int,
                   ring: bool, window: int):
    i, kb = pl.program_id(0), pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)          # (hg, G, hd)
    k = k_ref[...].astype(jnp.float32)          # (hg, bk, hd)
    v = v_ref[...].astype(jnp.float32)          # (hg, bk, hd)

    # (hg, G, hd) x (hg, bk, hd) -> (hg, G, bk), batched over the row axis
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale

    hgG = (hg, q.shape[1], bk)
    slots = kb * bk + jax.lax.broadcasted_iota(jnp.int32, hgG, 2)
    cp = cp_ref[pl.ds(i * hg, hg)].reshape(hg, 1, 1)
    _, valid = _decode_mask(slots, cp, s_real=s_real, ring=ring, window=window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...]                          # (hg, G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    # explicit zeroing (not just the _NEG offset): a fully-masked block —
    # routine under ring/window decode — would otherwise contribute
    # exp(_NEG - _NEG) = 1 per slot to the denominator
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,            # (BH, G, hd) — batch*kv_heads rows, G query heads
    k: jnp.ndarray,            # (BH, S, hd) — cache, S = seq bucket
    v: jnp.ndarray,            # (BH, S, hd)
    cur_pos: jnp.ndarray,      # (BH,) int32 — per-row decode position
    *,
    ring: bool = False,
    window: int = 0,           # static; <=0 disables the sliding window
    bk: int = 128,
    hg: int = 1,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """One-token attention against a filled cache, per-row positions."""
    if interpret is None:
        interpret = default_interpret()
    BH, G, hd = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    bk = max(1, min(bk, S))
    hg = max(1, min(hg, BH))
    cur_pos = jnp.asarray(cur_pos, jnp.int32).reshape(-1)
    if cur_pos.shape[0] == 1 and BH > 1:
        cur_pos = jnp.broadcast_to(cur_pos, (BH,))

    qp = pad_to(q, (hg, 1, 1))
    kp = pad_to(k, (hg, bk, 1))
    vp = pad_to(v, (hg, bk, 1))
    # padded rows carry cur_pos = -1: every slot fails kpos <= cur_pos, the
    # whole row masks out, and the zero output is sliced away below
    cpp = pad_to(cur_pos, (hg,), value=-1)
    nbh, nk = qp.shape[0] // hg, kp.shape[1] // bk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbh, nk),
        in_specs=[
            pl.BlockSpec((hg, G, hd), lambda i, j, cp: (i, 0, 0)),
            pl.BlockSpec((hg, bk, hd), lambda i, j, cp: (i, j, 0)),
            pl.BlockSpec((hg, bk, hd), lambda i, j, cp: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((hg, G, hd), lambda i, j, cp: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hg, G, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((hg, G, 1), jnp.float32),    # running max
            pltpu.VMEM((hg, G, 1), jnp.float32),    # running denominator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, nk=nk, bk=bk, hg=hg, scale=scale,
                          s_real=S, ring=ring, window=int(window or 0)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(cpp, qp, kp, vp)
    return out[:BH]


def chunked_decode_xla(
    q: jnp.ndarray,            # (BH, G, hd)
    k: jnp.ndarray,            # (BH, S, hd)
    v: jnp.ndarray,            # (BH, S, hd)
    cur_pos: jnp.ndarray,      # (BH,) int32
    *,
    ring: bool = False,
    window: int = 0,
    bk: int = 128,
    scale: float | None = None,
) -> jnp.ndarray:
    """The XLA fallback variant: same contract and same online-softmax
    recurrence, scanned over ``bk``-length cache chunks — interchangeable
    with :func:`decode_attention` under one dispatch entry (host backend,
    where interpret-mode Pallas is orders slower than XLA)."""
    BH, G, hd = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    bk = max(1, min(bk, S))
    cur_pos = jnp.asarray(cur_pos, jnp.int32).reshape(-1)
    if cur_pos.shape[0] == 1 and BH > 1:
        cur_pos = jnp.broadcast_to(cur_pos, (BH,))

    kp = pad_to(k, (1, bk, 1))
    vp = pad_to(v, (1, bk, 1))
    nk = kp.shape[1] // bk
    kc = kp.reshape(BH, nk, bk, hd).transpose(1, 0, 2, 3)   # (nk, BH, bk, hd)
    vc = vp.reshape(BH, nk, bk, hd).transpose(1, 0, 2, 3)

    qf = q.astype(jnp.float32)
    cp = cur_pos.reshape(BH, 1, 1)
    window = int(window or 0)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        ci, kb, vb = blk
        s = jnp.einsum("bgh,bsh->bgs", qf, kb.astype(jnp.float32)) * scale
        slots = ci * bk + jnp.arange(bk, dtype=jnp.int32).reshape(1, 1, bk)
        _, valid = _decode_mask(slots, cp, s_real=S, ring=ring, window=window)
        s = jnp.where(valid, s, _NEG)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bgs,bsh->bgh", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((BH, G, 1), _NEG, jnp.float32),
            jnp.zeros((BH, G, 1), jnp.float32),
            jnp.zeros((BH, G, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.arange(nk, dtype=jnp.int32), kc, vc))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def decode_ref(q, k, v, cur_pos, *, ring=False, window=0, scale=None):
    """Dense reference in the kernel's own (BH, G, hd) layout — the oracle
    the property tests compare both impls against (mirrors
    ``models.attention.gqa_decode`` slot math exactly)."""
    BH, G, hd = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    cur_pos = jnp.asarray(cur_pos, jnp.int32).reshape(-1)
    if cur_pos.shape[0] == 1 and BH > 1:
        cur_pos = jnp.broadcast_to(cur_pos, (BH,))
    s = jnp.einsum("bgh,bsh->bgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    slots = jnp.arange(S, dtype=jnp.int32).reshape(1, 1, S)
    _, valid = _decode_mask(slots, cur_pos.reshape(BH, 1, 1), s_real=S,
                            ring=ring, window=int(window or 0))
    s = jnp.where(valid, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgs,bsh->bgh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
