"""Flash attention Pallas kernel (beyond-paper §Perf move).

The dry-run walker shows the dense-arch train cells are memory-bound almost
entirely through attention score materialization: the XLA chunked path
round-trips (chunk x S) score tensors through HBM several times per layer
(forward, mask, softmax, backward, remat). This kernel keeps the score block
in VMEM for good: per (batch*head, q-block) grid cell it streams K/V blocks
through VMEM with the online-softmax recurrence, so HBM traffic is exactly
q + k + v + o — independent of S^2.

Schedule knobs (the paper's pragma vocabulary, again):
  * ``bq`` / ``bk``  — query / key block sizes (VMEM tiles);
  * the K-sweep is the innermost grid dim ('arbitrary'), batch*heads and
    q-blocks are 'parallel'.

HBM-traffic napkin math per (B, H, S, hd), used by the §Perf accounting:
    flash:  (3 reads + 1 write) * B*H*S*hd * bytes         ~ O(S)
    xla  :  + 2 * n_passes * B*H*S^2 * bytes(score)        ~ O(S^2)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import cdiv, default_interpret, pad_to, tpu_compiler_params

__all__ = ["flash_attention", "flash_hbm_bytes", "xla_attention_hbm_bytes"]

_NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, nk: int, bq: int, bk: int, scale: float, causal: bool,
                  sk_real: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0].astype(jnp.float32)            # (bk, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < sk_real            # padded keys must not contribute
    if causal:
        qb = pl.program_id(1)
        qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        valid &= qpos >= kpos
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                       # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,            # (BH, Sq, hd) — batch*heads flattened
    k: jnp.ndarray,            # (BH, Sk, hd)
    v: jnp.ndarray,            # (BH, Sk, hd)
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)

    qp = pad_to(q, (1, bq, 1))
    kp = pad_to(k, (1, bk, 1))
    vp = pad_to(v, (1, bk, 1))
    nq, nk = qp.shape[1] // bq, kp.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                          causal=causal, sk_real=Sk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq, :]


# ---------------------------------------------------------------------------
# analytic HBM accounting (used by §Perf to adjust the walker's memory term)
# ---------------------------------------------------------------------------


def flash_hbm_bytes(B: int, H: int, Kh: int, Sq: int, Sk: int, hd: int,
                    dtype_bytes: int = 2, bq: int = 128) -> float:
    """q read + (k, v) streamed once per q-block + o write."""
    nq = cdiv(Sq, bq)
    q_io = 2 * B * H * Sq * hd * dtype_bytes           # read q + write o
    kv_io = nq * 2 * B * Kh * Sk * hd * dtype_bytes    # k+v per q-block sweep
    return float(q_io + kv_io)


def xla_attention_hbm_bytes(B: int, H: int, Sq: int, Sk: int, hd: int,
                            dtype_bytes: int = 4, n_passes: int = 6) -> float:
    """The materializing path: score tensors cross HBM ~n_passes times
    (matmul out, mask, softmax in/out, backward twice)."""
    return float(n_passes * B * H * Sq * Sk * dtype_bytes)
