"""syr2k Pallas kernel: C = alpha*A@B^T + alpha*B@A^T + beta*C (Sec. 4.1).

This is the paper's flagship case study. Knob mapping:

  * P3/P4/P5 tile sizes -> ``bi``/``bj``/``bk`` (C-row block, C-col block,
    contraction block over M);
  * P2 interchange      -> ``interchange`` (swap which of the two C block axes
    is the outer grid loop);
  * P0/P1 array packing -> ``pack_a``/``pack_b``: stage the A (resp. B) tiles
    through an explicit VMEM scratch copy before the MXU ops — the local-
    buffer copy Polly's ``pack array`` performs. The accompanying space
    (spaces.py) reproduces the paper's InCondition: pack_b requires pack_a.

A and B are both consumed under two different index maps (row-block i and
row-block j) because C_ij needs A_i B_j^T + B_i A_j^T.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import cdiv, default_interpret, pad_to, tpu_compiler_params, unpad

__all__ = ["syr2k"]


def _syr2k_kernel(
    c_ref, ai_ref, bj_ref, bi_ref, aj_ref, o_ref, acc_ref, pa_ref, pb_ref,
    *, nk: int, alpha: float, beta: float, pack_a: bool, pack_b: bool,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = beta * c_ref[...].astype(jnp.float32)

    ai = ai_ref[...]
    aj = aj_ref[...]
    bi = bi_ref[...]
    bj = bj_ref[...]
    if pack_a:  # stage A tiles in a dedicated VMEM buffer (packing)
        pa_ref[...] = ai
        ai = pa_ref[...]
    if pack_b:
        pb_ref[...] = bi
        bi = pb_ref[...]

    acc_ref[...] += alpha * jnp.dot(ai, bj.T, preferred_element_type=jnp.float32)
    acc_ref[...] += alpha * jnp.dot(bi, aj.T, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def syr2k(
    C: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    alpha: float = 1.5,
    beta: float = 1.2,
    *,
    bi: int = 128,
    bj: int = 128,
    bk: int = 128,
    interchange: bool = False,
    pack_a: bool = False,
    pack_b: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    N, M = A.shape
    assert B.shape == (N, M) and C.shape == (N, N)

    bi = min(bi, N)
    bj = min(bj, N)
    bk = min(bk, M)

    # N must pad to a common multiple of bi and bj (both tile the same axis)
    import math

    l = math.lcm(bi, bj)
    Np = cdiv(N, l) * l
    Ap = pad_to(A, (Np, bk))
    Bp = pad_to(B, (Np, bk))
    Cp = pad_to(C, (Np, Np))

    ni, nj, nk = Np // bi, Np // bj, cdiv(M, bk)

    if interchange:
        grid = (nj, ni, nk)
        gi = lambda j, i, k: i
        gj = lambda j, i, k: j
        gk = lambda j, i, k: k
    else:
        grid = (ni, nj, nk)
        gi = lambda i, j, k: i
        gj = lambda i, j, k: j
        gk = lambda i, j, k: k

    out = pl.pallas_call(
        functools.partial(
            _syr2k_kernel, nk=nk, alpha=alpha, beta=beta,
            pack_a=pack_a, pack_b=pack_b,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bj), lambda *g: (gi(*g), gj(*g))),   # C
            pl.BlockSpec((bi, bk), lambda *g: (gi(*g), gk(*g))),   # A_i
            pl.BlockSpec((bj, bk), lambda *g: (gj(*g), gk(*g))),   # B_j
            pl.BlockSpec((bi, bk), lambda *g: (gi(*g), gk(*g))),   # B_i
            pl.BlockSpec((bj, bk), lambda *g: (gj(*g), gk(*g))),   # A_j
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda *g: (gi(*g), gj(*g))),
        out_shape=jax.ShapeDtypeStruct((Cp.shape[0], Cp.shape[1]), C.dtype),
        scratch_shapes=[
            pltpu.VMEM((bi, bj), jnp.float32),  # accumulator
            pltpu.VMEM((bi, bk), A.dtype),      # packed A tile
            pltpu.VMEM((bi, bk), B.dtype),      # packed B tile
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(Cp, Ap, Bp, Bp, Ap)
    return unpad(out, (N, N))
