"""Model-kernel dispatch builders: the serving hot path's tunable kernels.

The PolyBench kernels route through :mod:`repro.dispatch` via
``kernels.variants``; this module does the same for the kernels the model
stack actually serves — flash attention (``bq``/``bk`` VMEM tiles, plus the
chunked-XLA fallback as an ``impl`` variant axis) and the blocked matmul
behind the projection/unembed call sites. ``repro.models`` reaches these
through the ``service=`` path (see ``models.attention``), so prefill/decode
resolve tuned block shapes per shape signature instead of hard-coding the
kernel defaults.

Signature scheme: the dispatch service derives signatures from the runtime
arrays plus sorted static kwargs, so a flash call is keyed
``((BH, Sq, hd), (BH, Sk, hd), (BH, Sk, hd), (2,))`` — the trailing dim is
the static ``causal`` flag ((2,) causal, (1,) not). ``BH`` is batch times
kv heads: the GQA route dispatches per kv-head group (see
``models.attention``), so MHA and GQA key consistently.
:func:`flash_attention_signature` builds that key for offline publishers
(campaigns, tests) so their records resolve at dispatch time.
"""

from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import chunked_decode_xla, decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.variants import blocked_matmul_host

__all__ = [
    "chunked_attention_xla", "flash_attention_builder", "matmul_builder",
    "decode_attention_builder", "decode_attention_signature",
    "flash_attention_signature", "init_flash_attention", "init_matmul",
    "init_decode_attention", "decode_attention_host",
    "flash_attention_host", "matmul_host", "MODEL_KERNEL_BUILDERS",
    "register_model_kernels",
]

_NEG = -1.0e30


def chunked_attention_xla(
    q: jnp.ndarray,            # (BH, Sq, hd) — batch*heads flattened
    k: jnp.ndarray,            # (BH, Sk, hd)
    v: jnp.ndarray,            # (BH, Sk, hd)
    *,
    causal: bool = True,
    bq: int = 128,
    scale: float | None = None,
) -> jnp.ndarray:
    """The materializing fallback: per q-chunk full-score softmax in f32.
    Same contract as :func:`~repro.kernels.flash_attention.flash_attention`
    so the two are interchangeable variants under one dispatch entry."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    bq = min(bq, Sq)
    pad = (-Sq) % bq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0))) if pad else q
    nq = qp.shape[1] // bq
    qc = qp.reshape(BH, nq, bq, hd).transpose(1, 0, 2, 3)   # (nq, BH, bq, hd)
    kpos = jnp.arange(Sk)

    def one_chunk(ci, qblk):
        s = jnp.einsum("bqh,bsh->bqs", qblk.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if causal:
            qpos = ci * bq + jnp.arange(bq)
            s = jnp.where(qpos[None, :, None] >= kpos[None, None, :], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bqs,bsh->bqh", p, v.astype(jnp.float32))
        return o.astype(q.dtype)

    out = jax.lax.map(lambda xs: one_chunk(*xs), (jnp.arange(nq), qc))
    out = out.transpose(1, 0, 2, 3).reshape(BH, nq * bq, hd)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# dispatch builders: config (+ static kwargs) -> fn(*arrays)
# ---------------------------------------------------------------------------


def flash_attention_builder(cfg: Mapping[str, Any], *, causal: bool = True):
    impl = str(cfg.get("impl", "pallas"))
    bq, bk = int(cfg.get("bq", 128)), int(cfg.get("bk", 128))
    if impl == "xla":
        return functools.partial(chunked_attention_xla, causal=causal, bq=bq)
    if impl == "pallas":
        return functools.partial(flash_attention, causal=causal, bq=bq, bk=bk)
    raise ValueError(f"unknown flash_attention impl {impl!r}")


def decode_attention_builder(cfg: Mapping[str, Any], *, ring: bool = False,
                             window: int = 0):
    """Decode-attention variants under one dispatch entry. ``page`` is part
    of the tuned config but is a *layout* axis realized by the paged KV
    cache (it decides the seq bucket the signature's S lands on), so the
    builder ignores it — both impls read the cache view they are handed."""
    impl = str(cfg.get("impl", "pallas"))
    bk, hg = int(cfg.get("bk", 128)), int(cfg.get("hg", 1))
    if impl == "xla":
        return functools.partial(chunked_decode_xla, ring=ring, window=window,
                                 bk=bk)
    if impl == "pallas":
        return functools.partial(decode_attention, ring=ring, window=window,
                                 bk=bk, hg=hg)
    raise ValueError(f"unknown decode_attention impl {impl!r}")


def matmul_builder(cfg: Mapping[str, Any]):
    return functools.partial(
        blocked_matmul_host,
        bm=int(cfg.get("bm", 128)), bn=int(cfg.get("bn", 128)),
        bk=int(cfg.get("bk", 128)),
        interchange=bool(cfg.get("interchange", False)),
        pack=bool(cfg.get("pack", False)))


MODEL_KERNEL_BUILDERS = {
    "flash_attention": flash_attention_builder,
    "decode_attention": decode_attention_builder,
    "matmul": matmul_builder,
}


def register_model_kernels() -> None:
    """Register the model kernels into the repro.dispatch registry (called
    lazily by the registry itself, idempotent by construction)."""
    from repro.dispatch.registry import register
    from repro.kernels.spaces import kernel_space

    for name, builder in MODEL_KERNEL_BUILDERS.items():
        register(name, builder, space=functools.partial(kernel_space, name))


# ---------------------------------------------------------------------------
# store-signature / problem helpers (offline campaigns, CLI, tests)
# ---------------------------------------------------------------------------


def flash_attention_signature(BH: int, Sq: int, Sk: int, hd: int,
                              causal: bool = True) -> tuple:
    """The signature ``service.dispatch('flash_attention', q, k, v,
    causal=...)`` derives at runtime; the trailing dim is the static
    ``causal`` kwarg folded into the signature ((2,) = causal, (1,) = not —
    the two masking modes must not share tuned records)."""
    return ((BH, Sq, hd), (BH, Sk, hd), (BH, Sk, hd), (2,) if causal else (1,))


def init_flash_attention(BH: int, Sq: int, Sk: int, hd: int,
                         dtype=jnp.float32, seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (BH, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (BH, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (BH, Sk, hd), dtype)
    return q, k, v


def decode_attention_signature(BH: int, G: int, S: int, hd: int,
                               *, ring: bool = False, window: int = 0) -> tuple:
    """The signature ``service.dispatch('decode_attention', q, k, v,
    cur_pos, ring=..., window=...)`` derives at runtime. ``BH`` is batch
    times kv heads (the GQA route flattens per kv-head rows, G query heads
    ride along as a dense axis); ``S`` is the *seq bucket* — the paged
    cache's view length, always a multiple of the tuned ``page``. The
    (BH,) entry is the per-row ``cur_pos`` vector; the trailing dims are
    the static ``ring``/``window`` kwargs folded in sorted order ((2,) =
    ring, (1,) = linear; window clamps to (1,) when disabled)."""
    return ((BH, G, hd), (BH, S, hd), (BH, S, hd), (BH,),
            (2,) if ring else (1,), (max(1, int(window)),))


def init_decode_attention(BH: int, G: int, S: int, hd: int,
                          dtype=jnp.float32, seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (BH, G, hd), dtype)
    k = jax.random.normal(ks[1], (BH, S, hd), dtype)
    v = jax.random.normal(ks[2], (BH, S, hd), dtype)
    cur_pos = jnp.full((BH,), S - 1, jnp.int32)   # fully-resident cache
    return q, k, v, cur_pos


def init_matmul(M: int, K: int, N: int, dtype=jnp.float32, seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.random.normal(ks[0], (M, K), dtype) / jnp.sqrt(K).astype(dtype)
    b = jax.random.normal(ks[1], (K, N), dtype) / jnp.sqrt(N).astype(dtype)
    return a, b


def flash_attention_host(problem):
    def factory(cfg):
        return flash_attention_builder(cfg), problem

    return factory


def decode_attention_host(problem):
    def factory(cfg):
        return decode_attention_builder(cfg), problem

    return factory


def matmul_host(problem):
    def factory(cfg):
        return matmul_builder(cfg), problem

    return factory
