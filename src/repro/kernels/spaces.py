"""Per-kernel ConfigurationSpaces — the paper's pragma parameter spaces,
re-targeted at TPU schedule knobs.

Two flavors per kernel:

  * ``target="tpu"``  — MXU/VMEM-aligned tile sequences (multiples of 8/128),
    driving the Pallas kernels (backend B2 / real TPU);
  * ``target="host"`` — the paper's literal 11-entry tile sequences
    ('4'...'2048'), driving the XLA host variants (backend B1), where cache
    behavior — not the MXU — shapes the landscape, as on the paper's i7.

Space sizes mirror the paper: syr2k 2*2*2*11^3 = 10,648; 3mm 2^7 * 11^3 =
170,368; lu / covariance / heat-3d / floyd-warshall analogous.
"""

from __future__ import annotations

from repro.core.space import (
    Categorical,
    ConfigurationSpace,
    ForbiddenClause,
    InCondition,
    Ordinal,
)

__all__ = ["kernel_space", "KERNEL_SPACES"]

# the paper's tile sequences (Sec. 4.1)
HOST_TILES_A = (4, 8, 16, 20, 32, 50, 64, 80, 96, 100, 128)
HOST_TILES_B = (4, 8, 16, 20, 32, 50, 64, 80, 100, 128, 2048)
HOST_TILES_C = (4, 8, 16, 20, 32, 50, 64, 80, 100, 128, 256)
# TPU-aligned sequences: sublane/lane multiples (11 entries, like the paper)
TPU_TILES = (8, 16, 32, 64, 96, 128, 192, 256, 384, 512, 1024)
TPU_TILES_K = (16, 32, 64, 128, 192, 256, 384, 512, 768, 1024, 2048)


def _tiles(target: str, which: str):
    if target == "host":
        return {"a": HOST_TILES_A, "b": HOST_TILES_B, "c": HOST_TILES_C}[which]
    return {"a": TPU_TILES, "b": TPU_TILES_K, "c": TPU_TILES}[which]


def syr2k_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("pack_a", (True, False), default=False),
        Categorical("pack_b", (True, False), default=False),
        Categorical("interchange", (True, False), default=False),
        Ordinal("bi", _tiles(target, "a"), default=_tiles(target, "a")[8]),
        Ordinal("bk", _tiles(target, "b"), default=_tiles(target, "b")[-1]),
        Ordinal("bj", _tiles(target, "c"), default=_tiles(target, "c")[-1]),
    ])
    # the paper's CS.InCondition: consider packing B only when A is packed
    cs.add_condition(InCondition("pack_b", "pack_a", (True,)))
    return cs


def mm3_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("pack1", (True, False), default=True),
        Categorical("pack2", (True, False), default=True),
        Categorical("pack3", (True, False), default=True),
        Categorical("inter1", (True, False), default=False),
        Categorical("inter2", (True, False), default=False),
        Categorical("inter3", (True, False), default=False),
        Categorical("fuse_second", (True, False), default=False),
        Ordinal("bm", _tiles(target, "a"), default=_tiles(target, "a")[8]),
        Ordinal("bk", _tiles(target, "b"), default=_tiles(target, "b")[-1]),
        Ordinal("bn", _tiles(target, "c"), default=_tiles(target, "c")[-1]),
    ])
    return cs


def lu_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    cs = ConfigurationSpace(seed=seed)
    panel = (8, 16, 32, 64, 128) if target == "tpu" else (4, 8, 16, 32, 64)
    cs.add_hyperparameters([
        Categorical("pack", (True, False), default=True),
        Ordinal("bs", panel, default=panel[2]),
        Ordinal("bm", _tiles(target, "a"), default=_tiles(target, "a")[8]),
        Ordinal("bn", _tiles(target, "c"), default=_tiles(target, "c")[-1]),
    ])
    return cs


def heat3d_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    cs = ConfigurationSpace(seed=seed)
    bi = (1, 2, 4, 8, 16, 32) if target == "tpu" else (1, 2, 4, 8, 16, 32)
    cs.add_hyperparameters([
        Ordinal("bi", bi, default=8),
        Categorical("fuse_t", (1, 2), default=1),
    ])
    return cs


def covariance_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("fuse_center", (True, False), default=True),
        Categorical("interchange", (True, False), default=False),
        Ordinal("bi", _tiles(target, "a"), default=_tiles(target, "a")[8]),
        Ordinal("bk", _tiles(target, "b"), default=_tiles(target, "b")[-1]),
        Ordinal("bj", _tiles(target, "c"), default=_tiles(target, "c")[-1]),
    ])
    return cs


def floyd_warshall_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    cs = ConfigurationSpace(seed=seed)
    blocks = (16, 32, 64, 128, 256) if target == "tpu" else (4, 8, 16, 32, 64, 100)
    cs.add_hyperparameters([
        Ordinal("bs", blocks, default=blocks[2]),
        Ordinal("bi", _tiles(target, "a"), default=_tiles(target, "a")[8]),
        Ordinal("bj", _tiles(target, "c"), default=_tiles(target, "c")[-1]),
        Ordinal("unroll", (1, 2, 4, 8), default=1),
    ])
    return cs


# ---------------------------------------------------------------------------
# model-kernel spaces: the serving hot path's schedule knobs (beyond-paper)
# ---------------------------------------------------------------------------

# flash-attention q/k block sizes; host entries small enough for interpret mode
FLASH_TILES_TPU = (128, 256, 512, 1024)
FLASH_TILES_HOST = (16, 32, 64, 128, 256, 512)


def flash_attention_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    """Tile space over ``bq``/``bk`` plus the implementation variant axis:
    the Pallas online-softmax kernel vs the chunked-XLA fallback (which only
    reads ``bq`` as its query-chunk size)."""
    cs = ConfigurationSpace(seed=seed)
    tiles = FLASH_TILES_TPU if target == "tpu" else FLASH_TILES_HOST
    cs.add_hyperparameters([
        Categorical("impl", ("pallas", "xla"),
                    default="pallas" if target == "tpu" else "xla"),
        Ordinal("bq", tiles, default=128),
        Ordinal("bk", tiles, default=128),
    ])
    return cs


# decode KV-block tiles and paged-cache page sizes; host entries small
# enough that interpret-mode sweeps stay millisecond-scale
DECODE_TILES_TPU = (128, 256, 512, 1024)
DECODE_TILES_HOST = (8, 16, 32, 64, 128, 256)
PAGE_SIZES_TPU = (64, 128, 256, 512)
PAGE_SIZES_HOST = (8, 16, 32, 64, 128)


def decode_attention_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    """Decode-attention space: KV block ``bk``, head-grouping ``hg`` (rows
    per grid cell), the ``impl`` variant axis, and the paged KV cache's
    ``page`` size — a layout axis (arXiv 2010.06521's point that layout
    belongs in the tuned space): it fixes the seq-bucket granularity the
    dispatch signature sees, trading padded attention work against
    per-bucket retrace frequency."""
    cs = ConfigurationSpace(seed=seed)
    tiles = DECODE_TILES_TPU if target == "tpu" else DECODE_TILES_HOST
    pages = PAGE_SIZES_TPU if target == "tpu" else PAGE_SIZES_HOST
    cs.add_hyperparameters([
        Categorical("impl", ("pallas", "xla"),
                    default="pallas" if target == "tpu" else "xla"),
        Ordinal("bk", tiles, default=128),
        Ordinal("hg", (1, 2, 4, 8), default=1),
        Ordinal("page", pages, default=pages[-1]),
    ])
    return cs


def matmul_space(target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    """Blocked-matmul space for the model projection/unembed call sites."""
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("pack", (True, False), default=False),
        Categorical("interchange", (True, False), default=False),
        Ordinal("bm", _tiles(target, "a"), default=_tiles(target, "a")[8]),
        Ordinal("bk", _tiles(target, "b"), default=_tiles(target, "b")[-1]),
        Ordinal("bn", _tiles(target, "c"), default=_tiles(target, "c")[-1]),
    ])
    return cs


KERNEL_SPACES = {
    "syr2k": syr2k_space,
    "mm3": mm3_space,
    "lu": lu_space,
    "heat3d": heat3d_space,
    "covariance": covariance_space,
    "floyd_warshall": floyd_warshall_space,
    "flash_attention": flash_attention_space,
    "decode_attention": decode_attention_space,
    "matmul": matmul_space,
}


def kernel_space(name: str, target: str = "tpu", seed: int = 1234) -> ConfigurationSpace:
    return KERNEL_SPACES[name](target=target, seed=seed)
