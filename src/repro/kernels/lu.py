"""Blocked LU decomposition (no pivoting), Sec. 4.3.

Right-looking blocked algorithm with block size ``bs``:

  per block step kb:
    1. factor the diagonal block (unblocked Doolittle, masked updates);
    2. row panel  U12 = L11^{-1} A12   (unit-lower triangular solve);
    3. col panel  L21 = A21 U11^{-1}   (upper triangular solve);
    4. trailing update A22 -= L21 @ U12 — the GEMM hot spot, executed by the
       tunable Pallas tiled-matmul kernel.

To keep every shape static under jit (the trailing submatrix shrinks), panels
are held at full (N x bs)/(bs x N) extent and masked with iota comparisons:
rows/cols outside the active region are zeroed, so the full-size GEMM update
is a no-op there. The paper's knobs map to: ``bs`` = the panel tile (P3-role),
``bm``/``bn`` = trailing-GEMM tiles, ``pack`` = GEMM packing.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels.matmul import tiled_matmul
from repro.kernels.util import cdiv, default_interpret, pad_to

__all__ = ["lu"]


def _factor_diag(D: jnp.ndarray) -> jnp.ndarray:
    """Unblocked Doolittle on a bs x bs block, masked for static shapes."""
    bs = D.shape[0]
    rows = jnp.arange(bs)

    def step(r, M):
        piv = M[r, r]
        m = jnp.where(rows > r, M[:, r] / piv, 0.0)
        row = jnp.where(rows > r, M[r, :], 0.0)
        M = M - jnp.outer(m, row)
        M = M.at[:, r].set(jnp.where(rows > r, m, M[:, r]))
        return M

    return jax.lax.fori_loop(0, bs, step, D)


def _unit_lower_solve(L: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Solve L X = B with L unit lower triangular (bs x bs), B (bs x n)."""
    bs = L.shape[0]

    def step(i, X):
        # x_i = b_i - sum_{j<i} L[i,j] x_j  (unit diagonal)
        contrib = jnp.where(jnp.arange(bs)[:, None] < i, X, 0.0)
        xi = B[i, :] - L[i, :] @ contrib
        return X.at[i, :].set(xi)

    return jax.lax.fori_loop(0, bs, step, jnp.zeros_like(B))


def _upper_right_solve(U: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Solve X U = B with U upper triangular (bs x bs), B (n x bs)."""
    bs = U.shape[0]

    def step(j, X):
        contrib = jnp.where(jnp.arange(bs)[None, :] < j, X, 0.0)
        xj = (B[:, j] - contrib @ U[:, j]) / U[j, j]
        return X.at[:, j].set(xj)

    return jax.lax.fori_loop(0, bs, step, jnp.zeros_like(B))


def lu(
    A: jnp.ndarray,
    *,
    bs: int = 32,
    bm: int = 128,
    bn: int = 128,
    pack: bool = True,
    matmul_impl: Literal["pallas", "xla"] = "pallas",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Packed LU of A (N x N): L strictly below the diagonal (unit implied),
    U on/above. Matches ``ref.lu_ref``."""
    if interpret is None:
        interpret = default_interpret()
    N = A.shape[0]
    bs = min(bs, N)
    Ap = pad_to(A, (bs, bs))
    Np = Ap.shape[0]
    if Np != N:
        # keep padded diagonal nonsingular; padding is identity outside A
        idx = jnp.arange(N, Np)
        Ap = Ap.at[idx, idx].set(1.0)
    nb = Np // bs
    rows = jnp.arange(Np)

    def block_step(kb, M):
        off = kb * bs
        D = jax.lax.dynamic_slice(M, (off, off), (bs, bs))
        D = _factor_diag(D)
        L11 = jnp.tril(D, -1) + jnp.eye(bs, dtype=D.dtype)
        U11 = jnp.triu(D)

        # full-width row panel, solve, then mask to columns right of the block
        row_panel = jax.lax.dynamic_slice(M, (off, 0), (bs, Np))
        U12_full = _unit_lower_solve(L11, row_panel)
        col_ids = rows[None, :]
        right = col_ids >= off + bs
        new_row = jnp.where(right, U12_full, row_panel)
        # write the factored diagonal block into its columns
        in_diag = (col_ids >= off) & (col_ids < off + bs)
        diag_cols = jax.lax.dynamic_update_slice(
            jnp.zeros_like(row_panel), D, (0, off)
        )
        new_row = jnp.where(in_diag, diag_cols, new_row)
        M = jax.lax.dynamic_update_slice(M, new_row, (off, 0))

        # full-height column panel
        col_panel = jax.lax.dynamic_slice(M, (0, off), (Np, bs))
        L21_full = _upper_right_solve(U11, col_panel)
        row_ids = rows[:, None]
        below = row_ids >= off + bs
        new_col = jnp.where(below, L21_full, col_panel)
        M = jax.lax.dynamic_update_slice(M, new_col, (0, off))

        # trailing update: A22 -= L21 @ U12 (masked panels make it exact)
        Lmask = jnp.where(below, new_col, 0.0)          # (Np, bs)
        Umask = jnp.where(right, new_row, 0.0)          # (bs, Np)
        if matmul_impl == "pallas":
            upd = tiled_matmul(
                Lmask, Umask, bm=bm, bn=bn, bk=bs, pack=pack, interpret=interpret
            )
        else:
            upd = Lmask @ Umask
        return M - upd

    out = jax.lax.fori_loop(0, nb, block_step, Ap)
    return out[:N, :N]
