"""Public jit'd entry points for the six PolyBench-analog kernels.

Each op takes an optional ``config`` dict in the same schema the autotuner
searches (see spaces.py), defaulting to the VMEM/MXU-derived defaults (the
TPU analog of the paper's cache-derived (96, 2048, 256) defaults).
"""

from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp

# NB: resolve the submodules via importlib — the package __init__ re-exports
# same-named functions, which shadow plain `import repro.kernels.x as _x`
# (the `as` form reads the package attribute, which is the function)
import importlib

_cov = importlib.import_module("repro.kernels.covariance")
_fw = importlib.import_module("repro.kernels.floyd_warshall")
_heat = importlib.import_module("repro.kernels.heat3d")
_lu = importlib.import_module("repro.kernels.lu")
_m3 = importlib.import_module("repro.kernels.m3mm")
_mm = importlib.import_module("repro.kernels.matmul")
_sy = importlib.import_module("repro.kernels.syr2k")

__all__ = [
    "matmul_op", "syr2k_op", "mm3_op", "lu_op", "heat3d_op", "covariance_op",
    "floyd_warshall_op", "DEFAULTS",
]

DEFAULTS: dict[str, dict[str, Any]] = {
    "matmul": dict(bm=128, bn=128, bk=128, interchange=False, pack=True),
    "syr2k": dict(bi=128, bj=128, bk=128, interchange=False,
                  pack_a=False, pack_b=False),
    "mm3": dict(bm=128, bn=128, bk=128, pack1=True, pack2=True, pack3=True,
                inter1=False, inter2=False, inter3=False, fuse_second=False),
    "lu": dict(bs=32, bm=128, bn=128, pack=True),
    "heat3d": dict(bi=8, fuse_t=1),
    "covariance": dict(bi=128, bj=128, bk=256, fuse_center=True, interchange=False),
    "floyd_warshall": dict(bs=64, bi=128, bj=128, unroll=1),
}


def _merged(name: str, config: Mapping[str, Any] | None) -> dict:
    out = dict(DEFAULTS[name])
    if config:
        out.update({k: v for k, v in config.items() if k in out})
    return out


def matmul_op(a, b, config=None, interpret=None):
    return _mm.tiled_matmul(a, b, **_merged("matmul", config), interpret=interpret)


def syr2k_op(C, A, B, alpha=1.5, beta=1.2, config=None, interpret=None):
    return _sy.syr2k(C, A, B, alpha, beta, **_merged("syr2k", config),
                     interpret=interpret)


def mm3_op(A, B, C, D, config=None, interpret=None):
    return _m3.mm3(A, B, C, D, **_merged("mm3", config), interpret=interpret)


def lu_op(A, config=None, interpret=None):
    return _lu.lu(A, **_merged("lu", config), interpret=interpret)


def heat3d_op(A, tsteps, config=None, interpret=None):
    return _heat.heat3d(A, tsteps, **_merged("heat3d", config), interpret=interpret)


def covariance_op(data, config=None, interpret=None):
    return _cov.covariance(data, **_merged("covariance", config), interpret=interpret)


def floyd_warshall_op(path, config=None, interpret=None):
    return _fw.floyd_warshall(
        path, **_merged("floyd_warshall", config),
        allow_semiring_reassociation=True, interpret=interpret,
    )
