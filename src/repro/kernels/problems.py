"""Canonical bench/LARGE problem registry + the cost-backend evaluators.

One table of problem dimensions per kernel, shared by the autotune CLI
(``repro.launch.autotune``), the pallas-tuning benchmark
(``benchmarks.pallas_tuning``), and the cost-backend background tuner —
previously the CLI's ``BENCH_PROBLEMS``/``BENCH_DIMS`` and the benchmark's
shape tables drifted independently.

  * ``BENCH_DIMS`` — host-timeable sizes (backend B1, the paper's Core-i7
    role): small enough that one evaluation is milliseconds on CPU.
  * ``LARGE_SHAPES`` — the paper's LARGE dataset sizes (backend B2, scored
    by the analytic TPU cost model); the model kernels use a 16-head
    4k-context serving shape as their LARGE analog.
  * ``DEFAULTS_TPU`` — the MXU-default schedules the benchmark compares
    autotuned configs against.

The cost-backend half closes the "background tuning on the cost backend"
loop: :func:`make_cost_evaluator` scores configs with
:func:`repro.kernels.cost.kernel_cost` at fixed dims, and
:func:`register_cost_backend` re-registers every costed kernel with a
``VariantSpec.make_evaluator`` that derives the dims from the campaign's
runtime argument shapes — so a :class:`~repro.dispatch.BackgroundTuner`
attached to a TPU-target :class:`~repro.dispatch.DispatchService` tunes
schedules analytically on a host with no TPU attached.
"""

from __future__ import annotations

from typing import Callable, Mapping

import numpy as np

from repro.core.plopper import EvalResult

__all__ = [
    "BENCH_DIMS",
    "BENCH_PROBLEMS",
    "DEFAULTS_TPU",
    "LARGE_SHAPES",
    "PROXY_DIMS",
    "bench_problem",
    "dims_from_signature",
    "fidelity_ready",
    "fidelity_readiness",
    "make_cost_evaluator",
    "problem_signature_for",
    "register_cost_backend",
]

# host-timeable problem dims behind the bench problems (heat3d includes its
# tsteps knob); the per-kernel dim order matches kernels.ref init_* functions
BENCH_DIMS = {
    "syr2k": (240, 200),
    "mm3": (200, 180, 160, 150, 170),
    "lu": (256,),
    "heat3d": (40, 8),
    "covariance": (300, 240),
    "floyd_warshall": (240,),
    "flash_attention": (4, 128, 128, 64),
    "decode_attention": (8, 2, 128, 64),   # (BH, G, seq_bucket, hd)
    "matmul": (256, 192, 224),
}

# the paper's LARGE dataset sizes per kernel; the model kernels (serving hot
# path) use a 16-head 4k-context serving shape as their "LARGE" analog
LARGE_SHAPES = {
    "syr2k": (1200, 1000),
    "mm3": (800, 900, 1000, 1100, 1200),
    "lu": (2000,),
    "heat3d": (120, 500),
    "covariance": (1400, 1200),
    "floyd_warshall": (2800,),
    "flash_attention": (16, 4096, 4096, 128),
    "decode_attention": (16, 8, 4096, 128),
    "matmul": (2000, 2300, 2600),
}

# reduced-shape proxy sizes (repro.fidelity rung 1): the same kernels at
# roughly half the linear problem dims (an eighth of the work for the cubic
# kernels), so a proxy evaluation costs a fraction of the full bench timing
# while preserving the schedule landscape's ordering well enough to screen.
# heat3d additionally cuts tsteps (a pure multiplier on config ranking).
PROXY_DIMS = {
    "syr2k": (120, 100),
    "mm3": (100, 90, 80, 75, 85),
    "lu": (128,),
    "heat3d": (24, 4),
    "covariance": (150, 120),
    "floyd_warshall": (120,),
    "flash_attention": (2, 64, 64, 64),
    "decode_attention": (4, 2, 64, 64),
    "matmul": (128, 96, 112),
}

DEFAULTS_TPU = {
    "syr2k": dict(bi=128, bj=128, bk=128),
    "mm3": dict(bm=128, bn=128, bk=128),
    "lu": dict(bs=32, bm=128, bn=128),
    "heat3d": dict(bi=8, fuse_t=1),
    "covariance": dict(bi=128, bj=128, bk=256),
    "floyd_warshall": dict(bs=64, bi=128, bj=128, unroll=1),
    "flash_attention": dict(impl="pallas", bq=128, bk=128),
    "decode_attention": dict(impl="pallas", bk=128, hg=1, page=128),
    "matmul": dict(bm=128, bn=128, bk=128, pack=True),
}


def bench_problem(name: str, dims: tuple | None = None):
    """Variant factory for ``name`` — the thing a
    :class:`~repro.core.plopper.TimingEvaluator` wall-clocks (backend B1).
    Defaults to :data:`BENCH_DIMS` sizes; pass ``dims`` explicitly (e.g.
    :data:`PROXY_DIMS`) for the fidelity ladder's reduced-shape proxy rung."""
    from repro.kernels import model_kernels as MK
    from repro.kernels import ref as R
    from repro.kernels import variants as V

    dims = BENCH_DIMS[name] if dims is None else tuple(dims)
    if name == "heat3d":
        return V.heat3d_host(R.init_heat3d(dims[0]), tsteps=dims[1])
    if name == "flash_attention":
        return MK.flash_attention_host(MK.init_flash_attention(*dims))
    if name == "decode_attention":
        return MK.decode_attention_host(MK.init_decode_attention(*dims))
    if name == "matmul":
        return MK.matmul_host(MK.init_matmul(*dims))
    init = getattr(R, f"init_{name}")
    host = getattr(V, f"{name}_host")
    return host(init(*dims))


# name -> thunk returning that kernel's variant factory; the registry form of
# :func:`bench_problem` for callers that iterate the bench suite
BENCH_PROBLEMS = {name: (lambda n=name: bench_problem(n)) for name in BENCH_DIMS}


def problem_signature_for(kernel: str, backend: str):
    """Per-argument store signature for a kernel's canonical problem — the
    same scheme ``repro.dispatch`` derives from runtime args, so configs
    published offline resolve at ``dispatch()`` time. Host-backend campaigns
    run at :data:`BENCH_DIMS`; cost-backend campaigns at the paper's
    :data:`LARGE_SHAPES`."""
    from repro.kernels.ref import problem_signature

    dims = LARGE_SHAPES[kernel] if backend == "cost" else BENCH_DIMS[kernel]
    return problem_signature(kernel, *dims)


def dims_from_signature(kernel: str, signature) -> tuple:
    """Inverse of :func:`repro.kernels.ref.problem_signature`: recover the
    problem dims from a (possibly runtime-derived) shape signature. Trailing
    static-kwarg entries (e.g. flash attention's folded ``causal`` flag) are
    ignored."""
    if kernel == "syr2k":
        return (signature[0][0], signature[1][1])
    if kernel == "mm3":
        (P, Q), (_, R_), (_, S), (_, T) = signature[:4]
        return (P, Q, R_, S, T)
    if kernel == "lu":
        return (signature[0][0],)
    if kernel == "heat3d":
        # tsteps rides in as a static-kwarg entry when present (dispatch folds
        # it into the runtime signature); a bare-array signature — e.g. a
        # background factory whose args are just the grid — scores one step,
        # which preserves config ranking (tsteps is a pure multiplier)
        t = signature[1][0] if len(signature) > 1 and len(signature[1]) == 1 else 1
        return (signature[0][0], t)
    if kernel == "covariance":
        return tuple(signature[0])
    if kernel == "floyd_warshall":
        return (signature[0][0],)
    if kernel == "flash_attention":
        (BH, Sq, hd), (_, Sk, _) = signature[0], signature[1]
        return (BH, Sq, Sk, hd)
    if kernel == "decode_attention":
        (BH, G, hd), (_, S, _) = signature[0], signature[1]
        return (BH, G, S, hd)
    if kernel == "matmul":
        (M, K), (_, N) = signature[0], signature[1]
        return (M, K, N)
    raise KeyError(f"unknown kernel {kernel!r}")


def fidelity_ready(kernel: str) -> bool:
    """True when ``kernel`` can participate in the fidelity ladder's rung 0:
    an analytic cost-model entry exists to screen with. Kernels without one
    can still cascade over the timing rungs, but pay hardware (or proxy
    hardware) for every screen."""
    from repro.kernels.cost import KERNEL_COST_FNS

    return kernel in KERNEL_COST_FNS


def fidelity_readiness() -> dict[str, bool]:
    """``kernel -> fidelity_ready`` over every dispatch-registered kernel —
    the machine-readable coverage map ``repro-analyze space`` publishes, so a
    kernel registered for dispatch but missing a cost-model entry (and thus
    unable to join rung 0) is a reviewable fact rather than a silent gap."""
    from repro.dispatch.registry import registered

    return {name: fidelity_ready(name) for name in registered()}


def make_cost_evaluator(kernel: str, dims: tuple | None = None) -> Callable:
    """``config -> EvalResult`` scored by the analytic TPU cost model at
    ``dims`` (default: the paper's LARGE sizes). Infeasible configs (VMEM
    over budget) come back failed with the model's penalty semantics."""
    from repro.kernels.cost import kernel_cost

    shape = tuple(dims) if dims is not None else LARGE_SHAPES[kernel]

    def evaluate(cfg: Mapping) -> EvalResult:
        t, info = kernel_cost(kernel, cfg, *shape)
        if not np.isfinite(t):
            return EvalResult(1e9, False, info)
        return EvalResult(t, True, info)

    return evaluate


def _cost_make_evaluator(kernel: str) -> Callable:
    """A ``VariantSpec.make_evaluator``: given a background campaign's
    ``factory(config) -> (fn, args)``, return an evaluator that never runs
    ``fn`` — it derives the problem dims from the args' shapes and scores the
    config analytically. Thread-safe and hardware-free by construction."""

    def make(factory: Callable) -> Callable:
        inner: list[Callable] = []  # built once, after dims are derived

        def evaluate(cfg: Mapping) -> EvalResult:
            if not inner:
                _, args = factory(cfg)
                sig = tuple(tuple(int(d) for d in np.shape(a)) for a in args)
                inner.append(make_cost_evaluator(kernel, dims_from_signature(kernel, sig)))
            return inner[0](cfg)

        return evaluate

    return make


def register_cost_backend() -> None:
    """Re-register every costed kernel into the dispatch registry with the
    roofline cost model as its background-campaign evaluator. Call this on a
    TPU-target host before attaching a :class:`~repro.dispatch.BackgroundTuner`
    to a ``DispatchService(backend="cost", target="tpu")`` — campaigns then
    tune BlockSpec geometry against the analytic model instead of
    wall-clocking XLA-on-host, which is meaningless for a TPU target."""
    import functools

    from repro.dispatch.registry import get, register
    from repro.kernels.cost import KERNEL_COST_FNS
    from repro.kernels.spaces import kernel_space

    for name in KERNEL_COST_FNS:
        spec = get(name)  # loads builtins; preserves each kernel's builder
        register(name, spec.builder,
                 space=functools.partial(kernel_space, name),
                 make_evaluator=_cost_make_evaluator(name))
