"""Blocked Floyd-Warshall in the (min, +) semiring, Sec. 4.6.

The paper's most instructive case: Polly's static heuristic *regressed* FW by
9x (its ISL schedule destroyed spatial locality), and tiling FW at all
requires `-polly-pragma-ignore-depcheck` because the legality of the blocked
schedule rests on min-plus algebra, which no dependence test can prove.

TPU adaptation: the blocked FW is the classic 3-phase algorithm where every
phase is a **min-plus matrix product** — pure VPU work (no MXU for `min`), so
the kernel's roofline is memory-bound; blocking exists to keep D tiles in
VMEM across the k-sweep exactly as CPU blocking keeps them in cache.

  phase 1  diagonal block transitive closure (in-block FW),
  phase 2  row panel  D[kb,j] = min(D[kb,j], D[kb,kb] (x) D[kb,j]),
           col panel  D[i,kb] = min(D[i,kb], D[i,kb] (x) D[kb,kb]),
  phase 3  trailing   D[i,j]  = min(D[i,j],  D[i,kb] (x) D[kb,j])   [Pallas]

``allow_semiring_reassociation=True`` is mandatory to run the blocked kernel
— the explicit, caller-visible analog of ``-polly-pragma-ignore-depcheck``.
Knobs: ``bs`` (block), ``bi``/``bj`` (phase-3 grid tiles), ``unroll`` (the
k-sweep unroll factor inside the kernel, the paper's unroll-pragma analog).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import cdiv, default_interpret, pad_to, tpu_compiler_params

__all__ = ["floyd_warshall", "minplus_update"]

_BIG = 1.0e18  # padding distance: +inf surrogate that survives addition


def _minplus_kernel(d_ref, a_ref, b_ref, o_ref, *, bs: int, unroll: int):
    """o = min(d, min_k a[:, k] + b[k, :]) over the bs-wide contraction."""
    acc = d_ref[...]

    def body(k, acc):
        return jnp.minimum(acc, a_ref[:, k][:, None] + b_ref[k, :][None, :])

    acc = jax.lax.fori_loop(0, bs, body, acc, unroll=unroll)
    o_ref[...] = acc


def minplus_update(
    D: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    *,
    bi: int = 128,
    bj: int = 128,
    unroll: int = 1,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """min(D, A (x) B): D (n x m), A (n x bs), B (bs x m); one k-block deep."""
    if interpret is None:
        interpret = default_interpret()
    n, m = D.shape
    bs = A.shape[1]
    assert A.shape == (n, bs) and B.shape == (bs, m)
    bi = min(bi, n)
    bj = min(bj, m)

    Dp = pad_to(D, (bi, bj), value=_BIG)
    Ap = pad_to(A, (bi, 1), value=_BIG)
    Bp = pad_to(B, (1, bj), value=_BIG)
    ni, nj = Dp.shape[0] // bi, Dp.shape[1] // bj

    out = pl.pallas_call(
        functools.partial(_minplus_kernel, bs=bs, unroll=unroll),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
            pl.BlockSpec((bi, bs), lambda i, j: (i, 0)),
            pl.BlockSpec((bs, bj), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(Dp.shape, D.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(Dp, Ap, Bp)
    return out[:n, :m]


def _closure_in_block(D: jnp.ndarray) -> jnp.ndarray:
    """In-block Floyd-Warshall (phase 1), bs relaxation sweeps."""
    bs = D.shape[0]

    def step(k, M):
        return jnp.minimum(M, M[:, k][:, None] + M[k, :][None, :])

    return jax.lax.fori_loop(0, bs, step, D)


def floyd_warshall(
    path: jnp.ndarray,
    *,
    bs: int = 64,
    bi: int = 128,
    bj: int = 128,
    unroll: int = 1,
    allow_semiring_reassociation: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """All-pairs shortest paths. The blocked schedule reorders (min, +)
    reductions, which is only legal because (min, +) is a commutative
    semiring; like Polly, we refuse unless the caller asserts it."""
    if not allow_semiring_reassociation:
        raise ValueError(
            "blocked Floyd-Warshall reassociates the (min,+) reduction; pass "
            "allow_semiring_reassociation=True (the -polly-pragma-ignore-"
            "depcheck analog) or use ref.floyd_warshall_ref"
        )
    if interpret is None:
        interpret = default_interpret()
    N = path.shape[0]
    bs = min(bs, N)
    Dp = pad_to(path, (bs, bs), value=_BIG)
    Np = Dp.shape[0]
    nb = Np // bs

    def block_round(kb, D):
        off = kb * bs
        # phase 1: diagonal block closure
        diag = jax.lax.dynamic_slice(D, (off, off), (bs, bs))
        diag = _closure_in_block(diag)
        D = jax.lax.dynamic_update_slice(D, diag, (off, off))

        # phase 2: row panel then column panel (each one min-plus product)
        row = jax.lax.dynamic_slice(D, (off, 0), (bs, Np))
        row = minplus_update(row, diag, row, bi=bs, bj=bj, unroll=unroll,
                             interpret=interpret)
        D = jax.lax.dynamic_update_slice(D, row, (off, 0))

        col = jax.lax.dynamic_slice(D, (0, off), (Np, bs))
        col = minplus_update(col, col, diag, bi=bi, bj=bs, unroll=unroll,
                             interpret=interpret)
        D = jax.lax.dynamic_update_slice(D, col, (0, off))

        # phase 3: trailing full update (the Pallas grid kernel)
        D = minplus_update(D, col, row, bi=bi, bj=bj, unroll=unroll,
                           interpret=interpret)
        return D

    out = jax.lax.fori_loop(0, nb, block_round, Dp)
    return out[:N, :N]
