"""3mm Pallas pipeline: G = (A@B) @ (C@D), Sec. 4.2.

Three tiled-matmul invocations sharing one tile triple (bm, bn, bk) — the
paper's 3mm space is exactly 7 binary pragma choices x 3 shared tile ordinals
(2^7 * 11^3 = 170,368 configurations). The 7 binaries here: per-matmul
``pack`` (3), per-matmul ``interchange`` (3), and ``fuse_second`` which keeps
E = A@B resident and feeds it straight into the third product without a
round trip through HBM at full precision (f32 -> input dtype cast skipped).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.matmul import tiled_matmul

__all__ = ["mm3"]


def mm3(
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    pack1: bool = True,
    pack2: bool = True,
    pack3: bool = True,
    inter1: bool = False,
    inter2: bool = False,
    inter3: bool = False,
    fuse_second: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    E = tiled_matmul(A, B, bm=bm, bn=bn, bk=bk, pack=pack1, interchange=inter1,
                     out_dtype=jnp.float32 if fuse_second else None,
                     interpret=interpret)
    F = tiled_matmul(C, D, bm=bm, bn=bn, bk=bk, pack=pack2, interchange=inter2,
                     out_dtype=jnp.float32 if fuse_second else None,
                     interpret=interpret)
    G = tiled_matmul(E, F, bm=bm, bn=bn, bk=bk, pack=pack3, interchange=inter3,
                     out_dtype=A.dtype, interpret=interpret)
    return G
