"""heat-3d Pallas stencil kernel (Sec. 4.4).

PolyBench heat-3d applies a 3-axis second-difference update to the interior
of an N^3 grid, twice per time step (A->B, B->A). TPU adaptation:

  * the i (outermost) axis is grid-tiled with block size ``bi``; j and k stay
    resident in VMEM (an (bi+2h) x N x N f32 slab is a few hundred KB at
    PolyBench sizes — VMEM-friendly);
  * halo exchange uses the neighbor-block trick: the same input array is bound
    three times with index maps (i-1, i, i+1) (clamped at the edges), so each
    kernel instance sees its top/bottom halo rows without overlapping
    BlockSpecs;
  * ``fuse_t`` in {1, 2} is the *temporal blocking* knob — fuse_t=2 applies
    two time updates per HBM round trip with a 2-deep halo, halving stencil
    HBM traffic (the TPU-native analog of tiling the time loop, which is what
    Polly's default heat-3d schedule attempts on CPU).

Boundary handling is by masking with global indices, so halo garbage at the
array edges (from clamped index maps) never propagates — see the step-by-step
argument in the kernel body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import cdiv, default_interpret, pad_to, tpu_compiler_params

__all__ = ["heat3d", "heat3d_step"]


def _masked_update(ext: jnp.ndarray, g_rows: jnp.ndarray, n0: int) -> jnp.ndarray:
    """One masked stencil application on an extended slab.

    ``ext``: (L, N1, N2); rows 1..L-2 get the update where their *global* row
    index is interior; everything else copies through. Rows whose global index
    falls outside [0, n0) hold garbage, but garbage only feeds rows that the
    mask forces to copy, so it never propagates into kept values.
    """
    L, n1, n2 = ext.shape
    mid = ext[1:-1]
    i_diff = ext[2:] - 2.0 * mid + ext[:-2]

    jp = jnp.concatenate([mid[:, 1:, :], mid[:, -1:, :]], axis=1)
    jm = jnp.concatenate([mid[:, :1, :], mid[:, :-1, :]], axis=1)
    j_diff = jp - 2.0 * mid + jm

    kp = jnp.concatenate([mid[:, :, 1:], mid[:, :, -1:]], axis=2)
    km = jnp.concatenate([mid[:, :, :1], mid[:, :, :-1]], axis=2)
    k_diff = kp - 2.0 * mid + km

    new = 0.125 * i_diff + 0.125 * j_diff + 0.125 * k_diff + mid

    gi = g_rows[1:-1][:, None, None]
    jj = jnp.arange(n1)[None, :, None]
    kk = jnp.arange(n2)[None, None, :]
    interior = (
        (gi > 0) & (gi < n0 - 1)
        & (jj > 0) & (jj < n1 - 1)
        & (kk > 0) & (kk < n2 - 1)
    )
    new = jnp.where(interior, new, mid)
    return jnp.concatenate([ext[:1], new, ext[-1:]], axis=0)


def _heat_kernel(prev_ref, cur_ref, next_ref, o_ref, *, bi: int, h: int, n0: int):
    i = pl.program_id(0)
    ext = jnp.concatenate(
        [prev_ref[...][-h:], cur_ref[...], next_ref[...][:h]], axis=0
    )  # (bi + 2h, N1, N2)
    g_rows = i * bi - h + jnp.arange(bi + 2 * h)
    for _ in range(h):  # fused time steps (temporal blocking)
        ext = _masked_update(ext, g_rows, n0)
    o_ref[...] = ext[h : h + bi]


def heat3d_step(
    A: jnp.ndarray,
    *,
    bi: int = 8,
    fuse_t: int = 1,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """``fuse_t`` masked stencil applications in one Pallas pass."""
    if interpret is None:
        interpret = default_interpret()
    n0, n1, n2 = A.shape
    bi = min(bi, n0)
    h = fuse_t
    Ap = pad_to(A, (bi, 1, 1))
    ni = Ap.shape[0] // bi

    out = pl.pallas_call(
        functools.partial(_heat_kernel, bi=bi, h=h, n0=n0),
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((bi, n1, n2), lambda i: (jnp.maximum(i - 1, 0), 0, 0)),
            pl.BlockSpec((bi, n1, n2), lambda i: (i, 0, 0)),
            pl.BlockSpec((bi, n1, n2), lambda i: (jnp.minimum(i + 1, ni - 1), 0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, n1, n2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(Ap.shape, A.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(Ap, Ap, Ap)
    return out[:n0]


def heat3d(
    A: jnp.ndarray,
    tsteps: int,
    *,
    bi: int = 8,
    fuse_t: int = 1,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """PolyBench heat-3d: 2*tsteps stencil applications (A->B->A per step)."""
    total = 2 * tsteps
    assert total % fuse_t == 0, "fuse_t must divide 2*tsteps"
    step = functools.partial(heat3d_step, bi=bi, fuse_t=fuse_t, interpret=interpret)
    return jax.lax.fori_loop(0, total // fuse_t, lambda _, x: step(x), A)
