"""covariance Pallas kernel: data (N x M) -> cov (M x M) (Sec. 4.5).

cov = (data - mean)^T (data - mean) / (N-1) — a centered SYRK. Knobs:

  * ``bi``/``bj``  — output (attribute x attribute) tile;
  * ``bk``         — reduction tile over the N data points;
  * ``fuse_center``— subtract the column means inside the kernel (fusing the
                     PolyBench centering loop into the update loop) vs.
                     centering in a separate XLA pass before the kernel;
  * ``interchange``— swap the two output grid axes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import cdiv, default_interpret, pad_to, tpu_compiler_params, unpad

__all__ = ["covariance"]


def _cov_kernel(di_ref, dj_ref, mi_ref, mj_ref, o_ref, acc_ref,
                *, nk: int, denom: float, fuse_center: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    di = di_ref[...]  # (bk, bi) slab of data columns i
    dj = dj_ref[...]  # (bk, bj)
    if fuse_center:
        di = di - mi_ref[...]  # (1, bi) broadcast over rows
        dj = dj - mj_ref[...]
    acc_ref[...] += jnp.dot(di.T, dj, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def covariance(
    data: jnp.ndarray,
    *,
    bi: int = 128,
    bj: int = 128,
    bk: int = 256,
    fuse_center: bool = True,
    interchange: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    N, M = data.shape
    bi = min(bi, M)
    bj = min(bj, M)
    bk = min(bk, N)

    mean = data.mean(axis=0, keepdims=True)  # (1, M)
    if not fuse_center:
        data = data - mean

    l = math.lcm(bi, bj)
    Mp = cdiv(M, l) * l
    dp = pad_to(data, (bk, Mp))
    # padded rows must not perturb the sums: zero rows are exactly neutral
    # when fuse_center=False; when fusing, padded rows would contribute
    # (0-mean)^2, so zero the mean's effect by masking via a row-validity
    # trick: append mean value rows so (row - mean) == 0.
    if fuse_center and dp.shape[0] != N:
        pad_rows = dp.shape[0] - N
        filler = jnp.broadcast_to(pad_to(mean, (1, Mp)), (pad_rows, Mp))
        dp = dp.at[N:, :].set(filler)
    mp = pad_to(mean, (1, Mp))

    ni, nj, nk = Mp // bi, Mp // bj, cdiv(N, bk)

    if interchange:
        grid = (nj, ni, nk)
        gi = lambda j, i, k: i
        gj = lambda j, i, k: j
        gk = lambda j, i, k: k
    else:
        grid = (ni, nj, nk)
        gi = lambda i, j, k: i
        gj = lambda i, j, k: j
        gk = lambda i, j, k: k

    out = pl.pallas_call(
        functools.partial(
            _cov_kernel, nk=nk, denom=float(N - 1), fuse_center=fuse_center
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bi), lambda *g: (gk(*g), gi(*g))),  # data cols i
            pl.BlockSpec((bk, bj), lambda *g: (gk(*g), gj(*g))),  # data cols j
            pl.BlockSpec((1, bi), lambda *g: (0, gi(*g))),        # means i
            pl.BlockSpec((1, bj), lambda *g: (0, gj(*g))),        # means j
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda *g: (gi(*g), gj(*g))),
        out_shape=jax.ShapeDtypeStruct((Mp, Mp), data.dtype),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(dp, dp, mp, mp)
    return unpad(out, (M, M))
