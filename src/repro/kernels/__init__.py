"""repro.kernels — PolyBench-analog TPU hot spots (Pallas + BlockSpec).

Layout per kernel: <name>.py holds the pl.pallas_call implementation;
ops.py the jit'd public wrappers; ref.py the pure-jnp oracles; spaces.py the
autotuner parameter spaces; variants.py the host-timeable XLA molds.
"""

from repro.kernels.covariance import covariance
from repro.kernels.floyd_warshall import floyd_warshall, minplus_update
from repro.kernels.heat3d import heat3d, heat3d_step
from repro.kernels.lu import lu
from repro.kernels.m3mm import mm3
from repro.kernels.matmul import tiled_matmul
from repro.kernels.ops import (
    DEFAULTS,
    covariance_op,
    floyd_warshall_op,
    heat3d_op,
    lu_op,
    matmul_op,
    mm3_op,
    syr2k_op,
)
from repro.kernels.spaces import KERNEL_SPACES, kernel_space
from repro.kernels.syr2k import syr2k

__all__ = [
    "covariance", "floyd_warshall", "minplus_update", "heat3d", "heat3d_step",
    "lu", "mm3", "tiled_matmul", "syr2k",
    "DEFAULTS", "covariance_op", "floyd_warshall_op", "heat3d_op", "lu_op",
    "matmul_op", "mm3_op", "syr2k_op", "KERNEL_SPACES", "kernel_space",
]
