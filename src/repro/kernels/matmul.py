"""Tunable tiled matmul Pallas kernel — the MXU-facing building block.

The paper's three pragma families map onto this kernel's knobs:

  * tiling       -> ``bm``/``bn``/``bk`` BlockSpec block shapes (VMEM tiles);
  * interchange  -> grid dimension order (``interchange=True`` makes the
                    N-block loop outer / M-block inner, changing which operand
                    tile stays resident across consecutive grid steps). The
                    contraction dimension stays innermost *by construction* so
                    every point of the space is a legal schedule;
  * array packing-> ``pack=True`` accumulates in an explicit f32 VMEM scratch
                    buffer and writes HBM once (the pack-into-local-buffer
                    analog); ``pack=False`` read-modify-writes the output
                    block in its own dtype each K step.

``interpret=True`` (the CPU default) runs the kernel body in Python for
correctness validation against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import cdiv, default_interpret, pad_to, tpu_compiler_params, unpad

__all__ = ["tiled_matmul"]


def _mm_kernel_pack(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_nopack(a_ref, b_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def tiled_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interchange: bool = False,
    pack: bool = True,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """C = A @ B with explicit VMEM tiling. Shapes need not be multiples of
    the block sizes (zero padding is applied and stripped)."""
    if interpret is None:
        interpret = default_interpret()
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype

    bm = min(bm, max(M, 1))
    bn = min(bn, max(N, 1))
    bk = min(bk, max(K, 1))

    ap = pad_to(a, (bm, bk))
    bp = pad_to(b, (bk, bn))
    mi, nj, kk = cdiv(M, bm), cdiv(N, bn), cdiv(K, bk)

    if interchange:
        grid = (nj, mi, kk)
        a_map = lambda j, i, k: (i, k)
        b_map = lambda j, i, k: (k, j)
        o_map = lambda j, i, k: (i, j)
    else:
        grid = (mi, nj, kk)
        a_map = lambda i, j, k: (i, k)
        b_map = lambda i, j, k: (k, j)
        o_map = lambda i, j, k: (i, j)

    common = dict(
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )
    if pack:
        out = pl.pallas_call(
            functools.partial(_mm_kernel_pack, nk=kk),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            **common,
        )(ap, bp)
    else:
        out = pl.pallas_call(functools.partial(_mm_kernel_nopack, nk=kk), **common)(ap, bp)
    return unpad(out, (M, N))
