"""Shared kernel utilities: padding, interpret-mode policy, alignment."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as _pltpu

__all__ = ["default_interpret", "cdiv", "pad_to", "unpad", "tpu_compiler_params",
           "TPU_LANE", "TPU_SUBLANE"]

# jax < 0.5 names the Mosaic params class TPUCompilerParams; newer releases
# renamed it CompilerParams — resolve whichever this jax ships
_CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kw):
    """Version-portable ``pltpu.CompilerParams`` (e.g. dimension_semantics)."""
    return _CompilerParams(**kw)

TPU_LANE = 128     # last-dim tile of the TPU vector unit / MXU
TPU_SUBLANE = 8    # second-to-last-dim tile (f32)


def default_interpret() -> bool:
    """Pallas kernels run in interpret mode unless a real TPU is attached.

    Override with REPRO_PALLAS_INTERPRET=0/1.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jnp.ndarray, multiples: tuple[int, ...], value: float = 0.0) -> jnp.ndarray:
    """Zero-pad each dim of ``x`` up to the next multiple of ``multiples``."""
    pads = []
    for dim, m in zip(x.shape, multiples):
        target = cdiv(dim, m) * m
        pads.append((0, target - dim))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


def unpad(x: jnp.ndarray, shape: tuple[int, ...]) -> jnp.ndarray:
    if tuple(x.shape) == tuple(shape):
        return x
    return x[tuple(slice(0, s) for s in shape)]
