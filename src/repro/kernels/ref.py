"""Pure-jnp oracles for every kernel — the correctness ground truth.

Each function is a direct transliteration of the PolyBench 4.2 reference
computation (the code the paper's pragmas transform), with the same dataset
semantics. These are used by the per-kernel allclose tests and as the
``gcc -O3``-role baselines in the benchmark tables.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "syr2k_ref", "mm3_ref", "lu_ref", "heat3d_ref", "covariance_ref",
    "floyd_warshall_ref", "init_syr2k", "init_mm3", "init_lu", "init_heat3d",
    "init_covariance", "init_floyd_warshall", "problem_signature",
]


# ---------------------------------------------------------------------------
# syr2k: C = alpha*A@B^T + alpha*B@A^T + beta*C   (A, B: N x M; C: N x N)
# ---------------------------------------------------------------------------


def syr2k_ref(C, A, B, alpha=1.5, beta=1.2):
    return alpha * (A @ B.T) + alpha * (B @ A.T) + beta * C


def init_syr2k(N: int, M: int, dtype=jnp.float32, seed: int = 0):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    A = jax.random.normal(k[0], (N, M), dtype)
    B = jax.random.normal(k[1], (N, M), dtype)
    C = jax.random.normal(k[2], (N, N), dtype)
    return C, A, B


# ---------------------------------------------------------------------------
# 3mm: G = (A @ B) @ (C @ D)
# ---------------------------------------------------------------------------


def mm3_ref(A, B, C, D):
    E = A @ B
    F = C @ D
    return E @ F


def init_mm3(P: int, Q: int, R: int, S: int, T: int, dtype=jnp.float32, seed: int = 0):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    A = jax.random.normal(k[0], (P, Q), dtype) / jnp.sqrt(Q).astype(dtype)
    B = jax.random.normal(k[1], (Q, R), dtype) / jnp.sqrt(R).astype(dtype)
    C = jax.random.normal(k[2], (R, S), dtype) / jnp.sqrt(S).astype(dtype)
    D = jax.random.normal(k[3], (S, T), dtype) / jnp.sqrt(T).astype(dtype)
    return A, B, C, D


# ---------------------------------------------------------------------------
# lu: A = L*U (Doolittle, no pivoting); returns packed LU (unit L below diag)
# ---------------------------------------------------------------------------


def lu_ref(A):
    n = A.shape[0]

    def step(k, M):
        col = M[:, k]
        piv = M[k, k]
        rows = jnp.arange(n)
        m = jnp.where(rows > k, col / piv, 0.0)
        row = jnp.where(rows > k, M[k, :], 0.0)  # only cols > k get updated
        M = M - jnp.outer(m, row)
        M = M.at[:, k].set(jnp.where(rows > k, m, M[:, k]))
        return M

    return jax.lax.fori_loop(0, n, step, A)


def init_lu(N: int, dtype=jnp.float32, seed: int = 0):
    A = jax.random.normal(jax.random.PRNGKey(seed), (N, N), dtype)
    # PolyBench makes A diagonally dominant so factorization is stable
    A = A + N * jnp.eye(N, dtype=dtype)
    return (A,)


# ---------------------------------------------------------------------------
# heat-3d: TSTEPS of the PolyBench 3-axis second-difference update
# ---------------------------------------------------------------------------


def _heat3d_step(A):
    # B[i,j,k] = 0.125*(A[i+1]-2A[i]+A[i-1]) + 0.125*(j) + 0.125*(k) + A
    out = (
        0.125 * (jnp.roll(A, -1, 0) - 2.0 * A + jnp.roll(A, 1, 0))
        + 0.125 * (jnp.roll(A, -1, 1) - 2.0 * A + jnp.roll(A, 1, 1))
        + 0.125 * (jnp.roll(A, -1, 2) - 2.0 * A + jnp.roll(A, 1, 2))
        + A
    )
    n0, n1, n2 = A.shape
    ii = jnp.arange(n0)[:, None, None]
    jj = jnp.arange(n1)[None, :, None]
    kk = jnp.arange(n2)[None, None, :]
    interior = (
        (ii > 0) & (ii < n0 - 1) & (jj > 0) & (jj < n1 - 1) & (kk > 0) & (kk < n2 - 1)
    )
    return jnp.where(interior, out, A)


def heat3d_ref(A, tsteps: int):
    # PolyBench alternates A->B->A; with the masked update each pass is the
    # same operator, so 2*tsteps masked applications reproduce it.
    return jax.lax.fori_loop(0, 2 * tsteps, lambda _, x: _heat3d_step(x), A)


def init_heat3d(N: int, dtype=jnp.float32, seed: int = 0):
    A = jax.random.uniform(jax.random.PRNGKey(seed), (N, N, N), dtype)
    return (A,)


# ---------------------------------------------------------------------------
# covariance: data (N points x M attrs) -> cov (M x M)
# ---------------------------------------------------------------------------


def covariance_ref(data):
    N = data.shape[0]
    mean = data.mean(axis=0, keepdims=True)
    c = data - mean
    return (c.T @ c) / (N - 1.0)


def init_covariance(N: int, M: int, dtype=jnp.float32, seed: int = 0):
    data = jax.random.normal(jax.random.PRNGKey(seed), (N, M), dtype)
    return (data,)


# ---------------------------------------------------------------------------
# floyd-warshall: all-pairs shortest paths, min-plus relaxation over k
# ---------------------------------------------------------------------------


def floyd_warshall_ref(path):
    n = path.shape[0]

    def step(k, D):
        return jnp.minimum(D, D[:, k][:, None] + D[k, :][None, :])

    return jax.lax.fori_loop(0, n, step, path)


def init_floyd_warshall(N: int, dtype=jnp.float32, seed: int = 0):
    # PolyBench-style integer-ish edge costs; keep them positive & bounded
    w = jax.random.uniform(jax.random.PRNGKey(seed), (N, N), dtype, 1.0, 10.0)
    w = w.at[jnp.arange(N), jnp.arange(N)].set(0.0)
    return (w,)


# ---------------------------------------------------------------------------
# problem signatures: paper problem dims -> per-argument shape signature,
# mirroring the init_* array shapes above. This is the SAME signature
# repro.dispatch derives from the runtime args, so configs published from
# offline campaigns (autotune CLI --store, pallas_tuning) resolve at
# dispatch() time instead of being structurally incompatible.
# ---------------------------------------------------------------------------


def problem_signature(name: str, *dims: int) -> tuple:
    if name == "syr2k":
        N, M = dims
        return ((N, N), (N, M), (N, M))
    if name == "mm3":
        P, Q, R, S, T = dims
        return ((P, Q), (Q, R), (R, S), (S, T))
    if name == "lu":
        (N,) = dims
        return ((N, N),)
    if name == "heat3d":
        N, tsteps = dims
        return ((N, N, N), (tsteps,))
    if name == "covariance":
        N, M = dims
        return ((N, M),)
    if name == "floyd_warshall":
        (N,) = dims
        return ((N, N),)
    if name == "flash_attention":
        # trailing (2,) = the static `causal=True` kwarg the service folds in
        BH, Sq, Sk, hd = dims
        return ((BH, Sq, hd), (BH, Sk, hd), (BH, Sk, hd), (2,))
    if name == "decode_attention":
        # (BH,) = per-row cur_pos; trailing (1,), (1,) = the static
        # `ring=False`/`window=0` defaults the service folds in
        BH, G, S, hd = dims
        return ((BH, G, hd), (BH, S, hd), (BH, S, hd), (BH,), (1,), (1,))
    if name == "matmul":
        M, K, N = dims
        return ((M, K), (K, N))
    raise KeyError(f"unknown kernel {name!r}")
