"""repro.engine — the unified campaign engine.

One abstraction, :class:`Campaign`, owns the ask/evaluate/tell loop that was
previously re-implemented by ``run_search``, the background tuner, the
autotune CLI, and the benchmark drivers. A campaign couples a
:class:`~repro.core.search.BayesianSearch` (batched ``ask(n)`` with a
constant-liar fill-in) to a pluggable :class:`Executor` (inline,
thread-pool, or whatever a :class:`~repro.dispatch.registry.VariantSpec`
injects — e.g. the roofline cost backend), checkpoints every record through
the :class:`~repro.core.database.PerformanceDatabase` JSONL, and resumes a
killed campaign without re-evaluating completed configs.

    from repro.engine import Campaign
    res = Campaign(space, evaluator, max_evals=100, parallel=4).run()
"""

from repro.engine.campaign import Campaign
from repro.engine.executors import (
    Executor,
    InlineExecutor,
    ThreadExecutor,
    evaluator_for_spec,
    make_executor,
)

__all__ = [
    "Campaign",
    "Executor",
    "InlineExecutor",
    "ThreadExecutor",
    "evaluator_for_spec",
    "make_executor",
]
