"""Campaign: the single ask/evaluate/tell loop behind the whole tuning stack.

Semantics (all inherited from the paper's loop, generalized to ``q`` in
flight):

  * **budget** — ``max_evals`` counts database records: real evaluations,
    failures, and GP duplicate-skips all consume budget, exactly as in the
    serial loop (the paper's "GP finishes only 66 of 200" asymmetry).
  * **batching** — proposals come from ``BayesianSearch.ask(n)``; each
    in-flight config is a constant-liar observation, so concurrent
    candidates diversify instead of piling onto one optimum. With
    ``parallel=1`` (the :class:`~repro.engine.executors.InlineExecutor`)
    the ask → evaluate → tell interleaving is byte-identical to the legacy
    serial loop, so fixed-seed trajectories are preserved.
  * **learner asymmetry** — RF/ET/GBRT never re-propose a config that is
    recorded *or* in flight; GP proposals that duplicate a recorded or
    in-flight config are told as skipped (budget consumed, nothing run).
  * **crash safety** — every ``tell`` appends one JSONL line via
    :class:`~repro.core.database.PerformanceDatabase`; a campaign killed
    after ``k`` records resumes from the same ``db_path`` and performs
    exactly ``max_evals - k`` further proposals, never re-evaluating a
    completed config.
"""

from __future__ import annotations

import concurrent.futures as cf
import time
from typing import Any, Callable, Mapping

from repro.core.database import FAILED, OK, SKIPPED_DUPLICATE, PerformanceDatabase, Record
from repro.core.plopper import EvalResult
from repro.core.search import BayesianSearch, SearchResult
from repro.core.space import ConfigurationSpace, config_key
from repro.engine.executors import Executor, make_executor
from repro.obs.metrics import get_registry
from repro.obs.trace import span as obs_span

__all__ = ["Campaign"]


class Campaign:
    """One autotuning campaign: space + evaluator (or executor) + budget.

    ``evaluator`` is any ``config -> EvalResult`` callable; ``parallel`` picks
    the executor width (1 = inline/serial). Alternatively pass a ready-made
    ``executor`` (anything satisfying :class:`~repro.engine.executors.Executor`)
    — then ``evaluator``/``parallel`` are ignored and the campaign does not
    shut the executor down when it finishes.
    """

    def __init__(
        self,
        space: ConfigurationSpace,
        evaluator: Callable[[Mapping[str, Any]], EvalResult] | None = None,
        *,
        executor: Executor | None = None,
        max_evals: int = 100,
        learner: str = "RF",
        seed: int = 1234,
        db: PerformanceDatabase | None = None,
        db_path: str | None = None,
        n_initial: int = 10,
        init_method: str = "lhs",
        kappa: float = 1.96,
        acq: str = "LCB",
        parallel: int = 1,
        warm_start: list | None = None,
        warm_start_records: list[tuple[Mapping[str, Any], float]] | None = None,
        callback: Callable[[Record], None] | None = None,
        feasibility: Callable[[Mapping[str, Any]], bool] | None = None,
        rung: int | None = None,
    ):
        if executor is None and evaluator is None:
            raise ValueError("Campaign needs an evaluator or an executor")
        self._owns_executor = executor is None
        self.learner = learner.upper()
        # rung-aware contract (repro.fidelity): a campaign running as one
        # rung of a multi-fidelity cascade carries its rung level. Every
        # record's info gains {"rung": r}, the campaign_* metrics gain a
        # rung label (per-rung latency histograms), and timings reports the
        # level. With rung=None (every pre-fidelity caller) nothing changes:
        # labels, info dicts, and RNG consumption are byte-identical, which
        # is what keeps single-rung q=1 trajectories pinned to the paper.
        self.rung = rung
        self._labels = {"learner": self.learner}
        if rung is not None:
            self._labels["rung"] = int(rung)
        # obs integration: per-phase latencies land in the process registry
        # (campaign_{ask,tell,wait,evaluate}_seconds{learner=}) alongside the
        # plain `timings` dict below, and each phase opens a trace span —
        # a campaign run with REPRO_TRACE set renders as one timeline.
        self._metrics = get_registry()
        if executor is None:
            evaluator = self._instrument_evaluator(evaluator)
        self.executor = executor if executor is not None else make_executor(evaluator, parallel)
        self.max_evals = max_evals
        self.warm_start = list(warm_start or [])
        self.callback = callback
        self.db = db if db is not None else PerformanceDatabase(
            db_path, param_names=space.param_names)
        self.search = BayesianSearch(
            space, learner=learner, kappa=kappa, acq=acq, n_initial=n_initial,
            init_method=init_method, seed=seed, db=self.db,
            prior_records=warm_start_records, feasibility=feasibility,
        )
        # optimizer-overhead telemetry: how much wall-clock the tuner itself
        # costs (surrogate fits + acquisition scans in ask, DB appends in
        # tell) vs time blocked on evaluation results. Fed into
        # SearchResult.timings and aggregated by BackgroundTuner.stats so
        # serving hosts can watch the tuner's CPU bill.
        # n_pruned mirrors BayesianSearch.n_pruned: candidates the static
        # feasibility pass (repro.analyze) discarded before acquisition
        # scoring — 0 unless a feasibility predicate was supplied.
        self.timings = {"ask_sec": 0.0, "tell_sec": 0.0, "wait_sec": 0.0,
                        "n_asks": 0, "n_tells": 0, "n_pruned": 0}
        if rung is not None:
            self.timings["rung"] = int(rung)

    # -- introspection -----------------------------------------------------------

    @property
    def q(self) -> int:
        """Max candidates in flight (the executor's width)."""
        return max(1, getattr(self.executor, "max_inflight", 1))

    @property
    def remaining(self) -> int:
        """Budget left: proposals this campaign will still make (the resume
        contract — a campaign killed after ``k`` records reports and performs
        exactly ``max_evals - k`` more)."""
        return max(0, self.max_evals - len(self.db))

    # -- the loop ----------------------------------------------------------------

    def run(self) -> SearchResult:
        try:
            self._run_warm_start()
            self._run_main_loop()
        finally:
            if self._owns_executor:
                self.executor.shutdown(wait=True)
        return self.result()

    def _instrument_evaluator(self, evaluator):
        """Wrap the evaluator so each evaluation is a trace span and a
        ``campaign_evaluate_seconds`` observation (runs on executor worker
        threads; shard-local recording keeps it lock-free)."""
        metrics, labels = self._metrics, self._labels

        def evaluate(cfg):
            t0 = time.perf_counter()
            try:
                with obs_span("campaign.evaluate", **labels):
                    return evaluator(cfg)
            finally:
                metrics.observe("campaign_evaluate_seconds",
                                time.perf_counter() - t0, **labels)

        return evaluate

    def _tell(self, config: Mapping[str, Any], result: EvalResult) -> None:
        if self.rung is not None:
            # rung-stamped records: the cascade (and anyone reading the
            # JSONL) can attribute each observation to its fidelity level
            result = EvalResult(result.objective, result.ok,
                                {**result.info, "rung": self.rung})
        t0 = time.perf_counter()
        with obs_span("campaign.tell", **self._labels):
            rec = self.search.tell(config, result)
        dt = time.perf_counter() - t0
        self.timings["tell_sec"] += dt
        self.timings["n_tells"] += 1
        self._metrics.observe("campaign_tell_seconds", dt, **self._labels)
        if self.callback:
            self.callback(rec)

    def _tell_skipped(self, config: Mapping[str, Any]) -> None:
        t0 = time.perf_counter()
        with obs_span("campaign.tell", skipped=True, **self._labels):
            rec = self.search.tell_skipped(config)
        dt = time.perf_counter() - t0
        self.timings["tell_sec"] += dt
        self.timings["n_tells"] += 1
        self._metrics.observe("campaign_tell_seconds", dt, **self._labels)
        if self.callback:
            self.callback(rec)

    def _ask(self, n: int) -> list[dict]:
        t0 = time.perf_counter()
        with obs_span("campaign.ask", n=n, **self._labels):
            batch = self.search.ask(n)
        dt = time.perf_counter() - t0
        self.timings["ask_sec"] += dt
        self.timings["n_asks"] += 1
        self.timings["n_pruned"] = self.search.n_pruned
        self._metrics.observe("campaign_ask_seconds", dt, **self._labels)
        return batch

    def _run_warm_start(self) -> None:
        """Evaluate warm-start configs first (known defaults, store bests) so
        the surrogate — and the final best — always include them. Results are
        told in submission order, keeping record indices deterministic at any
        executor width."""
        inflight: list[tuple[cf.Future, dict]] = []
        try:
            for cfg in self.warm_start:
                if len(self.db) + len(inflight) >= self.max_evals:
                    break  # budget exhausted: later warm configs can't run either
                if self.db.contains(cfg) or self.search.is_pending(cfg):
                    continue
                self.search.mark_pending(cfg)
                inflight.append((self.executor.submit(cfg), cfg))
            for fut, cfg in inflight:
                self._tell(cfg, fut.result())
        except BaseException:
            # a failing warm eval abandons its siblings; release their pending
            # slots so a caller that catches and re-runs isn't poisoned
            for _, cfg in inflight:
                self.search.clear_pending(cfg)
            raise

    def _run_main_loop(self) -> None:
        inflight: dict[cf.Future, dict] = {}
        keys_inflight: set[tuple] = set()
        order: list[cf.Future] = []  # submission order, for deterministic tells
        try:
            while True:
                # fill: propose until the executor is saturated or the budget
                # (records + in-flight) is fully committed
                while True:
                    want = min(self.q - len(inflight),
                               self.max_evals - len(self.db) - len(inflight))
                    if want <= 0:
                        break
                    progressed = False
                    for cfg in self._ask(want):
                        key = config_key(cfg)
                        if not self.search.dedups_against_db:
                            if self.db.contains(cfg):
                                # GP: a proposal duplicating a *recorded*
                                # config consumes budget unrun (the paper's
                                # budget asymmetry)
                                self._tell_skipped(cfg)
                                progressed = True
                                continue
                            if key in keys_inflight:
                                # duplicate of an unmeasured in-flight config:
                                # skipping now would record a NaN objective as
                                # the config's canonical lookup entry and
                                # erase its constant-liar row — defer instead
                                # until the real result lands
                                continue
                        fut = self.executor.submit(cfg)
                        inflight[fut] = cfg
                        keys_inflight.add(key)
                        order.append(fut)
                        progressed = True
                    if not progressed:
                        break  # only deferred duplicates: wait for results
                if not inflight:
                    break  # budget fully recorded (evals + skips)
                t0 = time.perf_counter()
                done, _ = cf.wait(list(inflight), return_when=cf.FIRST_COMPLETED)
                dt = time.perf_counter() - t0
                self.timings["wait_sec"] += dt
                self._metrics.observe("campaign_wait_seconds", dt,
                                      **self._labels)
                for fut in [f for f in order if f in done]:
                    cfg = inflight.pop(fut)
                    keys_inflight.discard(config_key(cfg))
                    order.remove(fut)
                    self._tell(cfg, fut.result())
        except BaseException:
            # a failing future abandons its siblings; release their pending
            # slots so a caller that catches and re-runs isn't poisoned
            for cfg in inflight.values():
                self.search.clear_pending(cfg)
            raise

    def result(self) -> SearchResult:
        """Summary over the database (complete or mid-flight)."""
        recs = self.db.records
        return SearchResult(
            db=self.db, best=self.db.best(),
            n_evaluated=sum(1 for r in recs if r.status == OK),
            n_skipped=sum(1 for r in recs if r.status == SKIPPED_DUPLICATE),
            n_failed=sum(1 for r in recs if r.status == FAILED),
            learner=self.learner,
            timings=dict(self.timings),
        )
