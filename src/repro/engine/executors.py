"""Evaluation executors: how a campaign turns proposed configs into results.

An executor is anything with ``submit(config) -> Future[EvalResult]``,
``max_inflight`` (the batch width the campaign should ask for), and
``shutdown()``. Two implementations cover the stack:

  * :class:`InlineExecutor` — evaluates synchronously inside ``submit``;
    ``max_inflight == 1``, so a campaign on it *is* the paper's serial loop.
  * :class:`ThreadExecutor` — a thread pool evaluating ``max_workers``
    candidates concurrently. The evaluator must be thread-safe (the stock
    :class:`~repro.core.plopper.TimingEvaluator` and the roofline
    cost-model evaluators are).

The evaluator itself is orthogonal: :func:`evaluator_for_spec` builds the
right one for a dispatch-registry :class:`VariantSpec` — the spec's
``make_evaluator`` override (e.g. the roofline cost backend registered by
``repro.kernels.problems.register_cost_backend``) when present, wall-clock
timing otherwise. That is what lets background campaigns tune TPU-target
schedules on a host with no TPU attached.
"""

from __future__ import annotations

import concurrent.futures as cf
from typing import Any, Callable, Mapping, Protocol, runtime_checkable

from repro.core.plopper import EvalResult

__all__ = [
    "Executor",
    "InlineExecutor",
    "ThreadExecutor",
    "make_executor",
    "evaluator_for_spec",
]


@runtime_checkable
class Executor(Protocol):
    max_inflight: int

    def submit(self, config: Mapping[str, Any]) -> "cf.Future[EvalResult]": ...

    def shutdown(self, wait: bool = True) -> None: ...


class InlineExecutor:
    """Synchronous executor: ``submit`` evaluates immediately and returns an
    already-completed future. Evaluator exceptions propagate through the
    future exactly as they would from a direct call."""

    max_inflight = 1

    def __init__(self, evaluator: Callable[[Mapping[str, Any]], EvalResult]):
        self.evaluator = evaluator

    def submit(self, config: Mapping[str, Any]) -> cf.Future:
        fut: cf.Future = cf.Future()
        try:
            fut.set_result(self.evaluator(config))
        except BaseException as e:  # noqa: BLE001 — surfaced at fut.result()
            fut.set_exception(e)
        return fut

    def shutdown(self, wait: bool = True) -> None:
        pass


class ThreadExecutor:
    """Thread-pool executor evaluating up to ``max_workers`` configs at once."""

    def __init__(self, evaluator: Callable[[Mapping[str, Any]], EvalResult],
                 max_workers: int = 4):
        if max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, got {max_workers}")
        self.evaluator = evaluator
        self.max_inflight = max_workers
        self._pool = cf.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="repro-engine")

    def submit(self, config: Mapping[str, Any]) -> cf.Future:
        return self._pool.submit(self.evaluator, dict(config))

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)


def make_executor(evaluator: Callable[[Mapping[str, Any]], EvalResult],
                  parallel: int = 1) -> Executor:
    """Inline for ``parallel=1`` (bit-for-bit serial semantics), thread pool
    for ``parallel>1``."""
    if parallel <= 1:
        return InlineExecutor(evaluator)
    return ThreadExecutor(evaluator, max_workers=parallel)


def evaluator_for_spec(spec, factory: Callable) -> Callable[[Mapping[str, Any]], EvalResult]:
    """Evaluator for a dispatch-registry ``VariantSpec``: the spec's
    ``make_evaluator`` override (cost backends, custom scorers) when present,
    else wall-clock timing of ``factory(config) -> (fn, args)``."""
    if spec.make_evaluator is not None:
        return spec.make_evaluator(factory)
    from repro.core.plopper import TimingEvaluator

    return TimingEvaluator(factory, repeats=spec.eval_repeats, warmup=spec.eval_warmup)
