"""repro.dispatch: tuning store, shape-signature lookup, runtime dispatch
with its compiled-executable cache, background tuning, and the warm-start
convergence contract (warm campaigns reach a stored optimum in <= 25% of the
cold-start evaluation count)."""

import math
import os

import numpy as np
import pytest

from repro.core import EvalResult, run_search
from repro.core.database import PerformanceDatabase
from repro.core.space import ConfigurationSpace, Ordinal
from repro.dispatch import (
    BackgroundTuner,
    DispatchService,
    TuningRecord,
    TuningStore,
    bucket_signature,
    register,
    resolve,
    shape_signature,
    signature_distance,
    signature_key,
    parse_signature_key,
)


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------


def test_signature_key_roundtrip():
    sig = ((1200, 1000), (8,))
    assert parse_signature_key(signature_key(sig)) == sig
    assert signature_key(sig) == "1200x1000;8"


def test_signature_from_arrays_and_scalars():
    sig = shape_signature([np.zeros((64, 32)), 8])
    assert sig == ((64, 32), (8,))


def test_signature_distance_log_scale():
    a, b = ((128, 128),), ((256, 256),)
    assert signature_distance(a, a) == 0.0
    assert signature_distance(a, b) == pytest.approx(1.0)  # one doubling per dim
    # incompatible structure -> inf
    assert signature_distance(a, ((128,),)) == math.inf
    # scale-free: same ratio at any magnitude
    assert signature_distance(((8,),), ((16,),)) == pytest.approx(
        signature_distance(((1024,),), ((2048,),)))


def test_bucket_signature_snaps_to_powers():
    assert bucket_signature(((130, 120), (7,))) == ((128, 128), (8,))


def test_signature_distinguishes_bool_flags():
    # causal=True vs causal=False static kwargs must not share store keys
    assert shape_signature([True]) == ((2,),)
    assert shape_signature([False]) == ((1,),)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------


def _rec(kernel="k", dims=(64, 64), backend="host", obj=1.0, **cfg):
    return TuningRecord(kernel=kernel, signature=(tuple(dims),), backend=backend,
                        config=cfg or {"t": 8}, objective=obj)


def test_store_roundtrip_persistence(tmp_path):
    path = str(tmp_path / "store")
    store = TuningStore(path)
    assert store.put(_rec(obj=2.0, t=8))
    assert store.put(_rec(obj=1.0, t=16))        # improvement: accepted
    assert not store.put(_rec(obj=1.5, t=4))     # regression: rejected
    store2 = TuningStore(path)                   # fresh process view
    assert len(store2) == 1
    got = store2.get("k", ((64, 64),), "host")
    assert got.objective == 1.0 and got.config == {"t": 16}


def test_store_cross_instance_refresh(tmp_path):
    path = str(tmp_path / "store")
    a, b = TuningStore(path), TuningStore(path)
    a.put(_rec(obj=3.0))
    assert b.get("k", ((64, 64),), "host") is None  # not yet refreshed
    b.refresh()
    assert b.get("k", ((64, 64),), "host").objective == 3.0


def test_store_compact_keeps_bests_only(tmp_path):
    path = str(tmp_path / "store")
    store = TuningStore(path)
    for obj in (5.0, 3.0, 1.0):
        store.put(_rec(obj=obj, t=int(obj)))
    store.put(_rec(dims=(128, 128), obj=2.0))
    assert store.compact() == 2
    with open(os.path.join(path, "store.jsonl")) as f:
        assert sum(1 for line in f if line.strip()) == 2
    assert TuningStore(path).get("k", ((64, 64),), "host").objective == 1.0


def test_store_append_after_torn_tail_preserves_both(tmp_path):
    path = str(tmp_path / "store")
    store = TuningStore(path)
    store.put(_rec(obj=2.0, t=8))
    with open(os.path.join(path, "store.jsonl"), "a") as f:
        f.write('{"kernel": "k", "sig')        # crashed writer's fragment
    store2 = TuningStore(path)
    assert store2.put(_rec(obj=1.0, t=16))     # must not merge into the tail
    store3 = TuningStore(path)
    assert store3.get("k", ((64, 64),), "host").objective == 1.0


def test_problem_signature_matches_runtime_dispatch():
    """Configs published offline (CLI --store / pallas_tuning) must land on
    the exact signatures dispatch() derives from runtime args."""
    from repro.kernels import ref as R

    C, A, B = R.init_syr2k(48, 32)
    assert R.problem_signature("syr2k", 48, 32) == shape_signature((C, A, B))
    assert R.problem_signature("mm3", 20, 18, 16, 15, 17) == shape_signature(
        R.init_mm3(20, 18, 16, 15, 17))
    assert R.problem_signature("lu", 24) == shape_signature(R.init_lu(24))
    (Ah,) = R.init_heat3d(16)
    assert R.problem_signature("heat3d", 16, 4) == shape_signature([Ah, 4])
    assert R.problem_signature("covariance", 30, 24) == shape_signature(
        R.init_covariance(30, 24))
    assert R.problem_signature("floyd_warshall", 24) == shape_signature(
        R.init_floyd_warshall(24))


def test_store_ingest_database(tmp_path):
    db = PerformanceDatabase(str(tmp_path / "camp"))
    db.add({"t": 4}, 4.0)
    db.add({"t": 32}, 0.5)
    store = TuningStore(str(tmp_path / "store"))
    rec = store.ingest_database(str(tmp_path / "camp"), "k", ((64, 64),), "host")
    assert rec is not None and rec.config == {"t": 32} and rec.n_evals == 2
    assert store.get("k", ((64, 64),), "host").objective == 0.5


# ---------------------------------------------------------------------------
# lookup: exact hit vs nearest neighbor
# ---------------------------------------------------------------------------


def test_resolve_exact_beats_nearest(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(_rec(dims=(128, 128), obj=1.0, t=128))
    store.put(_rec(dims=(1024, 1024), obj=1.0, t=1024))
    hit = resolve(store, "k", ((128, 128),), "host")
    assert hit.exact and hit.distance == 0.0 and hit.config == {"t": 128}


def test_resolve_nearest_by_log_distance(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(_rec(dims=(128, 128), obj=1.0, t=128))
    store.put(_rec(dims=(1024, 1024), obj=1.0, t=1024))
    near = resolve(store, "k", ((150, 150),), "host")
    assert not near.exact and near.config == {"t": 128}
    far = resolve(store, "k", ((700, 700),), "host")
    assert far.config == {"t": 1024}
    # max_distance bound and backend isolation
    assert resolve(store, "k", ((150, 150),), "host", max_distance=0.1) is None
    assert resolve(store, "k", ((128, 128),), "tpu") is None


# ---------------------------------------------------------------------------
# dispatch service: executable cache + counters
# ---------------------------------------------------------------------------

_TOY_SEQ = (1, 2, 4, 8, 16, 32)


def _toy_space(target="host", seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameter(Ordinal("s", _TOY_SEQ, default=1))
    return cs


def _toy_evaluator(cfg):
    # minimized at the largest scale factor (deterministic, no timing noise)
    return EvalResult(1.0 / cfg["s"], True, {})


register("toy_scale", builder=lambda cfg: lambda x: x * cfg["s"],
         space=_toy_space, make_evaluator=lambda factory: _toy_evaluator)


def _fragile_builder(cfg):
    # build-time failure mode: a poisoned config raises in the builder
    if cfg["s"] < 0:
        raise ValueError("poisoned config")

    def fn(x):
        # trace-time failure mode: the heat3d `assert total % h == 0` analog
        assert x.shape[0] % cfg["s"] == 0, "indivisible block"
        return x * cfg["s"]

    return fn


register("toy_fragile", builder=_fragile_builder, space=_toy_space)


def test_dispatch_exec_cache_hit_miss(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 2}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)
    fn = svc.dispatch("toy_scale", x)
    np.testing.assert_array_equal(np.asarray(fn(x)), x * 2)
    assert svc.stats["exec_miss"] == 1 and svc.stats["exec_hit"] == 0
    assert svc.dispatch("toy_scale", x) is fn           # same shape: cache hit
    assert svc.stats["exec_hit"] == 1
    svc.dispatch("toy_scale", np.arange(8.0))           # new shape: miss
    assert svc.stats["exec_miss"] == 2
    # the repeat dispatch went through the signature fast map: no second
    # store resolution on the hot path
    assert svc.stats["store_exact"] == 1 and svc.stats["store_near"] == 1


def test_dispatch_default_config_without_store():
    svc = DispatchService()
    x = np.arange(4.0)
    np.testing.assert_array_equal(np.asarray(svc.call("toy_scale", x)), x * 1)
    assert svc.stats["store_default"] == 1


def test_dispatch_unseen_shape_uses_nearest(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_scale", ((100,),), "host", {"s": 4}, 0.5))
    svc = DispatchService(store)
    x = np.arange(96.0)   # absent from the store -> nearest (100,) wins
    np.testing.assert_array_equal(np.asarray(svc.call("toy_scale", x)), x * 4)
    assert svc.stats["store_near"] == 1


def test_invalidate_hot_swaps_new_config(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 2}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)
    np.testing.assert_array_equal(np.asarray(svc.call("toy_scale", x)), x * 2)
    store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 8}, 0.1))
    assert svc.invalidate("toy_scale", ((4,),)) == 1
    np.testing.assert_array_equal(np.asarray(svc.call("toy_scale", x)), x * 8)


def test_jit_cached_shares_entry():
    svc = DispatchService()
    f1 = svc.jit_cached("serve/m", lambda x: x + 1)
    f2 = svc.jit_cached("serve/m", lambda x: x + 1)
    assert f1 is f2
    assert svc.stats["exec_miss"] == 1 and svc.stats["exec_hit"] == 1


# ---------------------------------------------------------------------------
# warm start: the <= 25%-of-cold-start convergence contract
# ---------------------------------------------------------------------------


def _quadratic_space(seed=1234):
    cs = ConfigurationSpace(seed=seed)
    vals = tuple(range(16))
    cs.add_hyperparameter(Ordinal("x", vals, default=0))
    cs.add_hyperparameter(Ordinal("y", vals, default=0))
    return cs


def _quadratic_eval(cfg):
    # deterministic toy landscape, optimum at (11, 3)
    return EvalResult((cfg["x"] - 11) ** 2 + (cfg["y"] - 3) ** 2 + 1.0, True, {})


def _evals_to_reach(db, target):
    for r in db.records:
        if r.status == "ok" and r.objective <= target * (1 + 1e-9):
            return r.index + 1
    return None


def test_warm_start_converges_in_quarter_of_cold(tmp_path):
    cold = run_search(_quadratic_space(), _quadratic_eval, max_evals=40,
                      learner="RF", seed=7, n_initial=10)
    stored_obj = cold.best.objective
    cold_evals = _evals_to_reach(cold.db, stored_obj)
    assert cold_evals is not None and cold_evals >= 4, (
        f"landscape too easy for the contract to be meaningful ({cold_evals})")

    # publish the cold campaign into a store, then warm-start a fresh one
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("quad", ((16, 16),), "host",
                           dict(cold.best.config), stored_obj, n_evals=40))
    hit = resolve(store, "quad", ((16, 16),), "host")
    warm = run_search(_quadratic_space(), _quadratic_eval, max_evals=40,
                      learner="RF", seed=8, n_initial=10,
                      warm_start=[dict(hit.config)],
                      warm_start_records=[(dict(hit.config), stored_obj)])
    warm_evals = _evals_to_reach(warm.db, stored_obj)
    assert warm_evals is not None
    assert warm_evals <= max(1, cold_evals // 4), (
        f"warm start took {warm_evals} evals vs cold {cold_evals}")


def test_warm_start_records_shrink_init_phase():
    from repro.core.search import BayesianSearch

    space = _quadratic_space()
    priors = [({"x": 11, "y": 3}, 1.0), ({"x": 10, "y": 3}, 2.0),
              ({"x": 11, "y": 4}, 2.0)]
    s = BayesianSearch(space, n_initial=10, prior_records=priors)
    assert s.n_priors == 3 and s.n_initial == 7
    X, y = s._training_data()
    assert X.shape[0] == 3 and y.min() == 1.0  # priors alone seed the surrogate
    # foreign configs are skipped, not fatal
    s2 = BayesianSearch(space, n_initial=10,
                        prior_records=[({"zz": 1}, 1.0)] + priors[:1])
    assert s2.n_priors == 1


# ---------------------------------------------------------------------------
# background tuning
# ---------------------------------------------------------------------------


def test_background_tuner_publishes_and_hot_swaps(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    tuner = BackgroundTuner(store, max_workers=1, max_evals=8, n_initial=3)
    try:
        fut = tuner.submit("toy_scale", ((4,),), "host",
                           space=_toy_space(), evaluator=_toy_evaluator)
        assert fut is not None
        # duplicate key while in flight (or queued) is deduplicated
        recs = tuner.drain()
        assert tuner.errors == []
        assert recs[0] is not None and recs[0].config["s"] == max(_TOY_SEQ)
        got = store.get("toy_scale", ((4,),), "host")
        assert got is not None and got.source == "background"
    finally:
        tuner.shutdown()


def test_background_tuner_warm_starts_from_neighbors(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_scale", ((8,),), "host", {"s": 32}, 1 / 32))
    tuner = BackgroundTuner(store, max_workers=1, max_evals=3, n_initial=1)
    try:
        tuner.submit("toy_scale", ((4,),), "host",
                     space=_toy_space(), evaluator=_toy_evaluator)
        recs = tuner.drain()
        assert tuner.errors == []
        # with only 3 evals, the neighbor's optimal config was re-evaluated
        # first and wins
        assert recs[0] is not None and recs[0].config["s"] == 32
    finally:
        tuner.shutdown()


# ---------------------------------------------------------------------------
# hardening: poisoned store records, _fast TTL sweep
# ---------------------------------------------------------------------------


def test_poisoned_builder_config_degrades_to_default(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_fragile", ((4,),), "host", {"s": -3}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)
    out = svc.call("toy_fragile", x)               # must not raise
    np.testing.assert_array_equal(np.asarray(out), x * 1)  # default config
    assert svc.stats["build_failed"] == 1
    # the offending record is quarantined: not served again, not re-accepted
    assert store.get("toy_fragile", ((4,),), "host") is None
    assert not store.put(TuningRecord("toy_fragile", ((4,),), "host", {"s": -3}, 0.1))
    # and the quarantine is visible to a fresh process view of the store
    assert not TuningStore(str(tmp_path / "s")).put(
        TuningRecord("toy_fragile", ((4,),), "host", {"s": -3}, 0.01))


def test_poisoned_trace_config_degrades_to_default(tmp_path):
    # builder succeeds but tracing fails (heat3d's indivisible fuse_t analog)
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_fragile", ((4,),), "host", {"s": 3}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)
    np.testing.assert_array_equal(np.asarray(svc.call("toy_fragile", x)), x * 1)
    assert svc.stats["build_failed"] == 1
    # a good config for the same key is still accepted after the quarantine
    assert store.put(TuningRecord("toy_fragile", ((4,),), "host", {"s": 2}, 0.4))
    svc.invalidate("toy_fragile")
    np.testing.assert_array_equal(np.asarray(svc.call("toy_fragile", x)), x * 2)
    assert svc.stats["build_failed"] == 1          # no new failure


def test_near_miss_build_failure_does_not_quarantine(tmp_path):
    # a neighbor that fails for THIS shape may be perfectly valid for its
    # own signature — it must degrade to the default without being banned
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_fragile", ((6,),), "host", {"s": 3}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)                 # nearest ((6,),): 3 doesn't divide 4
    np.testing.assert_array_equal(np.asarray(svc.call("toy_fragile", x)), x * 1)
    assert svc.stats["build_failed"] == 1
    assert store.get("toy_fragile", ((6,),), "host") is not None
    x6 = np.arange(6.0)                # still serves its own signature
    np.testing.assert_array_equal(np.asarray(svc.call("toy_fragile", x6)), x6 * 3)


def test_quarantine_canonicalizes_on_bucketed_store(tmp_path):
    store = TuningStore(str(tmp_path / "s"), bucket=True)
    store.put(_rec(dims=(130, 120), obj=1.0, t=8))
    store.quarantine(_rec(dims=(130, 120), obj=1.0, t=8))  # raw, unbucketed sig
    assert store.get("k", ((130, 120),), "host") is None
    assert not store.put(_rec(dims=(127, 126), obj=0.1, t=8))  # same bucket: banned


def test_fast_map_sweeps_expired_entries():
    svc = DispatchService(resolve_ttl_sec=0.0, fast_sweep_size=4)
    for i in range(16):  # jittery serving shapes, all instantly stale
        svc.dispatch("toy_scale", np.arange(float(i + 1)))
    # without the sweep the TTL map would hold all 16 signatures
    assert len(svc._fast) <= 5


def test_fast_map_expired_entry_replaced_on_hit():
    svc = DispatchService(resolve_ttl_sec=0.0)
    x = np.arange(4.0)
    svc.dispatch("toy_scale", x)
    assert len(svc._fast) == 1
    svc.dispatch("toy_scale", x)   # expired on hit: dropped then re-inserted
    assert len(svc._fast) == 1


# ---------------------------------------------------------------------------
# store bucketing + eviction
# ---------------------------------------------------------------------------


def test_bucketed_store_collapses_jittery_shapes(tmp_path):
    store = TuningStore(str(tmp_path / "s"), bucket=True)
    assert store.put(_rec(dims=(130, 120), obj=1.0, t=8))
    assert len(store) == 1
    # jittery neighbors land on (and resolve from) the same power-of-two key
    assert store.get("k", ((127, 130),), "host").config == {"t": 8}
    assert store.get("k", ((128, 128),), "host") is not None
    assert not store.put(_rec(dims=(126, 125), obj=2.0, t=4))  # same bucket, worse
    assert len(store) == 1


def test_compact_ttl_evicts_stale_records(tmp_path):
    import dataclasses
    import time as _time

    store = TuningStore(str(tmp_path / "s"))
    store.put(dataclasses.replace(_rec(dims=(64, 64), obj=1.0),
                                  created=_time.time() - 3600))
    store.put(_rec(dims=(128, 128), obj=1.0))
    assert store.compact(ttl_sec=60) == 1
    assert store.get("k", ((64, 64),), "host") is None
    assert store.get("k", ((128, 128),), "host") is not None


def test_compact_per_kernel_budget_keeps_recently_used(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    for d in (32, 64, 128):
        store.put(_rec(dims=(d, d), obj=1.0))
    store.put(_rec(kernel="other", dims=(8, 8), obj=1.0))
    store.get("k", ((64, 64),), "host")            # LRU-touch one key
    assert store.compact(max_per_kernel=1) == 2    # one per kernel survives
    assert store.get("k", ((64, 64),), "host") is not None
    assert store.get("k", ((32, 32),), "host") is None
    assert store.get("other", ((8, 8),), "host") is not None


def test_quarantine_survives_compact(tmp_path):
    path = str(tmp_path / "s")
    store = TuningStore(path)
    bad = _rec(dims=(64, 64), obj=1.0, t=8)
    store.put(bad)
    store.quarantine(bad)
    store.put(_rec(dims=(128, 128), obj=1.0, t=4))
    assert store.compact() == 1
    fresh = TuningStore(path)
    assert fresh.get("k", ((64, 64),), "host") is None
    assert not fresh.put(_rec(dims=(64, 64), obj=0.1, t=8))  # still banned


# ---------------------------------------------------------------------------
# model-kernel dispatch: flash attention resolves tuned (bq, bk) by signature
# ---------------------------------------------------------------------------


def _ref_attention(q, k, v, causal=True):
    import jax
    import jax.numpy as jnp

    s = jnp.einsum("bqh,bsh->bqs", q, k) * (q.shape[-1] ** -0.5)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = np.arange(Sq)[:, None] >= np.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    return jnp.einsum("bqs,bsh->bqh", jax.nn.softmax(s, axis=-1), v)


def test_flash_dispatch_resolves_tuned_blocks_by_signature(tmp_path):
    from repro.kernels.model_kernels import (
        flash_attention_signature,
        init_flash_attention,
    )

    q, k, v = init_flash_attention(2, 32, 32, 8)
    ref = np.asarray(_ref_attention(q, k, v))

    svc = DispatchService()                        # empty store -> space default
    out = np.asarray(svc.call("flash_attention", q, k, v, causal=True))
    assert svc.stats["store_default"] == 1
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord(
        "flash_attention", flash_attention_signature(2, 32, 32, 8), "host",
        {"impl": "pallas", "bq": 16, "bk": 16}, 0.5))
    svc2 = DispatchService(store)
    out2 = np.asarray(svc2.call("flash_attention", q, k, v, causal=True))
    assert svc2.stats["store_exact"] == 1          # resolved by signature
    assert svc2.stats["build_failed"] == 0         # tuned pallas variant ran
    np.testing.assert_allclose(out2, ref, atol=1e-5, rtol=1e-5)


def test_matmul_dispatch_matches_reference(tmp_path):
    from repro.kernels.model_kernels import init_matmul

    a, b = init_matmul(48, 40, 56)
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("matmul", (tuple(a.shape), tuple(b.shape)), "host",
                           {"bm": 16, "bn": 16, "bk": 16, "pack": True}, 0.5))
    svc = DispatchService(store)
    out = np.asarray(svc.call("matmul", a, b))
    assert svc.stats["store_exact"] == 1
    np.testing.assert_allclose(out, np.asarray(a) @ np.asarray(b),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# warm-start accounting fixes
# ---------------------------------------------------------------------------


def test_warm_start_excludes_reevaluated_config_from_priors(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_scale", ((8,),), "host", {"s": 32}, 1 / 32))
    store.put(TuningRecord("toy_scale", ((16,),), "host", {"s": 16}, 1 / 16))
    store.put(TuningRecord("toy_scale", ((64,),), "host", {"s": 8}, 1 / 8))
    tuner = BackgroundTuner(store, max_workers=1, warm_neighbors=3)
    try:
        cfgs, recs = tuner._warm_start("toy_scale", ((8,),), "host")
        assert cfgs == [{"s": 32}]                 # nearest, re-evaluated live
        # the re-evaluated config must NOT also appear as a virtual observation
        assert {"s": 32} not in [c for c, _ in recs]
        assert [c for c, _ in recs] == [{"s": 16}, {"s": 8}]
    finally:
        tuner.shutdown()


def test_warm_start_single_record_yields_no_priors(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("toy_scale", ((8,),), "host", {"s": 32}, 1 / 32))
    tuner = BackgroundTuner(store, max_workers=1)
    try:
        cfgs, recs = tuner._warm_start("toy_scale", ((8,),), "host")
        assert cfgs == [{"s": 32}] and recs is None
    finally:
        tuner.shutdown()


def test_run_search_warm_start_stops_at_budget():
    calls = []

    def ev(cfg):
        calls.append(dict(cfg))
        return EvalResult(1.0 / cfg["s"], True, {})

    warm = [{"s": s} for s in _TOY_SEQ]            # more configs than budget
    res = run_search(_toy_space(), ev, max_evals=2, learner="RF",
                     n_initial=1, warm_start=warm)
    assert len(calls) == 2 and len(res.db) == 2


def test_dispatch_miss_enqueues_background_campaign(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    tuner = BackgroundTuner(store, max_workers=1, max_evals=6, n_initial=2)
    svc = DispatchService(store, tuner=tuner)
    try:
        x = np.arange(4.0)
        svc.call("toy_scale", x)                  # miss -> default + enqueue
        assert svc.stats["bg_enqueued"] == 1
        svc.call("toy_scale", x)
        assert svc.stats["bg_enqueued"] == 1      # deduplicated while pending
        tuner.drain()
        assert tuner.errors == []
        np.testing.assert_array_equal(             # hot-swapped tuned config
            np.asarray(svc.call("toy_scale", x)), x * max(_TOY_SEQ))
    finally:
        tuner.shutdown()


def test_fast_hit_takes_lock_once():
    """The dispatch fast path (recent resolution, warm executable) must cost
    exactly one lock acquisition — read, exec lookup, and stat bump share a
    single critical section — even with metrics enabled: metric recording is
    shard-local (lock-free after the shard's one-time registration), so the
    registry lock must see ZERO acquisitions on the fast hit."""
    import threading

    from repro.obs.metrics import MetricsRegistry

    svc = DispatchService(metrics=MetricsRegistry())
    x = np.arange(4.0)
    svc.dispatch("toy_scale", x)  # populate the fast map + executable cache
    # (and register this thread's metrics shard — a one-time cost)

    class CountingLock:
        def __init__(self, inner):
            self._inner = inner
            self.acquisitions = 0

        def __enter__(self):
            self.acquisitions += 1
            return self._inner.__enter__()

        def __exit__(self, *exc):
            return self._inner.__exit__(*exc)

    counting = CountingLock(threading.RLock())
    svc._lock = counting
    reg_counting = CountingLock(threading.Lock())
    svc.metrics._lock = reg_counting
    hits_before = svc.stats["exec_hit"]
    svc.dispatch("toy_scale", x)
    assert svc.stats["exec_hit"] == hits_before + 1
    assert counting.acquisitions == 1
    assert reg_counting.acquisitions == 0
    # ...and the recording really happened: the fast-hit counter folded at
    # snapshot time shows this dispatch
    snap = svc.metrics.snapshot()
    fast = [c for c in snap["counters"]
            if c["name"] == "dispatch_requests_total"
            and c["labels"].get("path") == "fast_hit"]
    assert fast and fast[0]["value"] >= 1.0


def test_telemetry_reports_execute_latency_quantiles():
    """telemetry() surfaces per-signature execute-latency p50/p99 from the
    dispatch_execute_seconds histogram; the flat legacy keys stay intact."""
    from repro.obs.metrics import MetricsRegistry

    svc = DispatchService(metrics=MetricsRegistry())
    x = np.arange(4.0)
    fn = svc.dispatch("toy_scale", x)
    for _ in range(5):
        fn(x)
    tel = svc.telemetry()
    assert "exec_hit" in tel and "store_default" in tel  # legacy shape intact
    lat = tel["execute_latency"]
    assert len(lat) == 1
    row = lat[0]
    assert row["kernel"] == "toy_scale"
    assert row["backend"] == svc.backend
    assert row["count"] == 5
    assert 0 < row["p50_sec"] <= row["p99_sec"]
    assert row["mean_sec"] > 0


def test_optimizer_overhead_telemetry_flows_to_tuner(tmp_path):
    """Campaign.timings (ask/tell/wait seconds) aggregate into
    BackgroundTuner.stats — the CATBench-style first-class overhead metric."""
    store = TuningStore(str(tmp_path / "s"))
    tuner = BackgroundTuner(store, max_workers=1, max_evals=5, n_initial=2)
    svc = DispatchService(store, tuner=tuner)
    try:
        svc.dispatch("toy_scale", np.arange(4.0))
        tuner.drain()
        assert tuner.errors == []
        assert tuner.stats["campaigns"] == 1
        assert tuner.stats["ask_sec"] > 0.0
        assert tuner.stats["tell_sec"] > 0.0
    finally:
        tuner.shutdown()
