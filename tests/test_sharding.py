"""Sharding rules: per-leaf specs, profile selection, divisibility — the
unit-level guarantees behind the dry-run."""

import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.models.model import abstract_params, init_cache
from repro.parallel.sharding import (
    batch_specs,
    cache_specs,
    make_profile,
    mesh_axis_size,
    param_specs,
)


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh: enough for spec construction (no devices needed)."""
    devs = np.empty(shape, dtype=object)
    return _MeshLike(shape, axes)


class _MeshLike:
    """Duck-typed mesh carrying only .shape and .axis_names."""

    def __init__(self, shape, axes):
        self.shape = dict(zip(axes, shape))
        self.axis_names = axes


MESH1 = _MeshLike((16, 16), ("data", "model"))
MESH2 = _MeshLike((2, 16, 16), ("pod", "data", "model"))


def _leaf_specs(cfg, mesh, profile):
    tree = param_specs(abstract_params(cfg), mesh, profile, cfg)
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, P))[0]
    return {tuple(getattr(k, "key", str(k)) for k in path): spec
            for path, spec in flat}


def _shapes(cfg):
    flat = jax.tree_util.tree_flatten_with_path(abstract_params(cfg))[0]
    return {tuple(getattr(k, "key", str(k)) for k in path): leaf.shape
            for path, leaf in flat}


@pytest.mark.parametrize("mesh", [MESH1, MESH2])
@pytest.mark.parametrize("arch", ["qwen2-vl-7b", "deepseek-v2-236b",
                                  "mixtral-8x7b", "mamba2-780m",
                                  "whisper-large-v3", "gemma3-1b"])
def test_every_spec_divides_its_dim(arch, mesh):
    """The invariant the whisper-decode dry-run bug violated: every sharded
    dim must divide by the product of its mesh axes."""
    cfg = get_config(arch)
    profile = make_profile_like(mesh, "train", 256)
    specs = _leaf_specs(cfg, mesh, profile)
    shapes = _shapes(cfg)
    for path, spec in specs.items():
        shape = shapes[path]
        assert len(spec) <= len(shape), (path, spec, shape)
        for dim, axes in zip(shape, tuple(spec) + (None,) * len(shape)):
            if axes is None:
                continue
            n = mesh_axis_size_like(mesh, axes)
            assert dim % n == 0, (arch, path, spec, shape)


def make_profile_like(mesh, kind, batch):
    from repro.parallel.sharding import ShardingProfile, _divisible_prefix

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardingProfile(batch_axes=dp_axes, fsdp_axes=dp_axes)


def mesh_axis_size_like(mesh, axes):
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def test_expert_weights_sharded_over_experts_when_divisible():
    cfg = get_config("deepseek-v2-236b")   # 160 experts
    specs = _leaf_specs(cfg, MESH1, make_profile_like(MESH1, "train", 256))
    wg = [s for p, s in specs.items() if p[-1] == "wg" and p[-2] == "moe"]
    assert wg, "no expert weights found"
    # stacked (L, E, d, ff): E gets the fsdp axes, ff gets model
    assert tuple(wg[0]) == (None, "data", None, "model"), wg[0]


def test_mixtral_experts_fall_back_to_fsdp_on_d():
    cfg = get_config("mixtral-8x7b")   # 8 experts < 16 data
    specs = _leaf_specs(cfg, MESH1, make_profile_like(MESH1, "train", 256))
    wg = [s for p, s in specs.items() if p[-1] == "wg" and p[-2] == "moe"]
    assert wg[0][1] is None          # E unsharded
    assert wg[0][3] == "model"       # ff over tp


def test_embed_vocab_over_model_axis():
    cfg = get_config("qwen2-0.5b")
    specs = _leaf_specs(cfg, MESH1, make_profile_like(MESH1, "train", 256))
    assert tuple(specs[("embed",)]) == ("model", "data"), specs[("embed",)]


def test_norms_replicated():
    cfg = get_config("qwen2-0.5b")
    specs = _leaf_specs(cfg, MESH1, make_profile_like(MESH1, "train", 256))
    assert all(a is None for a in specs[("final_norm",)])
    ln = [s for p, s in specs.items() if p[-1] == "ln1"]
    assert all(all(a is None for a in s) for s in ln)


def test_cache_specs_divide():
    for arch in ("whisper-large-v3", "deepseek-v2-236b", "mamba2-780m",
                 "gemma3-1b", "zamba2-1.2b"):
        cfg = get_config(arch)
        cache = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
        profile = make_profile_like(MESH1, "decode", 128)
        specs = cache_specs(cache, MESH1, profile, cfg)
        flat_s = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
        for (path, spec), (_, leaf) in zip(flat_s, flat_c):
            for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if axes is None:
                    continue
                assert dim % mesh_axis_size_like(MESH1, axes) == 0, \
                    (arch, path, spec, leaf.shape)


def test_profile_batch_axes_divide_batch():
    # long_500k: batch=1 cannot shard -> empty batch axes
    prof = make_profile_real((2, 16, 16), ("pod", "data", "model"), "decode", 1)
    assert prof.batch_axes == ()
    prof = make_profile_real((2, 16, 16), ("pod", "data", "model"), "decode", 128)
    assert prof.batch_axes == ("pod", "data")
    prof = make_profile_real((16, 16), ("data", "model"), "train", 256)
    assert prof.batch_axes == ("data",)


def make_profile_real(shape, axes, kind, batch):
    mesh = _MeshLike(shape, axes)
    return make_profile(mesh, kind, batch)
