"""Decode-attention kernel: both impls pinned to the dense oracle across
ring-wrap, windowed, and cur_pos=0 edge cases, and the layout round-trip
against model-level ``gqa_decode``. Deterministic sweeps run everywhere
(tier-1, minimal CI); the hypothesis fuzz rides along where available."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (
    chunked_decode_xla,
    decode_attention,
    decode_ref,
)

DTOL = dict(atol=2e-5, rtol=2e-5)


def _close(got, want):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **DTOL)


def _decode_inputs(BH, G, S, hd, seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (BH, G, hd)),
            jax.random.normal(ks[1], (BH, S, hd)),
            jax.random.normal(ks[2], (BH, S, hd)))


@pytest.mark.parametrize("ring,window", [(False, 0), (True, 0),
                                         (False, 7), (True, 7)])
def test_decode_attention_matches_dense_reference(ring, window):
    BH, G, S, hd = 4, 2, 40, 16
    q, k, v = _decode_inputs(BH, G, S, hd)
    # per-row positions: empty context (pos 0), mid-cache, a wrapped ring
    # position past the allocation, and the exactly-full cache
    wrap = S + 25 if ring else S - 1
    cur = jnp.asarray([0, 13, wrap, S - 1], jnp.int32)
    want = decode_ref(q, k, v, cur, ring=ring, window=window)
    for bk, hg in ((16, 1), (64, 2), (128, 4)):
        got = decode_attention(q, k, v, cur, ring=ring, window=window,
                               bk=bk, hg=hg, interpret=True)
        _close(got, want)
    for bk in (8, 40, 128):
        got = chunked_decode_xla(q, k, v, cur, ring=ring, window=window, bk=bk)
        _close(got, want)


def test_decode_attention_matches_gqa_decode():
    """Kernel layout round-trip: flatten the model's (B, S, K, hd) cache to
    kernel rows exactly the way the dispatch route does, and match the
    model-level gqa_decode output for scalar and wrapped positions."""
    from repro.models.attention import gqa_decode

    B, S, K, G, hd = 2, 32, 2, 3, 16
    H = K * G
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kc = jax.random.normal(ks[1], (B, S, K, hd))
    vc = jax.random.normal(ks[2], (B, S, K, hd))
    for ring, cp in ((False, 23), (True, 23), (True, 100)):
        want = gqa_decode(q, kc, vc, cp, ring=ring)
        qg = q[:, 0].reshape(B, K, G, hd).reshape(B * K, G, hd)
        kf = kc.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
        vf = vc.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
        cur = jnp.full((B * K,), cp, jnp.int32)
        got = chunked_decode_xla(qg, kf, vf, cur, ring=ring, bk=8)
        got = got.reshape(B, K, G, hd).reshape(B, 1, H, hd)
        _close(got, want)


def test_decode_attention_vector_positions_independent_rows():
    """Per-row positions are independent: row i of the batched call equals a
    single-row call at that position (the continuous-batching contract)."""
    BH, G, S, hd = 5, 2, 24, 8
    q, k, v = _decode_inputs(BH, G, S, hd, seed=3)
    cur = jnp.asarray([0, 5, 11, 17, 23], jnp.int32)
    batched = chunked_decode_xla(q, k, v, cur, bk=8)
    for i in range(BH):
        solo = chunked_decode_xla(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                  cur[i:i + 1], bk=8)
        _close(batched[i], solo[0])


try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI installs omit hypothesis
    pass
else:
    @given(
        S=st.integers(8, 48),
        G=st.integers(1, 4),
        bk=st.sampled_from([4, 8, 16, 64]),
        ring=st.booleans(),
        window=st.sampled_from([0, 3, 9]),
        data=st.data(),
    )
    @settings(max_examples=20, deadline=None)
    def test_decode_attention_property(S, G, bk, ring, window, data):
        BH, hd = 3, 8
        hi = S * 3 - 1 if ring else S - 1
        cur = jnp.asarray(
            data.draw(st.lists(st.integers(0, hi), min_size=BH, max_size=BH)),
            jnp.int32)
        q, k, v = _decode_inputs(BH, G, S, hd, seed=S * 7 + G)
        want = decode_ref(q, k, v, cur, ring=ring, window=window)
        got = chunked_decode_xla(q, k, v, cur, ring=ring, window=window, bk=bk)
        _close(got, want)
