"""Parity suite: the vectorized surrogate/acquisition stack vs the legacy
recursive reference.

The vectorized hot path (prefix-sum CART splits, flat-array batched tree
inference, incremental GP Cholesky, pooled candidate encoding) promises:

  * RF / ET / GBRT fits and fixed-seed ``ask`` trajectories **bit-identical**
    to the pre-vectorization implementation (same RNG consumption order, same
    candidate thresholds, same tie-breaking);
  * GP predictions and trajectories within 1e-8 after incremental updates
    (documented tolerance — gemm-based distances and triangular solves drift
    a few ulps from the broadcast/dense-solve reference);
  * ``ask(n)`` samples and encodes the base candidate pool exactly once per
    batch.

The legacy implementations below are inlined verbatim from the pre-PR
``core/surrogates.py`` / ``core/search.py`` so the reference cannot drift.
"""

import numpy as np
import pytest

from repro.core.plopper import EvalResult
from repro.core.search import BayesianSearch
from repro.core.space import Categorical, ConfigurationSpace, Ordinal
from repro.core.surrogates import (
    ExtraTrees,
    GaussianProcess,
    GradientBoostedTrees,
    RandomForest,
    RegressionTree,
)

TILES = (4, 8, 16, 32, 64, 96, 128)


# ---------------------------------------------------------------------------
# the legacy reference, inlined (pre-vectorization surrogates)
# ---------------------------------------------------------------------------


class _LegacyNode:
    __slots__ = ("feature", "threshold", "left", "right", "value", "is_leaf")

    def __init__(self, value=0.0):
        self.feature, self.threshold = -1, 0.0
        self.left = self.right = None
        self.value, self.is_leaf = value, True


class LegacyRegressionTree:
    def __init__(self, max_depth=12, min_samples_split=2, min_samples_leaf=1,
                 max_features=None, splitter="best", rng=None):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.splitter = splitter
        self.rng = rng or np.random.default_rng(0)
        self.root = None

    def _n_features_to_try(self, d):
        mf = self.max_features
        if mf is None or mf == 1.0:
            return d
        if mf == "sqrt":
            return max(1, int(np.sqrt(d)))
        if mf == "log2":
            return max(1, int(np.log2(d))) if d > 1 else 1
        if isinstance(mf, float):
            return max(1, int(mf * d))
        return d

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self.root = self._build(X, y, 0)
        return self

    def _build(self, X, y, depth):
        node = _LegacyNode(value=float(y.mean()))
        n, d = X.shape
        if (depth >= self.max_depth or n < self.min_samples_split
                or n < 2 * self.min_samples_leaf or np.allclose(y, y[0])):
            return node
        feats = self.rng.permutation(d)[: self._n_features_to_try(d)]
        best = None
        for f in feats:
            col = X[:, f]
            lo, hi = col.min(), col.max()
            if lo == hi:
                continue
            if self.splitter == "random":
                thresholds = [self.rng.uniform(lo, hi)]
            else:
                uniq = np.unique(col)
                mids = (uniq[1:] + uniq[:-1]) / 2.0
                if len(mids) > 32:
                    mids = mids[np.linspace(0, len(mids) - 1, 32).astype(int)]
                thresholds = mids
            for t in thresholds:
                mask = col <= t
                nl = int(mask.sum())
                nr = n - nl
                if nl < self.min_samples_leaf or nr < self.min_samples_leaf:
                    continue
                yl, yr = y[mask], y[~mask]
                score = nl * yl.var() + nr * yr.var()
                if best is None or score < best[0]:
                    best = (score, f, t, mask)
        if best is None:
            return node
        _, f, t, mask = best
        node.is_leaf = False
        node.feature = int(f)
        node.threshold = float(t)
        node.left = self._build(X[mask], y[mask], depth + 1)
        node.right = self._build(X[~mask], y[~mask], depth + 1)
        return node

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        out = np.empty(len(X))
        for i, x in enumerate(X):
            node = self.root
            while not node.is_leaf:
                node = node.left if x[node.feature] <= node.threshold else node.right
            out[i] = node.value
        return out


class LegacyRandomForest:
    bootstrap, splitter, max_features = True, "best", "sqrt"

    def __init__(self, n_estimators=32, max_depth=12, seed=0, min_samples_leaf=1):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.rng = np.random.default_rng(seed)
        self.trees = []

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = len(X)
        self.trees = []
        for _ in range(self.n_estimators):
            idx = self.rng.integers(0, n, size=n) if self.bootstrap else np.arange(n)
            tree = LegacyRegressionTree(
                max_depth=self.max_depth, max_features=self.max_features,
                splitter=self.splitter, min_samples_leaf=self.min_samples_leaf,
                rng=np.random.default_rng(int(self.rng.integers(2**31))))
            tree.fit(X[idx], y[idx])
            self.trees.append(tree)
        return self

    def predict(self, X):
        preds = np.stack([t.predict(X) for t in self.trees])
        return preds.mean(axis=0), preds.std(axis=0) + 1e-9


class LegacyExtraTrees(LegacyRandomForest):
    bootstrap, splitter, max_features = False, "random", 1.0


class _LegacyQuantileGBT:
    def __init__(self, alpha, n_estimators, lr, max_depth, seed):
        self.alpha, self.n_estimators, self.lr, self.max_depth = (
            alpha, n_estimators, lr, max_depth)
        self.rng = np.random.default_rng(seed)
        self.base, self.trees = 0.0, []

    def fit(self, X, y):
        self.base = float(np.quantile(y, self.alpha))
        pred = np.full(len(y), self.base)
        self.trees = []
        for _ in range(self.n_estimators):
            resid = y - pred
            grad = np.where(resid > 0, self.alpha, self.alpha - 1.0)
            tree = LegacyRegressionTree(
                max_depth=self.max_depth,
                rng=np.random.default_rng(int(self.rng.integers(2**31))))
            tree.fit(X, grad)
            self._requantile(tree.root, X, resid, np.arange(len(y)))
            pred = pred + self.lr * tree.predict(X)
            self.trees.append(tree)
        return self

    def _requantile(self, node, X, resid, idx):
        if node.is_leaf:
            node.value = float(np.quantile(resid[idx], self.alpha)) if len(idx) else 0.0
            return
        mask = X[idx, node.feature] <= node.threshold
        self._requantile(node.left, X, resid, idx[mask])
        self._requantile(node.right, X, resid, idx[~mask])

    def predict(self, X):
        out = np.full(len(X), self.base)
        for tree in self.trees:
            out = out + self.lr * tree.predict(X)
        return out


class LegacyGBRT:
    def __init__(self, n_estimators=64, lr=0.15, max_depth=4, seed=0):
        self.models = {a: _LegacyQuantileGBT(a, n_estimators, lr, max_depth, seed + i)
                       for i, a in enumerate((0.16, 0.50, 0.84))}

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        for m in self.models.values():
            m.fit(X, y)
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        lo = self.models[0.16].predict(X)
        mid = self.models[0.50].predict(X)
        hi = self.models[0.84].predict(X)
        return mid, np.maximum((hi - lo) / 2.0, 1e-9)


class LegacyGP:
    def __init__(self, length_scales=(0.1, 0.2, 0.5, 1.0, 2.0, 5.0), noise=1e-4,
                 seed=0):
        self.length_scales = tuple(length_scales)
        self.noise = noise
        self._X = self._alpha = self._L = None
        self._ls, self._ymean, self._ystd = 1.0, 0.0, 1.0

    @staticmethod
    def _k(X1, X2, ls):
        d2 = ((X1[:, None, :] - X2[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / (ls * ls))

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self._ymean = float(y.mean())
        self._ystd = float(y.std()) or 1.0
        yn = (y - self._ymean) / self._ystd
        n = len(X)
        best = None
        for ls in self.length_scales:
            K = self._k(X, X, ls) + (self.noise + 1e-10) * np.eye(n)
            try:
                L = np.linalg.cholesky(K)
            except np.linalg.LinAlgError:
                continue
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
            lml = -0.5 * yn @ alpha - np.log(np.diag(L)).sum()
            if best is None or lml > best[0]:
                best = (lml, ls, L, alpha)
        if best is None:
            ls = self.length_scales[-1]
            K = self._k(X, X, ls) + 1e-2 * np.eye(n)
            L = np.linalg.cholesky(K)
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
            best = (0.0, ls, L, alpha)
        _, self._ls, self._L, self._alpha = best
        self._X = X
        return self

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        Ks = self._k(X, self._X, self._ls)
        mu = Ks @ self._alpha
        v = np.linalg.solve(self._L, Ks.T)
        var = np.maximum(1.0 - (v**2).sum(axis=0), 1e-12)
        return mu * self._ystd + self._ymean, np.sqrt(var) * self._ystd + 1e-9


LEGACY = {"RF": LegacyRandomForest, "ET": LegacyExtraTrees, "GBRT": LegacyGBRT}
CURRENT = {"RF": RandomForest, "ET": ExtraTrees, "GBRT": GradientBoostedTrees}


class LegacyBayesianSearch(BayesianSearch):
    """The pre-vectorization serial ask path, inlined verbatim: fresh learner
    per ask, fresh 512-sample pool per ask, ``encode_many`` on everything."""

    def _training_data(self):
        from repro.core.database import FAILED, OK
        recs = [r for r in self.db.records if r.status in (OK, FAILED)]
        if not recs:
            if self._prior_X is not None:
                return self._liar_augment(self._prior_X, self._prior_y)
            return (None, None) if not self._pending else self._liar_augment(None, None)
        ok_vals = [r.objective for r in recs if r.status == OK]
        cap = (max(ok_vals) * 2.0 + 1e-9) if ok_vals else 1.0
        X = self.space.encode_many([r.config for r in recs])
        y = np.array([min(r.objective, cap) for r in recs])
        if self._prior_X is not None:
            X = np.concatenate([X, self._prior_X])
            y = np.concatenate([y, self._prior_y])
        return self._liar_augment(X, y)

    def _legacy_pool(self):
        pool = self.space.sample_configurations(self.n_candidates, self.rng)
        best = self.db.best()
        if best is not None:
            pool += [self.space.mutate(best.config, self.rng)
                     for _ in range(self.n_candidates // 8)]
        return pool

    def _ask_one(self):
        if len(self.db) + self.n_pending < self.n_initial:
            if not self._init_queue:
                self._init_queue = self._initial_batch()
            while self._init_queue:
                cfg = self._init_queue.pop(0)
                if not self.dedups_against_db or self._is_fresh(cfg):
                    return cfg
            return self.space.sample_configuration(self.rng)

        X, y = self._training_data()
        if X is None or len(np.unique(y)) < 2:
            return self.space.sample_configuration(self.rng)
        seed = int(self.rng.integers(2**31))
        model = (LegacyGP(seed=seed) if self.learner_name == "GP"
                 else LEGACY[self.learner_name](seed=seed))
        model.fit(X, y)
        self._model = model

        pool = self._legacy_pool()
        Xc = self.space.encode_many(pool)
        mu, sigma = model.predict(Xc)
        best = self.db.best()
        scores = self.acq(mu, sigma, kappa=self.kappa,
                          best=best.objective if best else float(np.min(y)))
        order = np.argsort(scores)
        if self.dedups_against_db:
            for i in order:
                if self._is_fresh(pool[int(i)]):
                    return pool[int(i)]
            return self.space.sample_configuration(self.rng)
        return pool[int(order[0])]


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def toy_data(n=150, d=12, seed=0):
    """Encoded-config-shaped data: one-hot-ish binary blocks plus discrete
    normalized ranks — the structure the surrogates actually see."""
    rng = np.random.default_rng(seed)
    Xb = (rng.uniform(0, 1, size=(n, d // 2)) > 0.5).astype(float)
    Xc = rng.choice(np.linspace(0, 1, 11), size=(n, d - d // 2))
    X = np.concatenate([Xb, Xc], axis=1)
    y = (3 * X[:, 0] + np.sin(4 * X[:, -1]) + 0.5 * X[:, 2] * X[:, -2]
         + 0.01 * rng.standard_normal(n))
    return X, y


def small_space(seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("pack", (True, False), default=False),
        Categorical("inter", (True, False), default=False),
        Ordinal("t1", TILES, default=96),
        Ordinal("t2", TILES, default=96),
    ])
    return cs


def objective(cfg):
    return (1.0 - 0.3 * bool(cfg["pack"]) - 0.2 * bool(cfg["inter"])
            + 0.004 * abs(int(cfg["t1"]) - 64) + 0.002 * abs(int(cfg["t2"]) - 32))


def run_serial(search, max_evals):
    """The paper's serial loop over any BayesianSearch; returns the config
    trajectory (with GP duplicate-skip semantics)."""
    traj = []
    while len(search.db) < max_evals:
        cfg = search.ask()
        traj.append(dict(cfg))
        if not search.dedups_against_db and search.db.contains(cfg):
            search.tell_skipped(cfg)
        else:
            search.tell(cfg, EvalResult(objective(cfg), True, {}))
    return traj


# ---------------------------------------------------------------------------
# tree learners: bit-identical fits and trajectories
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["RF", "ET", "GBRT"])
@pytest.mark.parametrize("seed", [0, 3])
def test_tree_fit_bit_identical(name, seed):
    X, y = toy_data(seed=seed)
    Xte, _ = toy_data(n=64, seed=seed + 100)
    ref = LEGACY[name](seed=seed).fit(X, y)
    got = CURRENT[name](seed=seed).fit(X, y)
    for XX in (X, Xte):
        mu_r, sg_r = ref.predict(XX)
        mu_g, sg_g = got.predict(XX)
        np.testing.assert_array_equal(mu_g, mu_r)
        np.testing.assert_array_equal(sg_g, sg_r)


def test_single_tree_bit_identical_structure():
    X, y = toy_data(n=90, seed=1)
    ref = LegacyRegressionTree(max_depth=8, rng=np.random.default_rng(7)).fit(X, y)
    got = RegressionTree(max_depth=8, rng=np.random.default_rng(7)).fit(X, y)

    def walk(a, b):
        assert a.is_leaf == b.is_leaf
        if a.is_leaf:
            assert a.value == b.value
            return
        assert (a.feature, a.threshold) == (b.feature, b.threshold)
        walk(a.left, b.left)
        walk(a.right, b.right)

    walk(ref.root, got.root)
    # and the flat-array traversal equals the recursive walk
    np.testing.assert_array_equal(got.predict(X), ref.predict(X))


@pytest.mark.parametrize("learner", ["RF", "ET", "GBRT"])
def test_tree_ask_trajectory_bit_identical(learner):
    ref = LegacyBayesianSearch(small_space(), learner=learner, seed=11)
    got = BayesianSearch(small_space(), learner=learner, seed=11)
    assert run_serial(ref, 25) == run_serial(got, 25)


# ---------------------------------------------------------------------------
# GP: documented 1e-8 tolerance, incremental == full
# ---------------------------------------------------------------------------


def test_gp_predictions_within_tolerance():
    X, y = toy_data(seed=2)
    Xte, _ = toy_data(n=64, seed=200)
    ref = LegacyGP().fit(X, y)
    got = GaussianProcess().fit(X, y)
    assert got._ls == ref._ls
    for XX in (X, Xte):
        mu_r, sg_r = ref.predict(XX)
        mu_g, sg_g = got.predict(XX)
        np.testing.assert_allclose(mu_g, mu_r, atol=1e-8, rtol=0)
        np.testing.assert_allclose(sg_g, sg_r, atol=1e-8, rtol=0)


def test_gp_incremental_matches_full_refit():
    """partial_fit row-appends must track a from-scratch legacy fit *at the
    same length scale* within 1e-8 at every step: the incremental Cholesky
    extension introduces no meaningful drift between the periodic full
    refactorizations. (Length-scale selection itself is deliberately hoisted
    to every ``refit_every`` tells — between grid runs the cached scale may
    differ from what a fresh grid would pick; trajectory-level agreement is
    pinned separately at fixed seeds below.)"""
    X, y = toy_data(n=120, seed=4)
    Xte, _ = toy_data(n=32, seed=400)
    inc = GaussianProcess()
    for i in range(10, len(X) + 1):
        inc.partial_fit(X[:i], y[:i])
        if i % 25 == 0 or i == len(X):
            ref = LegacyGP(length_scales=(inc._ls,)).fit(X[:i], y[:i])
            mu_r, sg_r = ref.predict(Xte)
            mu_g, sg_g = inc.predict(Xte)
            np.testing.assert_allclose(mu_g, mu_r, atol=1e-8, rtol=0)
            np.testing.assert_allclose(sg_g, sg_r, atol=1e-8, rtol=0)


def test_gp_incremental_handles_tail_churn():
    """The BO batch pattern: liar rows appended at the tail, then replaced by
    real observations (prefix unchanged, tail rewritten, set shrinks/grows)."""
    X, y = toy_data(n=60, seed=5)
    inc = GaussianProcess()
    inc.partial_fit(X[:40], y[:40])
    # append two liar rows, then drop them and land three real rows
    Xl = np.concatenate([X[:40], X[50:52]])
    yl = np.concatenate([y[:40], np.full(2, float(y[:40].mean()))])
    inc.partial_fit(Xl, yl)
    inc.partial_fit(X[:43], y[:43])
    ref = LegacyGP().fit(X[:43], y[:43])
    mu_r, sg_r = ref.predict(X[45:55])
    mu_g, sg_g = inc.predict(X[45:55])
    np.testing.assert_allclose(mu_g, mu_r, atol=1e-8, rtol=0)
    np.testing.assert_allclose(sg_g, sg_r, atol=1e-8, rtol=0)


def test_gp_ask_trajectory_matches_legacy():
    ref = LegacyBayesianSearch(small_space(), learner="GP", seed=21)
    got = BayesianSearch(small_space(), learner="GP", seed=21)
    assert run_serial(ref, 25) == run_serial(got, 25)


# ---------------------------------------------------------------------------
# pooled acquisition: the base pool is sampled and encoded once per ask(n)
# ---------------------------------------------------------------------------


class _CountingSpace(ConfigurationSpace):
    def __init__(self, seed=1234):
        super().__init__(seed)
        self.n_sample_calls = 0
        self.n_rows_encoded = 0

    def sample_configurations(self, n, rng=None):
        self.n_sample_calls += 1
        return super().sample_configurations(n, rng)

    def encode(self, config):
        self.n_rows_encoded += 1
        return super().encode(config)

    def encode_many(self, configs):
        self.n_rows_encoded += len(configs)
        return super().encode_many(configs)


def _counting_space():
    cs = _CountingSpace()
    cs.add_hyperparameters([
        Categorical("pack", (True, False), default=False),
        Ordinal("t1", TILES, default=96),
        Ordinal("t2", TILES, default=96),
    ])
    return cs


def test_ask_batch_samples_and_encodes_pool_once():
    cs = _counting_space()
    search = BayesianSearch(cs, learner="RF", seed=0, n_initial=4)
    rng = np.random.default_rng(1)
    for cfg in cs.sample_configurations(8, rng):
        search.tell(cfg, EvalResult(objective({"inter": False, **cfg}), True, {}))
    cs.n_sample_calls = 0
    cs.n_rows_encoded = 0
    q = 4
    batch = search.ask(q)
    assert len(batch) == q
    # one 512-sample draw for the whole batch (not one per proposal)
    assert cs.n_sample_calls == 1
    # base pool encoded once; per-proposal extras are mutation candidates
    # (n_candidates/8 each) + training/pending rows — far below q pools
    base = search.n_candidates
    per_proposal_extra = search.n_candidates // 8 + 32
    assert cs.n_rows_encoded <= base + q * per_proposal_extra
    for cfg in batch:
        search.clear_pending(cfg)


def test_encode_many_bitwise_equals_encode():
    """The batched encoder must agree with per-config ``encode`` to the bit:
    cached training rows (encode) and pool rows (encode_many) feed the same
    surrogate."""
    cs = ConfigurationSpace(seed=0)
    from repro.core.space import InCondition, Integer, Float
    cs.add_hyperparameters([
        Categorical("pack", (True, False), default=False),
        Ordinal("t1", TILES, default=96),
        Integer("u", 1, 64, log=True),
        Integer("v", 0, 7),
        Float("eps", 1e-4, 1e-1, log=True),
        Categorical("mode", ("a", "b", "c")),
    ])
    cs.add_condition(InCondition("t1", "pack", (True,)))
    rng = np.random.default_rng(5)
    configs = [cs.sample_configuration(rng) for _ in range(64)]
    batched = cs.encode_many(configs)
    single = np.stack([cs.encode(c) for c in configs])
    np.testing.assert_array_equal(batched, single)


def test_ask1_trajectory_equals_serial_ask():
    """ask(1) (the q=1 engine path) must consume RNG exactly like ask()."""
    a = BayesianSearch(small_space(), learner="RF", seed=9)
    b = BayesianSearch(small_space(), learner="RF", seed=9)
    for _ in range(15):
        cfg_a = a.ask()
        [cfg_b] = b.ask(1)
        assert cfg_a == cfg_b
        a.tell(cfg_a, EvalResult(objective(cfg_a), True, {}))
        b.tell(cfg_b, EvalResult(objective(cfg_b), True, {}))
