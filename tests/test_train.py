"""Training loop: loss goes down, grad accumulation is exact, remat is
numerically transparent, LR schedules behave."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import SyntheticLM, make_batch
from repro.models import init_params, loss_fn
from repro.train import (
    cosine_lr,
    init_train_state,
    linear_warmup_lr,
    make_train_step,
)

KEY = jax.random.PRNGKey(0)


def _cfg():
    return dataclasses.replace(get_reduced("qwen2-0.5b"), dtype=jnp.float32)


def test_loss_decreases_over_steps():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    opt = init_train_state(params)
    step = jax.jit(make_train_step(cfg, lr=3e-3))
    stream = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    first = last = None
    for i in range(30):
        batch = make_batch(stream, 0)  # same batch: should be memorized
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < 0.7 * first, (first, last)


def test_grad_accumulation_matches_full_batch():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    stream = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=8, seed=1)
    batch = make_batch(stream, 0)

    opt1 = init_train_state(params)
    opt2 = init_train_state(params)
    s1 = jax.jit(make_train_step(cfg, lr=1e-3, accum=1))
    s2 = jax.jit(make_train_step(cfg, lr=1e-3, accum=4))
    p1, _, m1 = s1(params, opt1, batch)
    p2, _, m2 = s2(params, opt2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-4)


def test_remat_does_not_change_loss():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    stream = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=4, seed=2)
    batch = make_batch(stream, 0)
    l_none, _ = loss_fn(params, batch, cfg, remat="none")
    l_full, _ = loss_fn(params, batch, cfg, remat="full")
    l_dots, _ = loss_fn(params, batch, cfg, remat="dots")
    np.testing.assert_allclose(float(l_none), float(l_full), rtol=1e-6)
    np.testing.assert_allclose(float(l_none), float(l_dots), rtol=1e-6)


def test_remat_grads_match():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    stream = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=4, seed=3)
    batch = make_batch(stream, 0)

    def loss_with(remat):
        return jax.grad(lambda p: loss_fn(p, batch, cfg, remat=remat)[0])(params)

    g1 = loss_with("none")
    g2 = loss_with("full")
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-3)


def test_lr_schedules():
    np.testing.assert_allclose(float(linear_warmup_lr(0, peak=1.0, warmup=10)), 0.1,
                               rtol=1e-6)
    np.testing.assert_allclose(float(linear_warmup_lr(99, peak=1.0, warmup=10)), 1.0,
                               rtol=1e-6)
    lrs = [float(cosine_lr(s, peak=1.0, warmup=10, total=100)) for s in range(100)]
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[50] > lrs[99]          # decaying after warmup
    assert lrs[99] >= 0.1 - 1e-6      # floor


def test_synthetic_stream_deterministic():
    s1 = SyntheticLM(1000, 16, 4, seed=42)
    s2 = SyntheticLM(1000, 16, 4, seed=42)
    b1, b2 = s1.batch_at(7), s2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    full1 = s1.batch_at(7)
    assert full1["tokens"].shape == full1["labels"].shape
