"""Performance database: dedup, persistence, resume, findMin."""

import csv
import json
import os

from repro.core.database import FAILED, OK, PerformanceDatabase
from repro.core.findmin import find_min, importance_report


def test_dedup_and_best():
    db = PerformanceDatabase()
    db.add({"a": 1}, 3.0)
    db.add({"a": 2}, 1.0)
    db.add({"a": 3}, 9.0, status=FAILED)
    assert db.contains({"a": 1})
    assert not db.contains({"a": 7})
    assert find_min(db).config == {"a": 2}
    assert db.lookup({"a": 1}).objective == 3.0


def test_best_trajectory_monotone():
    db = PerformanceDatabase()
    for i, y in enumerate([5.0, 4.0, 6.0, 2.0, 3.0]):
        db.add({"i": i}, y)
    traj = db.best_trajectory()
    assert traj == [5.0, 4.0, 4.0, 2.0, 2.0]
    assert all(a >= b for a, b in zip(traj, traj[1:]))


def test_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "db")
    db = PerformanceDatabase(path, param_names=["a", "b"])
    db.add({"a": 1, "b": "x"}, 2.5, elapsed_sec=0.1)
    db.add({"a": 2, "b": "y"}, 1.5, elapsed_sec=0.2, status=FAILED,
           info={"error": "boom"})

    # results.csv exists with both rows (paper's output file #1)
    with open(os.path.join(path, "results.csv")) as f:
        rows = list(csv.reader(f))
    assert rows[0] == ["a", "b", "objective", "elapsed_sec", "status"]
    assert len(rows) == 3

    # results.json reloads into an equivalent DB (the resume log)
    db2 = PerformanceDatabase(path)
    assert len(db2) == 2
    assert db2.best().objective == 2.5  # failed record is not "best"
    assert db2.contains({"a": 1, "b": "x"})
    assert db2.records[1].info["error"] == "boom"


def test_jsonl_appends_one_line_per_record(tmp_path):
    path = str(tmp_path / "db")
    db = PerformanceDatabase(path)
    for i in range(5):
        db.add({"i": i}, float(i))
    with open(os.path.join(path, "results.jsonl")) as f:
        data = [json.loads(line) for line in f if line.strip()]
    assert [d["config"]["i"] for d in data] == list(range(5))


def test_legacy_results_json_loads_and_migrates(tmp_path):
    path = str(tmp_path / "db")
    os.makedirs(path)
    legacy = [
        {"index": 0, "config": {"i": 0}, "objective": 4.0, "elapsed_sec": 0.1},
        {"index": 1, "config": {"i": 1}, "objective": 2.0, "elapsed_sec": 0.2},
    ]
    with open(os.path.join(path, "results.json"), "w") as f:
        json.dump(legacy, f)
    db = PerformanceDatabase(path)
    assert len(db) == 2
    assert db.best().objective == 2.0
    # migrated: future opens read the jsonl (full history preserved)
    assert os.path.exists(os.path.join(path, "results.jsonl"))
    db.add({"i": 2}, 1.0)
    db2 = PerformanceDatabase(path)
    assert len(db2) == 3
    assert db2.best().objective == 1.0


def test_jsonl_ignores_torn_final_line(tmp_path):
    path = str(tmp_path / "db")
    db = PerformanceDatabase(path)
    db.add({"i": 0}, 1.0)
    db.add({"i": 1}, 2.0)
    with open(os.path.join(path, "results.jsonl"), "a") as f:
        f.write('{"index": 2, "config": {"i"')  # crash mid-append
    db2 = PerformanceDatabase(path)
    assert len(db2) == 2
    # resumed appends must not merge into the torn fragment
    db2.add({"i": 3}, 0.5)
    db3 = PerformanceDatabase(path)
    assert len(db3) == 3
    assert db3.best().objective == 0.5


def test_importance_report_ranks_influential_param():
    db = PerformanceDatabase()
    for a in range(4):
        for b in range(4):
            db.add({"big": a, "small": b}, 10.0 * a + 0.1 * b)
    ranked = importance_report(db)
    assert ranked[0][0] == "big"
    assert ranked[0][1] > ranked[1][1]
