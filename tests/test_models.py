"""Per-architecture smoke tests (deliverable f): REDUCED config of each
family — forward + one train step on CPU, asserting shapes and finiteness —
plus family-specific invariants (SSD vs recurrence, MLA absorb equivalence,
ring cache)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _f32(cfg):
    return dataclasses.replace(cfg, dtype=jnp.float32)


def _batch(cfg, B=2, S=16):
    b = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        b["enc_embed"] = jax.random.normal(KEY, (B, cfg.encoder_len, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_decode(arch):
    cfg = _f32(get_reduced(arch))
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = forward(params, batch, cfg, ssm_chunk=8)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    total, metrics = loss_fn(params, batch, cfg, ssm_chunk=8)
    assert bool(jnp.isfinite(total))

    cache = init_cache(cfg, 2, 32)
    lg, cache2 = decode_step(params, cache, batch["tokens"][:, :1], 0, cfg)
    assert lg.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m", "mixtral-8x7b",
                                  "deepseek-v2-236b", "zamba2-1.2b"])
def test_smoke_train_step(arch):
    cfg = _f32(get_reduced(arch))
    params = init_params(cfg, KEY)
    opt = init_train_state(params)
    step = make_train_step(cfg, lr=1e-3, ssm_chunk=8)
    batch = _batch(cfg)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["total"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(o2["step"]) == 1
    # parameters actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)))
    assert delta > 0


def test_config_registry_complete():
    assert len(ARCHS) == 10
    for arch in ARCHS:
        cfg = get_config(arch)
        assert cfg.name == arch
        red = get_reduced(arch)
        assert red.family == cfg.family
        assert red.param_count() < cfg.param_count()


def test_param_counts_match_public_sizes():
    """Analytic N must land near the published sizes (ours differ only via
    documented substitutions like gated MLPs — see DESIGN.md)."""
    expected = {
        "qwen2-vl-7b": 7.6e9, "deepseek-v2-236b": 236e9, "mixtral-8x7b": 46.7e9,
        "mamba2-780m": 0.78e9, "gemma3-1b": 1.0e9, "qwen2-0.5b": 0.49e9,
        "zamba2-1.2b": 1.2e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert 0.7 < got / n < 1.4, (arch, got, n)


def test_moe_active_params_smaller():
    for arch in ("deepseek-v2-236b", "mixtral-8x7b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.35 * cfg.param_count()


def test_decode_matches_forward_teacher_forcing():
    """Sequential decode over a prompt must reproduce forward()'s logits —
    the cache path's end-to-end correctness check."""
    for arch in ("qwen2-0.5b", "mamba2-780m", "gemma3-1b"):
        cfg = _f32(get_reduced(arch))
        params = init_params(cfg, KEY)
        B, S = 2, 12
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
        ref_logits, _ = forward(params, {"tokens": toks}, cfg, ssm_chunk=4)
        cache = init_cache(cfg, B, S + 4)
        outs = []
        for t in range(S):
            lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
            outs.append(lg)
        got = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                                   atol=2e-2, rtol=2e-2), arch


def test_mla_absorb_equivalence():
    """DeepSeek decode: absorbed and naive schedules are the same math."""
    cfg = _f32(get_reduced("deepseek-v2-236b"))
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    c1 = init_cache(cfg, 2, 8)
    c2 = init_cache(cfg, 2, 8)
    lg_a, _ = decode_step(params, c1, toks, 0, cfg, mla_absorb=True)
    lg_n, _ = decode_step(params, c2, toks, 0, cfg, mla_absorb=False)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_n),
                               atol=1e-3, rtol=1e-3)


def test_ring_cache_matches_full_attention_within_window():
    """The windowed ring KV cache must reproduce full forward logits even
    after the ring wraps. Uses a dense arch with a uniform window (an MoE
    arch would diverge for the *separate*, documented reason that GShard
    capacity drops tokens in batched forward but never in decode)."""
    cfg = dataclasses.replace(_f32(get_reduced("qwen2-0.5b")), sliding_window=8)
    params = init_params(cfg, KEY)
    B, S = 1, 20  # exceeds the 8-token window -> ring wraps twice
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, S)
    assert cache["layers"]["k"].shape[2] == cfg.sliding_window  # ring alloc
    ring_logits = []
    for t in range(S):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        ring_logits.append(lg)
    ref, _ = forward(params, {"tokens": toks}, cfg)
    got = jnp.stack(ring_logits, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)


def test_moe_capacity_drops_are_decode_train_semantic_difference():
    """Documents GShard capacity semantics: with ample capacity, batched
    forward == sequential decode for an MoE arch; with tight capacity the
    batched path drops tokens (decode never does)."""
    from repro.models.moe import moe_ffn
    from repro.models.model import MOE_AUX_COEF  # noqa: F401 (import check)

    # ample capacity -> no drops -> paths agree
    cfg = dataclasses.replace(_f32(get_reduced("mixtral-8x7b")), capacity_factor=8.0)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(9), (1, 6), 0, cfg.vocab_size)
    ref, _ = forward(params, {"tokens": toks}, cfg)
    cache = init_cache(cfg, 1, 8)
    outs = []
    for t in range(6):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)


def test_gemma3_local_global_pattern():
    from repro.models.blocks import layer_windows
    cfg = get_config("gemma3-1b")
    w = layer_windows(cfg)
    assert len(w) == cfg.n_layers
    assert (w == 0).sum() == cfg.n_layers // (cfg.local_global_ratio + 1)
    assert set(w[w > 0]) == {cfg.sliding_window}


def test_moe_group_size_invariance():
    """Grouped dispatch must be semantics-preserving: with ample capacity,
    every group size yields the same output (the §Perf iteration-0 fix)."""
    from repro.models.moe import init_moe, moe_ffn

    p = init_moe(jax.random.PRNGKey(7), 16, 32, n_experts=4, n_shared=0,
                 dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 32, 16))
    outs = []
    for g in (8, 16, 64, 2048):
        y, _ = moe_ffn(p, x, top_k=2, capacity_factor=8.0, group_size=g)
        outs.append(np.asarray(y))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5, rtol=1e-5)


def test_whisper_decode_teacher_forcing():
    """Enc-dec path: sequential decode (self-cache + fixed cross K/V) must
    reproduce the batched decoder forward."""
    cfg = _f32(get_reduced("whisper-large-v3"))
    params = init_params(cfg, KEY)
    B, S = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(11), (B, S), 0, cfg.vocab_size)
    enc = jax.random.normal(jax.random.PRNGKey(12), (B, cfg.encoder_len, cfg.d_model))
    ref, _ = forward(params, {"tokens": toks, "enc_embed": enc}, cfg)

    # fill the cross cache the way prefill would: encoder output per layer
    from repro.models import blocks as BB
    from repro.models.common import rms_norm as _rn
    cache = init_cache(cfg, B, S + 2)
    enc_out = enc
    enc_pos = jnp.broadcast_to(jnp.arange(cfg.encoder_len)[None, :],
                               (B, cfg.encoder_len))
    for li in range(cfg.n_encoder_layers):
        p_l = jax.tree_util.tree_map(lambda a: a[li], params["enc_layers"])
        enc_out, _ = BB.attn_layer_train(p_l, enc_out, cfg=cfg,
                                         positions=enc_pos, window=None,
                                         moe=False, causal=False)
    hd = cfg.hd
    eks, evs = [], []
    for li in range(cfg.n_layers):
        p_l = jax.tree_util.tree_map(lambda a: a[li], params["dec_layers"])
        eks.append((enc_out @ p_l["xk"]).reshape(B, cfg.encoder_len,
                                                 cfg.n_kv_heads, hd))
        evs.append((enc_out @ p_l["xv"]).reshape(B, cfg.encoder_len,
                                                 cfg.n_kv_heads, hd))
    cache["cross"] = {"k": jnp.stack(eks), "v": jnp.stack(evs)}

    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        outs.append(lg)
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)
