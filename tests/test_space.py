"""ConfigurationSpace: unit + hypothesis property tests."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.space import (
    Categorical,
    ConfigurationSpace,
    Float,
    ForbiddenClause,
    InCondition,
    Integer,
    Ordinal,
    config_key,
)


def paper_syr2k_space(seed=1234):
    """The verbatim space from the paper's Sec 4.1."""
    cs = ConfigurationSpace(seed=seed)
    p0 = Categorical("P0", ("#pragma pack A", " "), default=" ")
    p1 = Categorical("P1", ("#pragma pack B", " "), default=" ")
    p2 = Categorical("P2", ("#pragma interchange", " "), default=" ")
    cs.add_hyperparameters([
        p0, p1, p2,
        Ordinal("P3", ("4", "8", "16", "20", "32", "50", "64", "80", "96", "100", "128"), default="96"),
        Ordinal("P4", ("4", "8", "16", "20", "32", "50", "64", "80", "100", "128", "2048"), default="2048"),
        Ordinal("P5", ("4", "8", "16", "20", "32", "50", "64", "80", "100", "128", "256"), default="256"),
    ])
    cs.add_condition(InCondition("P1", "P0", ("#pragma pack A",)))
    return cs


def test_paper_space_cardinality():
    # the paper reports 2*2*2*11^3 = 10,648 configurations for syr2k
    assert paper_syr2k_space().cardinality() == 10_648


def test_default_configuration_respects_conditions():
    cs = paper_syr2k_space()
    d = cs.default_configuration()
    assert d["P0"] == " "
    assert "P1" not in d  # pack-B inactive when A is not packed
    cs.validate(d)


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_samples_always_valid(seed):
    cs = paper_syr2k_space(seed=seed)
    cfg = cs.sample_configuration()
    cs.validate(cfg)  # raises on violation
    # P1 present iff P0 packs
    assert ("P1" in cfg) == (cfg["P0"] == "#pragma pack A")


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_encode_fixed_length_and_deterministic(seed):
    cs = paper_syr2k_space()
    rng = np.random.default_rng(seed)
    cfg = cs.sample_configuration(rng)
    v1 = cs.encode(cfg)
    v2 = cs.encode(dict(cfg))
    assert v1.shape == (cs.n_features(),)
    np.testing.assert_array_equal(v1, v2)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_mutate_stays_valid(seed):
    cs = paper_syr2k_space(seed=seed)
    cfg = cs.sample_configuration()
    mut = cs.mutate(cfg)
    cs.validate(mut)


def test_lhs_stratifies_ordinals():
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameter(Ordinal("t", tuple(range(10))))
    samples = cs.latin_hypercube(10)
    values = sorted(s["t"] for s in samples)
    # LHS over 10 strata of a 10-long ordinal must hit every value
    assert values == list(range(10))


def test_integer_log_bounds():
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameter(Integer("n", 1, 1024, log=True))
    for _ in range(200):
        v = cs.sample_configuration()["n"]
        assert 1 <= v <= 1024


def test_forbidden_clause_rejected():
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameters([Integer("a", 0, 3), Integer("b", 0, 3)])
    cs.add_forbidden(ForbiddenClause(lambda c: c["a"] == c["b"], "a==b"))
    for _ in range(100):
        cfg = cs.sample_configuration()
        assert cfg["a"] != cfg["b"]


def test_config_key_order_invariant():
    assert config_key({"a": 1, "b": "x"}) == config_key({"b": "x", "a": 1})


def test_validation_errors():
    cs = paper_syr2k_space()
    with pytest.raises(ValueError):
        cs.validate({"P0": "bogus"})
    with pytest.raises(ValueError):
        cs.validate({})  # missing active params
    good = cs.default_configuration()
    bad = dict(good, P1="#pragma pack B")  # inactive param present
    with pytest.raises(ValueError):
        cs.validate(bad)


def test_condition_cycle_detected():
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameters([Categorical("a", (0, 1)), Categorical("b", (0, 1))])
    cs.add_condition(InCondition("a", "b", (0,)))
    cs.add_condition(InCondition("b", "a", (0,)))
    with pytest.raises(ValueError):
        cs.sample_configuration()
