"""Per-kernel Pallas validation: shape/dtype/config sweeps against the
ref.py pure-jnp oracles, in interpret mode (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels import (
    covariance,
    floyd_warshall,
    heat3d,
    lu,
    mm3,
    syr2k,
    tiled_matmul,
)
from repro.kernels import ref as R
from repro.kernels.ops import (
    covariance_op,
    floyd_warshall_op,
    heat3d_op,
    lu_op,
    mm3_op,
    syr2k_op,
)

TOL = dict(atol=3e-2, rtol=3e-2)   # bf16-friendly
F32TOL = dict(atol=2e-3, rtol=2e-3)


def _close(got, want, **tol):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **(tol or F32TOL))


# ---------------------------------------------------------------------------
# matmul building block
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(32, 16, 24), (100, 70, 90), (128, 128, 128)])
@pytest.mark.parametrize("pack", [True, False])
def test_matmul_sweep(dtype, shape, pack):
    M, K, N = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (M, K), dtype)
    b = jax.random.normal(k2, (K, N), dtype)
    got = tiled_matmul(a, b, bm=32, bn=32, bk=16, pack=pack, interpret=True)
    want = (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(dtype)
    if dtype == jnp.bfloat16:
        # pack=False accumulates in bf16 across K blocks — that is the knob's
        # documented precision trade-off, so give it extra headroom
        tol = TOL if pack else dict(atol=1e-1, rtol=1e-1)
    else:
        tol = F32TOL
    _close(got, want, **tol)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(8, 80), k=st.integers(8, 80), n=st.integers(8, 80),
    bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]), inter=st.booleans(),
)
def test_matmul_property(m, k, n, bm, bn, bk, inter):
    """Any (shape x block x order) combination is exact: schedule legality by
    construction, the core property the autotuner relies on."""
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32)
    got = tiled_matmul(a, b, bm=bm, bn=bn, bk=bk, interchange=inter, interpret=True)
    _close(got, a @ b)


# ---------------------------------------------------------------------------
# per-benchmark kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [
    dict(bi=32, bj=32, bk=32),
    dict(bi=16, bj=32, bk=16, interchange=True),
    dict(bi=32, bj=16, bk=64, pack_a=True, pack_b=True),
])
def test_syr2k_configs(cfg):
    C, A, B = R.init_syr2k(72, 56)
    _close(syr2k(C, A, B, interpret=True, **cfg), R.syr2k_ref(C, A, B),
           atol=5e-3, rtol=5e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_syr2k_dtypes(dtype):
    C, A, B = R.init_syr2k(64, 48, dtype=dtype)
    got = syr2k(C, A, B, bi=32, bj=32, bk=16, interpret=True)
    want = R.syr2k_ref(C.astype(jnp.float32), A.astype(jnp.float32),
                       B.astype(jnp.float32))
    _close(got, want, **TOL)


@pytest.mark.parametrize("fuse", [False, True])
def test_mm3(fuse):
    A, B, C, D = R.init_mm3(48, 40, 36, 44, 52)
    got = mm3(A, B, C, D, bm=16, bn=16, bk=16, fuse_second=fuse, interpret=True)
    _close(got, R.mm3_ref(A, B, C, D), atol=5e-3, rtol=5e-3)


@pytest.mark.parametrize("bs", [8, 16, 28])
def test_lu_block_sizes(bs):
    (A,) = R.init_lu(64)
    _close(lu(A, bs=bs, bm=32, bn=32, interpret=True), R.lu_ref(A),
           atol=5e-3, rtol=5e-3)


def test_lu_reconstructs_matrix():
    (A,) = R.init_lu(48)
    out = np.asarray(lu(A, bs=16, interpret=True))
    L = np.tril(out, -1) + np.eye(48)
    U = np.triu(out)
    _close(L @ U, np.asarray(A), atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("bi,fuse_t", [(4, 1), (8, 2), (16, 1), (7, 1)])
def test_heat3d_configs(bi, fuse_t):
    (A,) = R.init_heat3d(18)
    got = heat3d(A, 2, bi=bi, fuse_t=fuse_t, interpret=True)
    _close(got, R.heat3d_ref(A, 2))


@pytest.mark.parametrize("cfg", [
    dict(bi=16, bj=16, bk=32, fuse_center=True),
    dict(bi=32, bj=16, bk=16, fuse_center=False, interchange=True),
])
def test_covariance_configs(cfg):
    (data,) = R.init_covariance(90, 48)
    _close(covariance(data, interpret=True, **cfg), R.covariance_ref(data))


def test_covariance_nondivisible_rows_fused():
    # N=77 not divisible by bk: fused centering must mask padded rows exactly
    (data,) = R.init_covariance(77, 40)
    got = covariance(data, bi=16, bj=16, bk=32, fuse_center=True, interpret=True)
    _close(got, R.covariance_ref(data))


@pytest.mark.parametrize("cfg", [
    dict(bs=16, bi=32, bj=32, unroll=1),
    dict(bs=32, bi=16, bj=64, unroll=4),
])
def test_floyd_warshall_configs(cfg):
    (W,) = R.init_floyd_warshall(64)
    got = floyd_warshall(W, allow_semiring_reassociation=True, interpret=True, **cfg)
    _close(got, R.floyd_warshall_ref(W))


def test_floyd_warshall_requires_reassociation_flag():
    (W,) = R.init_floyd_warshall(16)
    with pytest.raises(ValueError, match="reassociat"):
        floyd_warshall(W, bs=8)


def test_floyd_warshall_triangle_inequality():
    (W,) = R.init_floyd_warshall(40)
    D = np.asarray(floyd_warshall(W, bs=8, allow_semiring_reassociation=True,
                                  interpret=True))
    # property: closure is idempotent (D is a fixed point)
    D2 = np.minimum(D, (D[:, :, None] + D[None, :, :]).min(axis=1))
    np.testing.assert_allclose(D, D2, atol=1e-4)


# ---------------------------------------------------------------------------
# ops.py public wrappers accept autotuner config dicts
# ---------------------------------------------------------------------------


def test_ops_accept_config_dicts():
    C, A, B = R.init_syr2k(48, 40)
    cfg = {"bi": 16, "bj": 16, "bk": 16, "interchange": True, "junk_key": 1}
    _close(syr2k_op(C, A, B, config=cfg, interpret=True), R.syr2k_ref(C, A, B),
           atol=5e-3, rtol=5e-3)
    (W,) = R.init_floyd_warshall(32)
    _close(floyd_warshall_op(W, config={"bs": 8}, interpret=True),
           R.floyd_warshall_ref(W))
    (Ah,) = R.init_heat3d(12)
    _close(heat3d_op(Ah, 1, config={"bi": 4}, interpret=True), R.heat3d_ref(Ah, 1))
    (Al,) = R.init_lu(32)
    _close(lu_op(Al, config={"bs": 8}, interpret=True), R.lu_ref(Al),
           atol=5e-3, rtol=5e-3)
    (dat,) = R.init_covariance(40, 24)
    _close(covariance_op(dat, config={"bi": 8, "bj": 8}, interpret=True),
           R.covariance_ref(dat))
    A3 = R.init_mm3(24, 20, 16, 28, 20)
    _close(mm3_op(*A3, config={"bm": 8, "bn": 8, "bk": 8}, interpret=True),
           R.mm3_ref(*A3), atol=5e-3, rtol=5e-3)


# ---------------------------------------------------------------------------
# flash attention (beyond-paper kernel)
# ---------------------------------------------------------------------------


def test_flash_attention_sweep():
    from repro.kernels.flash_attention import flash_attention

    BH, S, hd = 2, 100, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (BH, S, hd))
    k = jax.random.normal(ks[1], (BH, S, hd))
    v = jax.random.normal(ks[2], (BH, S, hd))

    def ref(causal):
        s = jnp.einsum("bqd,bkd->bqk", q, k) * (hd ** -0.5)
        if causal:
            s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, -1e30)
        return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1), v)

    for causal in (True, False):
        for bq, bk in ((32, 32), (16, 64), (64, 32)):
            got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=True)
            _close(got, ref(causal), atol=1e-4, rtol=1e-4)


def test_flash_hbm_accounting():
    from repro.kernels.flash_attention import (
        flash_hbm_bytes,
        xla_attention_hbm_bytes,
    )

    B, H, K, S, hd = 16, 28, 4, 4096, 128   # qwen2-vl GQA geometry
    fb = flash_hbm_bytes(B, H, K, S, S, hd)
    xb = xla_attention_hbm_bytes(B, H, S, S, hd)
    assert xb / fb > 10  # the S^2 vs S separation at 4k sequence
