"""repro.fidelity: ladder validation, calibration, cascade semantics,
resume, prior dedup, and the pinned cost-model rank-correlation contract."""

import json
import os
import shutil

import numpy as np
import pytest

from repro.core.database import PerformanceDatabase
from repro.core.plopper import EvalResult
from repro.core.search import BayesianSearch
from repro.core.space import Categorical, ConfigurationSpace, Ordinal, config_key
from repro.engine import Campaign
from repro.fidelity import (
    CascadeCampaign,
    FidelityLadder,
    Rung,
    RungCalibration,
    default_ladder,
    pairs_from_records,
)
from repro.fidelity.audit import audit_kernel, spearman_rho
from repro.obs.metrics import MetricsRegistry, get_registry, set_registry

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "fidelity_recorded.json")


def toy_space(seed=1):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Ordinal("a", (1, 2, 4, 8, 16), default=4),
        Ordinal("b", (1, 2, 4, 8, 16), default=4),
    ])
    return cs


def true_obj(cfg):
    return (np.log2(cfg["a"]) - 3) ** 2 + (np.log2(cfg["b"]) - 1) ** 2 + 0.1


def make_eval(scale, power=1.0):
    def evaluate(cfg):
        return EvalResult(scale * true_obj(cfg) ** power, True, {})
    return evaluate


def toy_ladder(budgets=(30, 10, 6), promote=(6, 3)):
    return FidelityLadder([
        Rung(0, "cost", make_eval(0.001, 1.1), budget=budgets[0],
             promote=promote[0]),
        Rung(1, "proxy", make_eval(0.1), budget=budgets[1],
             promote=promote[1]),
        Rung(2, "hw", make_eval(1.0), budget=budgets[2]),
    ])


# -- ladder ----------------------------------------------------------------------


class TestLadder:
    def test_validates_shape(self):
        ev = make_eval(1.0)
        with pytest.raises(ValueError, match="budget"):
            Rung(0, "cost", ev, budget=0)
        with pytest.raises(ValueError, match="ascending"):
            FidelityLadder([Rung(1, "a", ev, 4, 2), Rung(0, "b", ev, 4)])
        with pytest.raises(ValueError, match="unique"):
            FidelityLadder([Rung(0, "a", ev, 4, 2), Rung(1, "a", ev, 4)])
        with pytest.raises(ValueError, match="promotes nothing"):
            FidelityLadder([Rung(0, "a", ev, 4, 0), Rung(1, "b", ev, 4)])
        with pytest.raises(ValueError, match="cannot promote"):
            FidelityLadder([Rung(0, "a", ev, 4, 5), Rung(1, "b", ev, 8)])
        with pytest.raises(ValueError, match="can only evaluate"):
            FidelityLadder([Rung(0, "a", ev, 8, 6), Rung(1, "b", ev, 4)])

    def test_top_and_describe(self):
        ladder = toy_ladder()
        assert ladder.top.name == "hw"
        desc = ladder.describe()
        assert [d["budget"] for d in desc] == [30, 10, 6]
        assert [d["promote"] for d in desc] == [6, 3, 0]

    def test_default_ladder_shapes(self):
        l3 = default_ladder("matmul", budgets=(64, 16, 8))
        assert [r.name for r in l3] == ["cost", "proxy", "hw"]
        l2 = default_ladder("matmul", budgets=(32, 8))
        assert [r.name for r in l2] == ["cost", "hw"]
        assert l2[0].promote == max(2, 8 // 2)

    def test_default_ladder_requires_cost_model(self):
        with pytest.raises(KeyError, match="fidelity_ready"):
            default_ladder("no_such_kernel")


# -- calibration -----------------------------------------------------------------


class TestCalibration:
    def test_recovers_log_affine_mapping(self):
        # high = 10 * low^0.5 exactly; the fit must invert it
        c = RungCalibration(min_pairs=3)
        rng = np.random.default_rng(0)
        for low in rng.uniform(1e-4, 1e-1, size=12):
            c.update(low, 10.0 * low ** 0.5)
        d = c.describe()
        assert d["n_pairs"] == 12
        assert abs(d["scale"] - 0.5) < 1e-6
        assert abs(d["bias"] - 10.0) < 1e-6
        assert abs(c.apply(1e-2) - 10.0 * 1e-1) < 1e-6

    def test_bias_only_below_min_pairs(self):
        c = RungCalibration(min_pairs=3)
        c.update(0.001, 0.05)
        d = c.describe()
        assert d["scale"] == 1.0
        assert abs(d["bias"] - 50.0) < 1e-9
        assert abs(c.apply(0.002) - 0.1) < 1e-9

    def test_identity_without_pairs(self):
        c = RungCalibration()
        assert c.apply(0.123) == 0.123
        assert c.describe() == {"n_pairs": 0, "bias": 1.0, "scale": 1.0}

    def test_rejects_unusable_pairs(self):
        c = RungCalibration()
        assert not c.update(float("nan"), 1.0)
        assert not c.update(1.0, float("inf"))
        assert not c.update(-1.0, 1.0)
        assert not c.update(0.0, 1.0)
        assert c.n_pairs == 0

    def test_pairs_from_records_joins_by_config(self):
        lo, hi = PerformanceDatabase(), PerformanceDatabase()
        lo.add({"a": 1, "b": 2}, 0.001)
        lo.add({"a": 2, "b": 2}, 0.002)
        lo.add({"a": 4, "b": 4}, 0.004)
        hi.add({"a": 2, "b": 2}, 0.2)
        hi.add({"a": 1, "b": 2}, 0.1)
        hi.add({"a": 8, "b": 8}, 0.8)  # unmatched: no low-rung observation
        pairs = pairs_from_records(lo.records, hi.records)
        assert pairs == [(0.002, 0.2), (0.001, 0.1)]


# -- the cascade -----------------------------------------------------------------


class TestCascade:
    def test_finds_optimum_with_few_top_rung_evals(self):
        res = CascadeCampaign(toy_space(), toy_ladder(), seed=42,
                              n_initial=5).run()
        assert res.best.config == {"a": 8, "b": 2}
        assert res.hw_evals == 6
        assert res.stats["screened"] == 40       # rungs below the top
        assert res.stats["promoted"] == 9        # 6 + 3
        names = [s["name"] for s in res.stats["rungs"]]
        assert names == ["cost", "proxy", "hw"]

    def test_records_stamped_with_rung(self):
        res = CascadeCampaign(toy_space(), toy_ladder(), seed=42,
                              n_initial=5).run()
        for i, rung_res in enumerate(res.rungs):
            assert all(r.info.get("rung") == i for r in rung_res.db.records
                       if r.status == "ok")
            assert rung_res.timings.get("rung") == i

    def test_fixed_seed_replay_identical(self):
        runs = [CascadeCampaign(toy_space(), toy_ladder(), seed=42,
                                n_initial=5).run() for _ in range(2)]
        for a, b in zip(runs[0].rungs, runs[1].rungs):
            assert [(r.config, r.objective) for r in a.db.records] == \
                [(r.config, r.objective) for r in b.db.records]
        assert runs[0].stats["calibration"] == runs[1].stats["calibration"]

    def test_calibration_learned_from_promotions(self):
        res = CascadeCampaign(toy_space(), toy_ladder(), seed=42,
                              n_initial=5).run()
        c0, c1 = res.stats["calibration"]
        # rung0 -> rung1: high = 0.1*t vs low = 1e-3*t^1.1 — slope 1/1.1
        assert c0["n_pairs"] >= 3
        assert abs(c0["scale"] - 1 / 1.1) < 0.05
        # rung1 -> rung2 is exactly 10x: pure bias, unit scale
        assert abs(c1["bias"] - 10.0) < 0.5
        assert abs(c1["scale"] - 1.0) < 0.05

    def test_obs_counters(self):
        registry = MetricsRegistry()
        prev = get_registry()
        set_registry(registry)
        try:
            CascadeCampaign(toy_space(), toy_ladder(), seed=42, n_initial=5,
                            kernel="toy").run()
        finally:
            set_registry(prev)
        counters = registry.snapshot()["counters"]
        screened = [c for c in counters
                    if c["name"] == "fidelity_screened_total"]
        promoted = [c for c in counters
                    if c["name"] == "fidelity_promoted_total"]
        assert sum(c["value"] for c in screened) == 46   # every rung counts
        assert sum(c["value"] for c in promoted) == 9
        assert any(c["labels"].get("rung") == "0" for c in screened)
        assert all(c["labels"].get("kernel") == "toy" for c in screened)

    def test_warm_start_records_seed_top_rung(self):
        # external ground-truth priors flow into the top rung unchanged
        priors = [({"a": 8, "b": 2}, 0.1)]
        cc = CascadeCampaign(toy_space(), toy_ladder(), seed=42, n_initial=5,
                             warm_start_records=priors)
        got = cc.run()
        assert got.best.config == {"a": 8, "b": 2}
        top_priors = cc._priors_for(2)
        assert top_priors[-1] == ({"a": 8, "b": 2}, 0.1)

    def test_single_rung_matches_plain_campaign(self):
        # a one-rung ladder is exactly a flat campaign: same records
        ladder = FidelityLadder([Rung(0, "hw", make_eval(1.0), budget=12)])
        cres = CascadeCampaign(toy_space(), ladder, seed=9, n_initial=5).run()
        flat = Campaign(toy_space(), make_eval(1.0), max_evals=12,
                        seed=9, n_initial=5).run()
        assert [(r.config, r.objective) for r in cres.rungs[0].db.records] == \
            [(r.config, r.objective) for r in flat.db.records]


class TestCascadeResume:
    def test_resume_exact_remaining_budgets_and_replay(self, tmp_path):
        def fresh():
            return CascadeCampaign(toy_space(), toy_ladder(),
                                   db_root=str(tmp_path / "A"),
                                   seed=42, n_initial=5)

        full = fresh().run()

        # simulate a kill mid-rung-1: rung0 complete, rung1 truncated to 4
        # records, rung2 never started
        B = tmp_path / "B"
        shutil.copytree(tmp_path / "A" / "rung0", B / "rung0")
        os.makedirs(B / "rung1")
        src = (tmp_path / "A" / "rung1" / "results.jsonl").read_text()
        (B / "rung1" / "results.jsonl").write_text(
            "".join(src.splitlines(keepends=True)[:4]))

        resumed = CascadeCampaign(toy_space(), toy_ladder(), db_root=str(B),
                                  seed=42, n_initial=5).run()
        fresh_counts = [s["screened"] for s in resumed.stats["rungs"]]
        assert fresh_counts == [0, 6, 6]  # exactly the remaining budgets
        for a, b in zip(full.rungs, resumed.rungs):
            assert [(r.config, r.objective) for r in a.db.records] == \
                [(r.config, r.objective) for r in b.db.records]
        assert resumed.best.config == full.best.config

    def test_completed_cascade_is_a_noop_on_rerun(self, tmp_path):
        root = str(tmp_path / "db")
        CascadeCampaign(toy_space(), toy_ladder(), db_root=root,
                        seed=42, n_initial=5).run()
        again = CascadeCampaign(toy_space(), toy_ladder(), db_root=root,
                                seed=42, n_initial=5).run()
        assert all(s["screened"] == 0 for s in again.stats["rungs"])
        assert again.best.config == {"a": 8, "b": 2}


# -- warm_start_records dedup (the double-counting fix) --------------------------


class TestPriorDedup:
    def test_duplicate_configs_collapse_to_highest_fidelity(self):
        cfg_a, cfg_b = {"a": 1, "b": 2}, {"a": 4, "b": 8}
        records = [
            (cfg_a, 0.001),   # rung 0 estimate
            (cfg_b, 0.002),
            (cfg_a, 0.110),   # rung 1: same config, better fidelity
            (cfg_a, 0.100),   # rung 2: highest fidelity — must win
        ]
        s = BayesianSearch(toy_space(), prior_records=records, seed=1)
        assert s.n_priors == 2                      # not 4
        # first-occurrence row order, last-occurrence (highest-rung) value
        assert s._prior_y.tolist() == [0.100, 0.002]

    def test_db_recorded_configs_dropped_from_priors(self):
        db = PerformanceDatabase()
        db.add({"a": 1, "b": 2}, 0.09)
        records = [({"a": 1, "b": 2}, 0.001), ({"a": 4, "b": 8}, 0.002)]
        s = BayesianSearch(toy_space(), prior_records=records, seed=1, db=db)
        assert s.n_priors == 1                      # the DB one dropped
        assert s._prior_y.tolist() == [0.002]

    def test_invalid_prior_configs_skipped(self):
        records = [({"a": 3, "b": 2}, 0.5),         # 3 not in the Ordinal
                   ({"a": 2, "b": 2}, 0.4)]
        s = BayesianSearch(toy_space(), prior_records=records, seed=1)
        assert s.n_priors == 1


# -- rung-aware Campaign contract ------------------------------------------------


class TestRungAwareCampaign:
    def test_rung_none_leaves_records_untouched(self):
        res = Campaign(toy_space(), make_eval(1.0), max_evals=8,
                       seed=3, n_initial=4).run()
        assert all("rung" not in r.info for r in res.db.records)
        assert "rung" not in res.timings

    def test_rung_label_does_not_change_trajectory(self):
        plain = Campaign(toy_space(), make_eval(1.0), max_evals=10,
                         seed=3, n_initial=4).run()
        runged = Campaign(toy_space(), make_eval(1.0), max_evals=10,
                          seed=3, n_initial=4, rung=2).run()
        assert [r.config for r in plain.db.records] == \
            [r.config for r in runged.db.records]
        assert [r.objective for r in plain.db.records] == \
            [r.objective for r in runged.db.records]
        assert all(r.info.get("rung") == 2 for r in runged.db.records)
        assert runged.timings["rung"] == 2


# -- spearman + the pinned rank-correlation contract -----------------------------


class TestSpearman:
    def test_perfect_and_inverted(self):
        assert spearman_rho([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
        assert spearman_rho([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)

    def test_ties_and_degenerate(self):
        assert abs(spearman_rho([1, 1, 2, 2], [1, 1, 2, 2]) - 1.0) < 1e-12
        assert np.isnan(spearman_rho([1, 1, 1], [1, 2, 3]))
        assert np.isnan(spearman_rho([1, 2], [1, 2]))


class TestPinnedRankCorrelation:
    """Cost-model ordering vs *recorded* hardware timings: the fixture holds
    measured proxy-dims timings per kernel; the test recomputes the (fully
    deterministic) cost scores, so a cost-model change that scrambles the
    ordering moves rho and fails here instead of silently degrading every
    cascade's screen."""

    @pytest.fixture(scope="class")
    def recorded(self):
        with open(FIXTURE) as fh:
            return json.load(fh)

    def test_every_fidelity_ready_kernel_recorded(self, recorded):
        from repro.kernels.cost import KERNEL_COST_FNS
        assert sorted(recorded["kernels"]) == sorted(KERNEL_COST_FNS)

    def test_rho_reproduces_recorded_value(self, recorded):
        from repro.kernels.problems import make_cost_evaluator
        for kernel, entry in recorded["kernels"].items():
            cost = make_cost_evaluator(kernel, tuple(entry["dims"]))
            scores, measured = [], []
            for row in entry["rows"]:
                res = cost(row["config"])
                assert res.ok, f"{kernel}: recorded config now infeasible"
                scores.append(res.objective)
                measured.append(row["measured_sec"])
            rho = spearman_rho(scores, measured)
            assert rho == pytest.approx(entry["rho"], abs=0.02), \
                f"{kernel}: cost-model ordering drifted from the recording"

    def test_strong_kernels_stay_above_threshold(self, recorded):
        strong = {k for k, e in recorded["kernels"].items() if e["strong"]}
        # the cascade's poster kernels must stay screenable
        assert {"matmul", "mm3"} <= strong
        for kernel in strong:
            assert recorded["kernels"][kernel]["rho"] >= 0.2

    def test_audit_kernel_with_injected_measure(self, recorded):
        entry = recorded["kernels"]["matmul"]
        table = {config_key(r["config"]): r["measured_sec"]
                 for r in entry["rows"]}
        # same seed/samples as the recording: every sampled config resolves
        rep = audit_kernel("matmul", n_samples=recorded["samples"],
                           seed=recorded["seed"], dims=tuple(entry["dims"]),
                           measure=lambda c: table.get(config_key(c),
                                                       float("nan")))
        assert rep["screen_ok"]
        assert rep["rho"] == pytest.approx(entry["rho"], abs=0.02)


# -- coverage audit + CLI plumbing -----------------------------------------------


class TestCoverageAudit:
    def test_fidelity_readiness_covers_registry(self):
        from repro.dispatch.registry import registered
        from repro.kernels.problems import fidelity_readiness
        cov = fidelity_readiness()
        assert set(cov) == set(registered())
        assert all(isinstance(v, bool) for v in cov.values())
        assert cov["matmul"] is True

    def test_analyze_space_emits_fidelity_flags(self, capsys):
        from repro.launch.analyze import main
        rc = main(["space", "--kernel", "syr2k", "--samples", "8", "--json"])
        assert rc == 0
        out = json.loads(capsys.readouterr().out)
        assert all("fidelity_ready" in row for row in out["audit"])
        assert "coverage" in out["fidelity"]
        assert out["fidelity"]["coverage"]["syr2k"] is True

    def test_fidelity_cli_show(self, capsys):
        from repro.launch.fidelity import main
        rc = main(["show", "--kernel", "matmul"])
        assert rc == 0
        out = json.loads(capsys.readouterr().out)
        assert out["fidelity_ready"] is True
        assert [r["name"] for r in out["ladder"]] == ["cost", "proxy", "hw"]

    def test_fidelity_cli_audit_plumbing(self, capsys, monkeypatch, tmp_path):
        import repro.fidelity.audit as audit_mod
        from repro.launch.fidelity import main
        rows = {"matmul": dict(kernel="matmul", dims=[8], target="host",
                               n_sampled=4, n_paired=4, n_dropped=0,
                               rho=0.9, rho_min=0.2, screen_ok=True),
                "lu": dict(kernel="lu", dims=[8], target="host",
                           n_sampled=4, n_paired=4, n_dropped=0,
                           rho=-0.1, rho_min=0.2, screen_ok=False)}
        monkeypatch.setattr(audit_mod, "audit_kernel",
                            lambda k, **kw: rows[k])
        out_file = str(tmp_path / "audit.json")
        rc = main(["audit", "--kernel", "matmul", "--json", "--out", out_file])
        assert rc == 0
        assert json.loads(capsys.readouterr().out)["weak_kernels"] == []
        assert os.path.exists(out_file)
        # --strict turns a weak kernel into a CI failure
        rc = main(["audit", "--kernel", "lu", "--strict"])
        assert rc == 1

    def test_autotune_cli_rejects_bad_cascade_combos(self):
        from repro.launch.autotune import main
        with pytest.raises(SystemExit):
            main(["--kernel", "syr2k", "--cascade", "--backend", "cost"])
        with pytest.raises(SystemExit):
            main(["--kernel", "syr2k", "--rung-budgets", "8,4"])


# -- real-kernel cascade + BackgroundTuner wiring --------------------------------


class TestRealKernelCascade:
    def test_default_ladder_cascade_on_matmul_proxy(self):
        # real cost model + real timing, at proxy dims so this stays fast
        from repro.kernels.problems import PROXY_DIMS
        from repro.kernels.spaces import kernel_space
        ladder = default_ladder("matmul", budgets=(16, 4),
                                dims=PROXY_DIMS["matmul"], repeats=1, warmup=1)
        res = CascadeCampaign(kernel_space("matmul", target="host", seed=5),
                              ladder, seed=5, n_initial=4,
                              kernel="matmul").run()
        assert res.best is not None
        assert res.hw_evals <= 4
        assert res.stats["rungs"][0]["screened"] == 16


class TestBackgroundTunerCascade:
    def _make(self, tmp_path, **kwargs):
        from repro.dispatch.store import TuningStore
        from repro.dispatch.background import BackgroundTuner
        store = TuningStore(str(tmp_path / "store"))
        return store, BackgroundTuner(store, max_evals=8, n_initial=4,
                                      seed=11, **kwargs)

    def test_cascade_campaign_publishes_and_counts(self, tmp_path):
        from repro.kernels.problems import problem_signature_for
        from repro.kernels.spaces import kernel_space
        store, tuner = self._make(tmp_path, cascade=True,
                                  cascade_budgets=(24, 4))
        sig = problem_signature_for("matmul", "host")

        def evaluator(cfg):  # synthetic "hardware": order matches cost rank
            return EvalResult(1e-6 * (abs(int(cfg["bm"]) - 128) + 1), True, {})

        fut = tuner.submit("matmul", sig, "host",
                           space=kernel_space("matmul", seed=11),
                           evaluator=evaluator)
        assert fut is not None
        rec = fut.result(timeout=120)
        assert not tuner.errors, tuner.errors
        assert rec is not None and rec.kernel == "matmul"
        assert tuner.stats["cascade_campaigns"] == 1
        assert tuner.stats["screened"] == 24
        assert tuner.stats["promoted"] >= 2
        assert len(store.records("matmul")) >= 1
        tuner.shutdown()

    def test_cost_backend_falls_back_to_flat(self, tmp_path):
        from repro.kernels.problems import problem_signature_for
        from repro.kernels.spaces import kernel_space
        store, tuner = self._make(tmp_path, cascade=True)
        sig = problem_signature_for("matmul", "cost")
        fut = tuner.submit("matmul", sig, "cost",
                           space=kernel_space("matmul", seed=11),
                           evaluator=lambda cfg: EvalResult(
                               1e-6 * int(cfg["bm"]), True, {}))
        fut.result(timeout=120)
        assert not tuner.errors, tuner.errors
        assert tuner.stats["cascade_campaigns"] == 0
        assert tuner.stats["campaigns"] == 1
        tuner.shutdown()

    def test_telemetry_surfaces_cascade_stats(self, tmp_path):
        from repro.dispatch.service import DispatchService
        store, tuner = self._make(tmp_path, cascade=True)
        svc = DispatchService(store=store, tuner=tuner)
        tel = svc.telemetry()
        assert "screened" in tel and "promoted" in tel
        assert "cascade_campaigns" in tel
        tuner.shutdown()
