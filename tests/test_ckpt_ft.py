"""Checkpointing + fault tolerance: save/restore roundtrip, async saver,
gradient compression with error feedback, straggler detection, elastic
mesh planning, evaluation-campaign deadline handling."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.core.plopper import DeadlineEvaluator, EvalResult, TimingEvaluator
from repro.ft import (
    LADDER,
    StragglerMonitor,
    compressed_psum,
    ef_compress_grads,
    plan_mesh,
    quantize,
)


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": jnp.ones((4,), jnp.bfloat16),
        "nested": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), t, step=3)
    got, step = restore(str(tmp_path), t)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(t)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_multiple(tmp_path):
    t = _tree()
    for s in (1, 5, 3):
        save(str(tmp_path), t, step=s)
    assert latest_step(str(tmp_path)) == 5
    _, step = restore(str(tmp_path), t)   # default: latest
    assert step == 5


def test_restore_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), _tree(), step=1)
    bad = dict(_tree(), w=jnp.zeros((2, 2)))
    with pytest.raises(ValueError, match="mismatch"):
        restore(str(tmp_path), bad)


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in range(5):
        ck.save(t, step=s)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_quantize_dequantize_bounded_error():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    q, scale = quantize(x)
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_steps():
    """With error feedback the *accumulated* compressed gradient converges to
    the accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 0.1
    residual = {"g": jnp.zeros((64,), jnp.float32)}
    acc = jnp.zeros((64,))
    steps = 50
    for _ in range(steps):
        deq, new_r = ef_compress_grads({"g": g_true}, residual)
        residual = {"g": new_r["g"]}
        acc = acc + deq["g"]
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(g_true),
                               atol=2e-3)


def test_compressed_psum_in_shard_map():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(devs[:1]), ("d",))
    x = jnp.linspace(-1.0, 1.0, 16).reshape(1, 16)

    def f(xs):
        return compressed_psum(xs[0], "d")[None]

    out = shard_map(f, mesh=mesh, in_specs=P("d", None), out_specs=P("d", None))(x)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(x[0]), atol=2e-2)


# ---------------------------------------------------------------------------
# straggler + elastic + deadline
# ---------------------------------------------------------------------------


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=3)
    for _ in range(10):
        _, slow = mon.observe(0.1)
        assert not slow
    _, slow = mon.observe(0.5)
    assert slow
    assert mon.flagged == 1
    # the straggler does not poison the baseline
    assert mon.ewma < 0.15


def test_elastic_ladder_planning():
    plan = plan_mesh(512)
    assert plan.shape == (2, 16, 16) and plan.multi_pod
    plan = plan_mesh(511)   # one pod lost a chip -> fall to single pod
    assert plan.shape == (16, 16)
    assert plan.dropped == 511 - 256
    plan = plan_mesh(100)
    assert plan.n_devices <= 100
    with pytest.raises(RuntimeError):
        plan_mesh(0)
    # ladder is strictly decreasing in device count
    sizes = [a * b * c for (a, b, c) in LADDER]
    assert sizes == sorted(sizes, reverse=True)


def test_deadline_evaluator_flags_stragglers():
    def slow_eval(cfg):
        time.sleep(0.05)
        return EvalResult(1.0, True, {})

    ev = DeadlineEvaluator(slow_eval, deadline_sec=0.01)
    res = ev({"x": 1})
    assert not res.ok
    assert "straggler_wall_sec" in res.info

    ev2 = DeadlineEvaluator(slow_eval, deadline_sec=10.0)
    assert ev2({"x": 1}).ok


def test_timing_evaluator_catches_exceptions():
    def broken(cfg):
        raise RuntimeError("synthetic compile failure")

    ev = TimingEvaluator(broken)
    res = ev({"x": 1})
    assert not res.ok and res.objective >= 1e9
    assert "synthetic compile failure" in res.info["error"]


def test_compressed_psum_int8_wire_dtype():
    """The int8 path must put int8 on the wire (the compression claim):
    lower a shard_map psum and assert the all-reduce payload dtype."""
    import re

    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

    def f(xs):
        return compressed_psum(xs[0], "d")[None]

    x = jnp.linspace(-1, 1, 32).reshape(1, 32)
    txt = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                            out_specs=P("d", None))).lower(x).compile().as_text()
    ar_lines = [l for l in txt.splitlines() if " all-reduce(" in l and "=" in l]
    payload_dtypes = set()
    for l in ar_lines:
        payload_dtypes.update(re.findall(r"(s8|f32|bf16)\[", l.split(" all-reduce(")[0]))
    # gradient payload rides in s8; the f32 scale agreement is a scalar pmax
    assert "s8" in payload_dtypes, (payload_dtypes, ar_lines)
